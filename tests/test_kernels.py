"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per-kernel shape/dtype sweeps + hypothesis property tests, per the repo's
kernel contract: every kernel must match its ref.py oracle allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # guarded hypothesis import

from repro.core import affine
from repro.kernels import ref, ops
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas


# ---------------------------------------------------------------------------
# fake_quant kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (100, 100), (1, 128),
                                   (257, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fake_quant_kernel_matches_ref(shape, dtype, bits):
    key = jax.random.PRNGKey(hash((shape, bits)) % 2**31)
    x = (jax.random.normal(key, shape) * 3.0).astype(dtype)
    vmin = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    vmax = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    got = fake_quant_pallas(x.astype(jnp.float32).reshape(shape), vmin, vmax,
                            bits, block_rows=64, block_cols=128,
                            interpret=True)
    want = ref.fake_quant_with_range_ref(x.astype(jnp.float32), vmin, vmax,
                                         bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fake_quant_op_dispatches_and_matches():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 128))
    got = ops.fake_quant(x, 8, backend="interpret")
    want = ref.fake_quant_ref(x, 8)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 300), st.sampled_from([2, 6, 8]))
def test_prop_fake_quant_kernel_random_shapes(rows, cols, bits):
    x = jax.random.normal(jax.random.PRNGKey(rows * 1000 + cols), (rows, cols))
    got = ops.fake_quant(x, bits, backend="interpret")
    want = ref.fake_quant_ref(x, bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# int8 matmul kernel
# ---------------------------------------------------------------------------

def _quantize_operands(key, m, k, n):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k)) * 2.0
    w = jax.random.normal(kw, (k, n)) * 0.5
    xq, xp = affine.quantize_to_int(x, 8, axis=None)
    # per-output-channel weight quantization (paper's per-axis scheme)
    wq_list, wscale, wzero = [], [], []
    wq, wp = affine.quantize_to_int(w, 8, axis=1)
    return x, w, xq, xp, wq, wp


@pytest.mark.parametrize("mkn", [(8, 128, 128), (32, 256, 64), (100, 70, 36),
                                 (1, 512, 256), (64, 64, 512)])
def test_int8_matmul_kernel_matches_ref(mkn):
    m, k, n = mkn
    x, w, xq, xp, wq, wp = _quantize_operands(jax.random.PRNGKey(m + n), m, k, n)
    w_scale = wp.delta.reshape(-1)
    w_zero = wp.zero_point.reshape(-1)
    got = int8_matmul_pallas(xq, wq, xp.delta, xp.zero_point, w_scale, w_zero,
                             block_m=32, block_n=64, block_k=64,
                             interpret=True)
    want = ref.int8_matmul_ref(xq, wq, xp.delta, w_scale, xp.zero_point,
                               w_zero)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_int8_matmul_ref_close_to_float_matmul():
    """End-to-end: int8 GEMM approximates the float product (paper's premise)."""
    m, k, n = 16, 256, 32
    x, w, xq, xp, wq, wp = _quantize_operands(jax.random.PRNGKey(7), m, k, n)
    got = ref.int8_matmul_ref(xq, wq, xp.delta, wp.delta.reshape(-1),
                              xp.zero_point, wp.zero_point.reshape(-1))
    want = x @ w
    # error ~ O(delta); relative tolerance scaled to magnitudes
    assert float(jnp.max(jnp.abs(got - want))) < 0.05 * float(
        jnp.max(jnp.abs(want))) + 0.1


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 40), st.integers(8, 130), st.integers(1, 50))
def test_prop_int8_matmul_random_shapes(m, k, n):
    x, w, xq, xp, wq, wp = _quantize_operands(
        jax.random.PRNGKey(m * 7919 + k * 13 + n), m, k, n)
    got = ops.int8_matmul(xq, wq, xp.delta, xp.zero_point,
                          wp.delta.reshape(-1), wp.zero_point.reshape(-1),
                          backend="interpret")
    want = ref.int8_matmul_ref(xq, wq, xp.delta, wp.delta.reshape(-1),
                               xp.zero_point, wp.zero_point.reshape(-1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq,dim", [(128, 64), (256, 128), (96, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_dense_ref(seq, dim, causal):
    key = jax.random.PRNGKey(seq + dim)
    q, k, v = jax.random.normal(key, (3, seq, dim))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_kv=64, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(0)
    q, k, v = jax.random.normal(key, (3, 128, 64))
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_kv=32, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(1)
    q, k, v = jax.random.normal(key, (3, 64, 32)) * 3.0
    got = flash_attention_pallas(q, k, v, causal=True, softcap=50.0,
                                 block_q=32, block_kv=32, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_decode_alignment():
    """seq_q < seq_kv (decode/suffix): queries align to the end of kv."""
    key = jax.random.PRNGKey(2)
    k = jax.random.normal(key, (128, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (128, 64))
    q = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    got = flash_attention_pallas(q, k, v, causal=True, block_q=8,
                                 block_kv=32, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_batched_op():
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (2, 4, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(6), (2, 4, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 64, 32))
    got = ops.flash_attention(q, k, v, backend="interpret")
    want = ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 5), st.integers(1, 4))
def test_prop_flash_attention_blocks(log_seq, dim8):
    seq, dim = 2 ** log_seq * 8, dim8 * 16
    key = jax.random.PRNGKey(seq * dim)
    q, k, v = jax.random.normal(key, (3, seq, dim))
    got = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_kv=16, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# int8 KV-cache decode attention kernel
# ---------------------------------------------------------------------------

from repro.kernels.int8_cache_attention import int8_cache_decode_attention


def _make_cache(key, t, dh):
    k = jax.random.normal(key, (t, dh))
    v = jax.random.normal(jax.random.fold_in(key, 1), (t, dh))
    ks = jnp.max(jnp.abs(k), axis=-1, keepdims=True) / 127.0
    vs = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0
    kc = jnp.clip(jnp.round(k / ks), -127, 127).astype(jnp.int8)
    vc = jnp.clip(jnp.round(v / vs), -127, 127).astype(jnp.int8)
    return kc, ks, vc, vs


@pytest.mark.parametrize("t,dh,pos", [(256, 64, 255), (256, 64, 100),
                                      (128, 128, 17)])
def test_int8_cache_decode_matches_ref(t, dh, pos):
    key = jax.random.PRNGKey(t + pos)
    q = jax.random.normal(key, (4, dh))
    kc, ks, vc, vs = _make_cache(jax.random.fold_in(key, 7), t, dh)
    got = int8_cache_decode_attention(q, kc, ks, vc, vs, pos,
                                      block_t=64, interpret=True)
    want = ref.int8_cache_decode_ref(q, kc, ks, vc, vs, pos)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_int8_cache_decode_window():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64))
    kc, ks, vc, vs = _make_cache(jax.random.fold_in(key, 3), 256, 64)
    got = int8_cache_decode_attention(q, kc, ks, vc, vs, 200, window=64,
                                      block_t=64, interpret=True)
    want = ref.int8_cache_decode_ref(q, kc, ks, vc, vs, 200, window=64)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_int8_cache_decode_quantization_error_small():
    """int8 cache attention ~ fp attention (the feature's premise)."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (128, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (128, 64))
    ks = jnp.max(jnp.abs(k), axis=-1, keepdims=True) / 127.0
    vs = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0
    kc = jnp.clip(jnp.round(k / ks), -127, 127).astype(jnp.int8)
    vc = jnp.clip(jnp.round(v / vs), -127, 127).astype(jnp.int8)
    fp = ref.mha_ref(q, k, v, causal=False)
    q8 = ref.int8_cache_decode_ref(q, kc, ks, vc, vs, 127)
    assert float(jnp.max(jnp.abs(fp - q8))) < 0.05
