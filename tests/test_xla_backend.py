"""Native-XLA int8 backend vs the pure-jnp oracle (ISSUE 6).

Acceptance contracts:
* xla-vs-ref *bitwise* parity of ``ops.int8_matmul`` across bits {4, 8} x
  odd/even K (the int4 padding edge) x chunked K (contractions longer than
  the exact-f32 bound, exercising the int32 chunk accumulator),
* the same parity for the per-layer and fused actor applies across heads
  {logits, q, mu} and for the conv im2col path (Catch pixel actors),
* ``_resolve``: ``auto`` -> ``xla`` off-TPU, the ``REPRO_KERNEL_BACKEND``
  env override, and explicit ``backend=`` always winning,
* the 8-bit branch rejects K-mismatched weights with a ``ValueError``
  (regression: it used to contract garbage silently),
* int8 + ``kernel_backend="xla"`` trains end to end on every topology.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affine
from repro.kernels import ops, ref, xla_backend
from repro.rl import actorq, loops
from repro.rl.networks import make_network

SMALL_DQN = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                 buffer_size=512, batch_size=16, warmup=8)


# ---------------------------------------------------------------------------
# int8_matmul: xla vs ref, bitwise
# ---------------------------------------------------------------------------

def _operands(key, m, k, n, bits):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k)) * 2.0
    w = jax.random.normal(kw, (k, n)) * 0.5
    xq, xp = affine.quantize_to_int(x, 8, axis=None)
    wq, wp = affine.quantize_to_int(w, bits, axis=1)
    return xq, xp, wq, wp


# odd/even K, K=1 edge, and K=700 > the 8-bit exact-f32 chunk (258) so the
# CPU path must take the chunked int32 accumulator
@pytest.mark.parametrize("mkn", [(9, 64, 32), (9, 65, 32), (7, 33, 5),
                                 (1, 1, 8), (5, 700, 16)])
@pytest.mark.parametrize("bits", [4, 8])
def test_int8_matmul_xla_bitwise_matches_ref(mkn, bits):
    m, k, n = mkn
    xq, xp, wq, wp = _operands(jax.random.PRNGKey(m * 131 + k + bits),
                               m, k, n, bits)
    w_scale = wp.delta.reshape(-1)
    w_zero = wp.zero_point.reshape(-1)
    want = ref.int8_matmul_ref(xq, wq, xp.delta, w_scale, xp.zero_point,
                               w_zero)
    w_arg = affine.pack_int4(wq) if bits <= 4 else wq
    got = ops.int8_matmul(xq, w_arg, xp.delta, xp.zero_point, w_scale,
                          w_zero, backend="xla", w_bits=bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_exact_f32_matmul_chunks_like_int32():
    """Adversarial contraction: worst-case magnitude codes at K well past
    the exact-f32 bound still reproduce int32 accumulation exactly."""
    k = 3000
    xq = jnp.full((2, k), -128, jnp.int8)
    wq = jnp.full((k, 3), 127, jnp.int8)
    xc = xq.astype(jnp.float32) - (-3.0)
    wc = wq.astype(jnp.float32) - 2.0
    got = xla_backend._exact_f32_matmul(xc, wc, 8)
    want = (np.asarray(xc).astype(np.int64) @ np.asarray(wc).astype(np.int64)
            ).astype(np.int32).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# actor applies: per-layer + fused + conv, xla vs ref, bitwise
# ---------------------------------------------------------------------------

_HEAD_OUT = {"logits": 4, "q": 3, "mu": 2}   # a2c/ppo (+value), dqn, ddpg


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("head", sorted(_HEAD_OUT))
@pytest.mark.parametrize("fused", [False, True])
def test_actor_apply_xla_bitwise_matches_ref(bits, head, fused):
    net = make_network((5,), _HEAD_OUT[head], hidden=(24, 24))
    params = net.init(jax.random.PRNGKey(bits + len(head)))
    obs = jax.random.normal(jax.random.PRNGKey(7), (9, 5)) * 2.0
    qp = actorq.pack_actor_params(params, bits=bits)
    if fused:
        qp = actorq.calibrate_actor_cache(qp, obs, backend="ref")
        assert actorq.ACT_QUANT in qp
    got = actorq.quantized_apply(qp, obs, backend="xla")
    want = actorq.quantized_apply(qp, obs, backend="ref")
    assert got.shape == (9, _HEAD_OUT[head])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [4, 8])
def test_conv_im2col_xla_bitwise_matches_ref(bits):
    net = make_network((6, 6, 2), 3, conv_filters=(4,), fc_width=16)
    qp = actorq.pack_actor_params(net.init(jax.random.PRNGKey(3)), bits=bits)
    obs = jax.random.normal(jax.random.PRNGKey(4), (5, 6, 6, 2))
    np.testing.assert_array_equal(
        np.asarray(actorq.quantized_apply(qp, obs, backend="xla")),
        np.asarray(actorq.quantized_apply(qp, obs, backend="ref")))


# ---------------------------------------------------------------------------
# dispatch: auto resolution + REPRO_KERNEL_BACKEND override
# ---------------------------------------------------------------------------

def test_auto_resolves_to_xla_off_tpu(monkeypatch):
    monkeypatch.delenv(ops.ENV_BACKEND, raising=False)
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert ops._resolve("auto") == want


@pytest.mark.parametrize("forced", ops.BACKENDS)
def test_env_override_forces_backend(monkeypatch, forced):
    monkeypatch.setenv(ops.ENV_BACKEND, forced)
    assert ops._resolve("auto") == forced


def test_explicit_backend_beats_env_override(monkeypatch):
    monkeypatch.setenv(ops.ENV_BACKEND, "ref")
    assert ops._resolve("interpret") == "interpret"


def test_env_override_rejects_unknown_backend(monkeypatch):
    monkeypatch.setenv(ops.ENV_BACKEND, "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        ops._resolve("auto")


def test_env_override_reaches_jitted_op(monkeypatch):
    """The override must bite inside a jitted ``backend="auto"`` call.  An
    off-pattern shape keeps this trace out of the shared jit cache (the
    env var is read at trace time, so a cached entry would shadow it)."""
    monkeypatch.setenv(ops.ENV_BACKEND, "ref")
    xq, xp, wq, wp = _operands(jax.random.PRNGKey(0), 3, 17, 11, 8)
    got = ops.int8_matmul(xq, wq, xp.delta, xp.zero_point,
                          wp.delta.reshape(-1), wp.zero_point.reshape(-1))
    want = ref.int8_matmul_ref(xq, wq, xp.delta, wp.delta.reshape(-1),
                               xp.zero_point, wp.zero_point.reshape(-1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# shape validation (regression: 8-bit branch accepted K-mismatched weights)
# ---------------------------------------------------------------------------

def test_int8_matmul_rejects_k_mismatched_weights():
    xq, xp, wq, wp = _operands(jax.random.PRNGKey(1), 4, 32, 8, 8)
    with pytest.raises(ValueError, match="unpacked codes"):
        ops.int8_matmul(xq, wq[:-1], xp.delta, xp.zero_point,
                        wp.delta.reshape(-1), wp.zero_point.reshape(-1),
                        backend="ref")


def test_int8_matmul_rejects_packed_codes_without_w_bits():
    """A byte-packed int4 cache passed with the default w_bits=8 is the
    silent-garbage case the validation exists for."""
    xq, xp, wq, wp = _operands(jax.random.PRNGKey(2), 4, 32, 8, 4)
    packed = affine.pack_int4(wq)
    with pytest.raises(ValueError, match="byte-packed"):
        ops.int8_matmul(xq, packed, xp.delta, xp.zero_point,
                        wp.delta.reshape(-1), wp.zero_point.reshape(-1),
                        backend="ref")
    with pytest.raises(ValueError, match="byte-packed codes"):
        ops.int8_matmul(xq, wq, xp.delta, xp.zero_point,
                        wp.delta.reshape(-1), wp.zero_point.reshape(-1),
                        backend="ref", w_bits=4)


# ---------------------------------------------------------------------------
# training smokes: kernel_backend="xla" on every topology
# ---------------------------------------------------------------------------

def test_int8_xla_trains_fused_driver():
    res = loops.train("a2c", "cartpole", iterations=4, record_every=2,
                      eval_episodes=2, steps_per_call=2,
                      actor_backend="int8", calib_batch=8,
                      algo_overrides=dict(kernel_backend="xla"))
    assert all(np.isfinite(res.rewards))
    assert res.algo_cfg.kernel_backend == "xla"


def test_int8_xla_actor_learner_topology():
    res = loops.train("dqn", "cartpole", topology="actor-learner",
                      num_actors=2, sync_every=2, actor_backend="int8",
                      iterations=4, record_every=2, eval_episodes=2,
                      algo_overrides=dict(SMALL_DQN, kernel_backend="xla"))
    assert all(np.isfinite(res.rewards))
    assert len(res.divergences) > 0


def test_int8_xla_async_topology():
    res = loops.train("dqn", "cartpole", topology="async", num_actors=2,
                      sync_every=4, steps_per_call=2, actor_backend="int8",
                      calib_batch=8, iterations=4, record_every=2,
                      eval_episodes=2,
                      algo_overrides=dict(SMALL_DQN, kernel_backend="xla"))
    assert all(np.isfinite(res.rewards))
    assert res.actor_lags and all(lag >= 4 for lag in res.actor_lags)
