"""Continuous-batching policy-serving subsystem (ISSUE 7).

Acceptance contracts:

* **Batched == sequential, bitwise** — for every actor backend (fp32 /
  int8 / int4), dispatching N sessions as one padded batch produces
  bit-for-bit the actions of submitting them one at a time.  Quantized
  backends serve a *calibrated* cache (static activation scales make each
  row's compute independent of batch composition — the serving contract);
  the test pins a single bucket so fp32's GEMM shape matches too.
* **Hot-swap is never torn** — a param push during in-flight batches is
  one atomic reference swap: every response's action is consistent with
  the cache version it reports, under a swap-hammering thread.
* **Bucket selection is deterministic** — a pure function of
  (batch size, bucket list); padding is repeat-last-row and therefore
  range-neutral for the dynamically-quantized path.
* A slow open-loop latency smoke drives the threaded server end to end.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.rl import actorq
from repro.rl.env import EnvSpec
from repro.rl.networks import make_network
from repro.serving import (Batcher, PolicyServer, SessionTable, StepCounter,
                           greedy_calib_obs, pad_rows, remove_padding,
                           select_bucket)

DISCRETE = EnvSpec(name="srv-disc", obs_shape=(5,), n_actions=3)
CONTINUOUS = EnvSpec(name="srv-cont", obs_shape=(5,), action_dim=2,
                     action_scale=2.0)

ALL_BACKENDS = ["fp32", "int8", "int4"]


def _params(spec, seed=0, hidden=(16, 16)):
    out = spec.n_actions if not spec.continuous else spec.action_dim
    return make_network(spec.obs_shape, out, hidden=hidden).init(
        jax.random.PRNGKey(seed))


def _obs(n, spec=DISCRETE, seed=1):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (n,) + tuple(spec.obs_shape))) * 1.5


def _server(spec, actor_backend, *, buckets=(8,), calib=True,
            kernel_backend="ref", max_wait_us=0, seed=0):
    srv = PolicyServer(spec, actor_backend=actor_backend,
                       kernel_backend=kernel_backend, buckets=buckets,
                       max_wait_us=max_wait_us,
                       calib_batch=32 if calib else 0)
    srv.push_params(_params(spec, seed),
                    calib_obs=_obs(32, spec, seed=seed + 100))
    return srv


# ---------------------------------------------------------------------------
# bucket selection / padding primitives
# ---------------------------------------------------------------------------

def test_bucket_selection_deterministic_minimal():
    buckets = (4, 16, 64)
    for n in range(1, 65):
        b = select_bucket(n, buckets)
        assert b == min(x for x in buckets if x >= n)
        assert b == select_bucket(n, buckets)   # pure — replays identically
    with pytest.raises(ValueError):
        select_bucket(65, buckets)
    with pytest.raises(ValueError):
        select_bucket(0, buckets)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=512),
       st.lists(st.integers(min_value=1, max_value=512), min_size=1,
                max_size=6, unique=True))
def test_bucket_selection_property(n, raw_buckets):
    buckets = tuple(sorted(raw_buckets))
    fits = [b for b in buckets if b >= n]
    if not fits:
        with pytest.raises(ValueError):
            select_bucket(n, buckets)
    else:
        assert select_bucket(n, buckets) == fits[0]


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=16))
def test_pad_rows_roundtrip(n, extra):
    x = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    padded = pad_rows(x, n + extra)
    assert padded.shape == (n + extra, 3)
    np.testing.assert_array_equal(np.asarray(remove_padding(padded, n)), x)
    # repeat-padding never moves a per-tensor min/max (range-neutrality)
    assert padded.min() == x.min() and padded.max() == x.max()


def test_pad_rows_rejects_overflow():
    with pytest.raises(ValueError):
        pad_rows(np.zeros((4, 2), np.float32), 3)


def test_step_counter_threaded():
    c = StepCounter()
    threads = [threading.Thread(target=lambda: [c.next() for _ in range(500)])
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 4000   # no lost increments


def test_session_table_lifecycle():
    tab = SessionTable()
    a, b = tab.open(), tab.open()
    assert len(tab) == 2 and a != b
    tab.on_step(a, version=3)
    assert tab.checkout(a).steps == 1
    assert tab.checkout(a).last_version == 3
    rec = tab.close(a)
    assert rec.closed and len(tab) == 1
    with pytest.raises(KeyError):
        tab.checkout(a)
    with pytest.raises(KeyError):
        tab.close(a)
    assert tab.stats() == {"open": 1, "opened": 2, "closed": 1}


# ---------------------------------------------------------------------------
# THE acceptance contract: padded-batch == per-session sequential, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("actor_backend", ALL_BACKENDS)
@pytest.mark.parametrize("spec", [DISCRETE, CONTINUOUS],
                         ids=["discrete", "continuous"])
def test_batched_equals_sequential_bitwise(actor_backend, spec):
    """One padded batch of N sessions == N single-session dispatches,
    bit for bit (continuous spec compares full f32 action vectors)."""
    srv = _server(spec, actor_backend)
    obs = _obs(7, spec)
    sids = [srv.open_session() for _ in range(7)]
    batched = srv.serve(list(zip(sids, obs)))
    sequential = [srv.serve([(sid, o)])[0] for sid, o in zip(sids, obs)]
    for got, want in zip(batched, sequential):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kernel_backend", ["ref", "xla"])
@pytest.mark.parametrize("actor_backend", ["int8", "int4"])
def test_batched_equals_sequential_across_buckets(actor_backend,
                                                  kernel_backend):
    """Quantized + calibrated caches are exact integer programs: the
    bitwise contract holds even when batched and sequential dispatches pad
    to *different* buckets (rows are independent once scales are static)."""
    srv = _server(CONTINUOUS, actor_backend, buckets=(2, 4, 16),
                  kernel_backend=kernel_backend)
    obs = _obs(9, CONTINUOUS, seed=7)
    sids = [srv.open_session() for _ in range(9)]
    batched = srv.serve(list(zip(sids, obs)))        # buckets 16 (9 rows)
    sequential = [srv.serve([(sid, o)])[0]           # bucket 2 each
                  for sid, o in zip(sids, obs)]
    for got, want in zip(batched, sequential):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=8), st.integers(0, 2 ** 31 - 1))
def test_batched_equals_sequential_property(n, seed):
    """Property form over batch size and data for the int8 backend."""
    srv = _server(CONTINUOUS, "int8", seed=seed % 97)
    obs = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (n, 5)), np.float32) * 3.0
    sids = [srv.open_session() for _ in range(n)]
    batched = srv.serve(list(zip(sids, obs)))
    sequential = [srv.serve([(sid, o)])[0] for sid, o in zip(sids, obs)]
    for got, want in zip(batched, sequential):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kernel_backend", ["ref", "xla"])
def test_dynamic_path_padding_neutral(kernel_backend):
    """calib_batch=0 (dynamic per-layer quantization) is sensitive to
    batch *composition* — but never to repeat-padding: a padded dispatch
    equals the direct unpadded apply on the same rows, bitwise, because
    duplicated rows cannot move any per-tensor min/max at any layer."""
    params = _params(CONTINUOUS, seed=3)
    srv = _server(CONTINUOUS, "int8", buckets=(16,), calib=False,
                  kernel_backend=kernel_backend, seed=3)
    obs = _obs(5, CONTINUOUS, seed=11)
    sids = [srv.open_session() for _ in range(5)]
    served = srv.serve(list(zip(sids, obs)))         # padded 5 -> 16
    cache = actorq.pack_actor_params(params, 8)
    mu = actorq.quantized_apply(cache, jnp.asarray(obs),
                                backend=kernel_backend)
    direct = np.asarray(jnp.tanh(mu) * CONTINUOUS.action_scale)
    np.testing.assert_array_equal(np.stack(served), direct)


def test_calibrated_serving_uses_fused_cache():
    srv = _server(DISCRETE, "int8")
    assert actorq.ACT_QUANT in srv.current.cache
    srv_dyn = _server(DISCRETE, "int8", calib=False)
    assert actorq.ACT_QUANT not in srv_dyn.current.cache


# ---------------------------------------------------------------------------
# hot-swap: atomic, never torn, zero-copy
# ---------------------------------------------------------------------------

def _versioned_params(version, n_actions=3, obs_dim=5):
    """Zero-weight policy whose argmax encodes ``version % n_actions`` —
    any serving result reveals which cache computed it."""
    p = _params(DISCRETE, seed=0, hidden=(8,))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p)
    bias = jnp.zeros((n_actions,), jnp.float32
                     ).at[version % n_actions].set(10.0 + version)
    zeros["out"]["b"] = bias
    return zeros


@pytest.mark.parametrize("actor_backend", ALL_BACKENDS)
def test_hot_swap_action_matches_reported_version(actor_backend):
    """Hammer push_params from one thread while serving from others:
    every response's action must equal the expected action OF THE VERSION
    IT REPORTS — a torn cache (mixing two versions in one dispatch) or a
    mid-batch swap would break the correspondence."""
    srv = PolicyServer(DISCRETE, actor_backend=actor_backend,
                       kernel_backend="ref", buckets=(4, 8), max_wait_us=200,
                       calib_batch=0)
    srv.push_params(_versioned_params(0))
    srv.warmup()
    obs = _obs(8)
    stop = threading.Event()
    pushes = {"n": 1}

    def swapper():
        while not stop.is_set():
            srv.push_params(_versioned_params(pushes["n"]))
            pushes["n"] += 1

    th = threading.Thread(target=swapper, daemon=True)
    with srv:
        th.start()
        try:
            sids = [srv.open_session() for _ in range(8)]
            for round_ in range(30):
                reqs = [srv.submit(sid, obs[i % 8])
                        for i, sid in enumerate(sids)]
                for r in reqs:
                    res = r.result(timeout=20)
                    assert int(res.action) == res.version % 3, \
                        (int(res.action), res.version)
        finally:
            stop.set()
            th.join(timeout=5)
    assert pushes["n"] > 1           # the hammer actually swapped
    assert srv.stats()["served"] == 8 * 30


def test_push_is_reference_swap_not_copy():
    """Zero-copy contract: the published fp32 cache IS the pushed pytree
    (same array objects), and a new push leaves the old entry's arrays
    untouched for in-flight readers."""
    srv = PolicyServer(DISCRETE, actor_backend="fp32", buckets=(4,))
    p1 = _params(DISCRETE, seed=1)
    e1 = srv.push_params(p1)
    assert e1.cache is p1
    assert e1.cache["out"]["w"] is p1["out"]["w"]
    snap = np.asarray(e1.cache["out"]["w"]).copy()
    e2 = srv.push_params(_params(DISCRETE, seed=2))
    assert e2.version == e1.version + 1
    assert srv.current is e2
    np.testing.assert_array_equal(np.asarray(e1.cache["out"]["w"]), snap)


def test_serve_requires_pushed_cache():
    srv = PolicyServer(DISCRETE, actor_backend="int8", buckets=(4,))
    sid = srv.open_session()
    with pytest.raises(RuntimeError):
        srv.serve([(sid, np.zeros(5, np.float32))])
    with pytest.raises(RuntimeError):
        srv.warmup()


# ---------------------------------------------------------------------------
# request validation / admission policy
# ---------------------------------------------------------------------------

def test_submit_validates_session_and_shape():
    srv = _server(DISCRETE, "fp32")
    with pytest.raises(KeyError):
        srv.submit(12345, np.zeros(5, np.float32))
    sid = srv.open_session()
    with pytest.raises(ValueError):
        srv.submit(sid, np.zeros(4, np.float32))
    srv.close_session(sid)
    with pytest.raises(KeyError):
        srv.submit(sid, np.zeros(5, np.float32))


def test_batcher_admission_caps_and_orders():
    b = Batcher(max_batch=4, max_wait_us=0)
    reqs = [type("R", (), {"t_enqueue": time.perf_counter()})()
            for _ in range(6)]
    for r in reqs:
        b.put(r)
    first = b.get_batch(timeout=0)
    second = b.get_batch(timeout=0)
    assert first == reqs[:4] and second == reqs[4:]   # FIFO, capped
    assert b.get_batch(timeout=0) is None


def test_batcher_close_fails_queued_requests():
    srv = _server(DISCRETE, "fp32")
    sid = srv.open_session()
    srv.start()
    srv.stop()
    with pytest.raises(RuntimeError):
        srv.submit(sid, np.zeros(5, np.float32))


def test_server_restarts_after_stop():
    """stop() closes the admission queue terminally; start() swaps in a
    fresh one so a stopped server serves again (benchmark probe cycle)."""
    srv = _server(DISCRETE, "int8")
    sid = srv.open_session()
    with srv:
        a1 = srv.submit(sid, np.zeros(5, np.float32)).result(timeout=10)
    with pytest.raises(RuntimeError):
        srv.submit(sid, np.zeros(5, np.float32))
    with srv:
        a2 = srv.submit(sid, np.zeros(5, np.float32)).result(timeout=10)
    np.testing.assert_array_equal(a1.action, a2.action)
    assert srv.sessions.checkout(sid).steps == 2


def test_server_invalid_buckets_rejected():
    for bad in [(), (8, 4), (4, 4)]:
        with pytest.raises(ValueError):
            PolicyServer(DISCRETE, buckets=bad)


def test_stats_padding_accounting():
    srv = _server(DISCRETE, "fp32", buckets=(8,))
    sids = [srv.open_session() for _ in range(5)]
    srv.serve([(s, np.zeros(5, np.float32)) for s in sids])
    st_ = srv.stats()
    assert st_["served"] == 5 and st_["padding_rows"] == 3
    assert st_["bucket_counts"][8] == 1 and st_["dispatches"] == 1
    assert st_["sessions"]["open"] == 5


def test_greedy_calib_obs_shape():
    from repro.rl.envs import make as make_env
    env = make_env("cartpole")
    cache = actorq.pack_actor_params(_params(
        EnvSpec(name="cp", obs_shape=(4,), n_actions=2)), 8)
    obs = greedy_calib_obs(env, cache, 24, kernel_backend="ref")
    assert obs.shape == (24, 4)
    assert bool(jnp.all(jnp.isfinite(obs)))


# ---------------------------------------------------------------------------
# open-loop latency smoke (threaded end-to-end)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_open_loop_latency_smoke():
    """Drive the threaded server with an open-loop burst from many
    sessions; every request completes, latency percentiles are finite,
    and the dispatcher actually batched (dispatches < requests)."""
    srv = _server(DISCRETE, "int8", buckets=(8, 32, 128), max_wait_us=500,
                  kernel_backend="ref")
    srv.warmup()
    n_sessions, per_session = 64, 4
    obs = _obs(n_sessions)
    with srv:
        sids = [srv.open_session() for _ in range(n_sessions)]
        reqs = []
        for _ in range(per_session):
            reqs.extend(srv.submit(sid, obs[i])
                        for i, sid in enumerate(sids))
        lats = [r.result(timeout=60).latency_s for r in reqs]
    total = n_sessions * per_session
    assert len(lats) == total
    assert all(np.isfinite(lats)) and np.percentile(lats, 99) > 0
    st_ = srv.stats()
    assert st_["served"] == total
    assert st_["dispatches"] < total      # continuous batching happened
    for sid in sids:
        assert srv.sessions.checkout(sid).steps == per_session


# ---------------------------------------------------------------------------
# dispatch-path invariant (ISSUE 8 bugfix)
# ---------------------------------------------------------------------------

def test_serve_dispatch_mismatch_fails_dropped_requests(monkeypatch):
    """Regression: ``serve``'s drained-count check was a bare ``assert``
    — stripped under ``python -O``, and a dropped request would hang its
    waiter on ``result()`` forever.  It must be a real error that also
    fails the unserved waiters."""
    srv = _server(DISCRETE, "fp32", buckets=(4,))
    sids = [srv.open_session() for _ in range(3)]
    real_get = srv.batcher.get_batch
    dropped = []

    def dropping_get(timeout=0):
        batch = real_get(timeout=timeout)
        if batch and not dropped:       # lose one admitted request
            dropped.append(batch.pop())
        return batch

    monkeypatch.setattr(srv.batcher, "get_batch", dropping_get)
    with pytest.raises(RuntimeError, match="invariant"):
        srv.serve(list(zip(sids, _obs(3))))
    # the dropped waiter was failed, not left hanging
    with pytest.raises(RuntimeError, match="invariant"):
        dropped[0].result(timeout=0)
    # the requests that WERE served still completed normally
    assert srv.stats()["served"] == 2
