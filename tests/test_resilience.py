"""Chaos suite for the self-healing ActorQ runtime (ISSUE 10).

Layers, bottom up:

* guard units — CRC sensitivity, finite checks with leaf-path diagnosis,
  structural cache validation, deterministic-jitter backoff, bounded
  retry.
* fault units — plan parsing round-trip, deterministic bit flips,
  poisoning, the injector's fire-once-across-attempts contract.
* checkpoint integrity — a torn ``leaves.msgpack`` is rejected by the
  manifest checksum on restore and skipped by ``latest_step``.
* serving hardening — bounded-queue shedding, request deadlines, worker
  crash auto-restart, hot-swap integrity.
* the chaos matrix — one supervised run per topology under a plan
  covering every applicable fault kind; every fault fires and the run
  recovers (``dropped_sync`` records not-applicable under in-jit syncs).
* the acceptance regression — a supervised run that retries, *and* one
  that rolls back a poisoned checkpoint, each finishing with params
  bitwise identical to the clean never-faulted run (the host-side
  injection + PR-8 bitwise-resume contract make recovery invisible).
"""
import dataclasses
import os
import time

import jax
import numpy as np
import pytest

from repro import resilience as rz
from repro.checkpoint import CheckpointManager
from repro.resilience import faults, guards, supervisor
from repro.rl import loops
from repro.rl.networks import make_network

SMALL = dict(n_envs=2, rollout_steps=2, updates_per_iter=2,
             buffer_size=64, batch_size=8, warmup=8)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _kwargs(topo, *, iterations=6, ckpt_dir=None, ckpt_every=3, **kw):
    multi = topo != "fused"
    out = dict(algo="dqn", env_name="cartpole", iterations=iterations,
               seed=3, record_every=3, eval_episodes=2,
               actor_backend="int8", algo_overrides=dict(SMALL),
               net_kwargs=dict(hidden=(16,)), topology=topo,
               num_actors=2 if multi else 1,
               sync_every=2 if multi else 1,
               checkpoint_dir=ckpt_dir,
               checkpoint_every=ckpt_every if ckpt_dir else 0)
    out.update(kw)
    return out


def _mlp_cache(backend="int8"):
    from repro.rl import actorq
    params = make_network((4,), 2, hidden=(8,)).init(jax.random.PRNGKey(0))
    return params, actorq.make_actor_cache(params, backend)


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_crc_detects_single_bitflip():
    _, cache = _mlp_cache()
    crc = guards.tree_crc32(cache)
    flipped = faults.bitflip_tree(cache, seed=7, nbits=1)
    assert guards.tree_crc32(flipped) != crc
    guards.verify_crc(cache, crc, what="cache")
    with pytest.raises(guards.IntegrityError, match="checksum mismatch"):
        guards.verify_crc(flipped, crc, what="cache")


def test_crc_covers_dtype_and_shape():
    a = {"x": np.zeros(4, np.float32)}
    b = {"x": np.zeros(4, np.int32)}      # same bytes, different dtype
    c = {"x": np.zeros((2, 2), np.float32)}
    crcs = {guards.tree_crc32(t) for t in (a, b, c)}
    assert len(crcs) == 3


def test_check_finite_names_offending_leaf():
    tree = {"w": np.ones(3, np.float32),
            "b": np.array([1.0, np.nan], np.float32)}
    with pytest.raises(guards.NonFiniteError, match=r"\['b'\]"):
        guards.check_finite(tree, what="params")
    guards.check_finite({"w": np.ones(3, np.float32)})
    # int leaves are not finite-checked
    guards.check_finite({"codes": np.array([1, 2], np.int8)})


def test_all_finite_is_jittable():
    tree = {"w": np.ones(3, np.float32)}
    assert bool(jax.jit(guards.all_finite)(tree))
    tree["w"] = np.array([1.0, np.inf], np.float32)
    assert not bool(jax.jit(guards.all_finite)(tree))


def test_validate_cache_catches_scale_corruption():
    _, cache = _mlp_cache()
    guards.validate_cache(cache)

    def poison_delta(x):
        if isinstance(x, guards.PackedTensor):
            return x._replace(delta=np.full_like(np.asarray(x.delta),
                                                 np.nan))
        return x

    bad = jax.tree_util.tree_map(
        poison_delta, cache,
        is_leaf=lambda x: isinstance(x, guards.PackedTensor))
    with pytest.raises(guards.CodeRangeError, match="delta"):
        guards.validate_cache(bad)


def test_validate_cache_catches_packed_size_mismatch():
    _, cache = _mlp_cache("int4")
    guards.validate_cache(cache)

    def truncate(x):
        if isinstance(x, guards.PackedTensor) and x.orig_shape is not None:
            codes = np.asarray(x.codes)
            return x._replace(codes=codes.reshape(-1)[:-1])
        return x

    bad = jax.tree_util.tree_map(
        truncate, cache,
        is_leaf=lambda x: isinstance(x, guards.PackedTensor))
    with pytest.raises(guards.CodeRangeError, match="packed"):
        guards.validate_cache(bad)


def test_backoff_deterministic_and_capped():
    a = guards.backoff_delay(2, base_s=0.01, factor=2.0, cap_s=1.0, seed=7)
    b = guards.backoff_delay(2, base_s=0.01, factor=2.0, cap_s=1.0, seed=7)
    assert a == b
    assert a >= 0.04                        # base * factor**2, plus jitter
    assert guards.backoff_delay(50, base_s=0.01, factor=2.0, cap_s=0.3,
                                seed=7) == 0.3
    assert guards.deterministic_jitter(1, 2) != \
        guards.deterministic_jitter(1, 3)


def test_retry_call_bounded():
    calls, slept = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise guards.IntegrityError("transient")
        return "ok"

    out = guards.retry_call(flaky, retries=2, base_s=0.01, factor=2.0,
                            cap_s=0.1, retry_on=guards.GuardError,
                            sleep=slept.append)
    assert out == "ok" and len(calls) == 3 and len(slept) == 2

    calls.clear()
    with pytest.raises(guards.IntegrityError):
        guards.retry_call(lambda: (calls.append(1),
                                   (_ for _ in ()).throw(
                                       guards.IntegrityError("always")))[1],
                          retries=1, base_s=0.01, factor=2.0, cap_s=0.1,
                          retry_on=guards.GuardError, sleep=lambda s: None)
    assert len(calls) == 2                  # 1 + retries


# ---------------------------------------------------------------------------
# faults
# ---------------------------------------------------------------------------

def test_fault_plan_parse_roundtrip():
    spec = "7:nan_grad@3,bitflip_push@5:nbits=3,actor_crash@8:shard=1"
    plan = faults.FaultPlan.parse(spec)
    assert plan.seed == 7 and len(plan.faults) == 3
    assert plan.faults[1].nbits == 3 and plan.faults[2].shard == 1
    assert faults.FaultPlan.parse(plan.spec_string()) == plan
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.FaultPlan.parse("1:meteor@3")
    with pytest.raises(ValueError, match="SEED"):
        faults.FaultPlan.parse("nan_grad@3")
    with pytest.raises(ValueError, match="nan|inf"):
        faults.FaultSpec(kind="nan_grad", step=1, mode="zero")


def test_bitflip_tree_deterministic_and_minimal():
    _, cache = _mlp_cache()
    a = faults.bitflip_tree(cache, seed=11, nbits=2)
    b = faults.bitflip_tree(cache, seed=11, nbits=2)
    assert guards.tree_crc32(a) == guards.tree_crc32(b)
    assert guards.tree_crc32(a) != guards.tree_crc32(cache)
    # structure, dtypes and shapes survive the corruption
    la, lc = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(cache)
    assert [(np.asarray(x).dtype, np.asarray(x).shape) for x in la] == \
        [(np.asarray(x).dtype, np.asarray(x).shape) for x in lc]
    # at most nbits bytes differ across the flattened payload
    diff = sum(int(np.sum(np.asarray(x).reshape(-1).view(np.uint8)
                          != np.asarray(y).reshape(-1).view(np.uint8)))
               for x, y in zip(la, lc))
    assert 1 <= diff <= 2


def test_poison_params_modes():
    params = {"w": np.ones((2, 2), np.float32),
              "codes": np.ones(4, np.int8)}
    assert np.isnan(np.asarray(
        faults.poison_params(params, "nan")["w"]).reshape(-1)[0])
    assert np.isinf(np.asarray(
        faults.poison_params(params, "inf")["w"]).reshape(-1)[0])
    # int leaves are never poisoned
    np.testing.assert_array_equal(
        faults.poison_params(params, "nan")["codes"], params["codes"])


def test_injector_fires_once_across_attempts():
    plan = faults.FaultPlan.parse("3:nan_grad@4")
    inj = faults.FaultInjector(plan)
    assert inj.pending("nan_grad", 3) is None
    # chunked drivers overshoot the target round: first opportunity wins
    assert inj.take("nan_grad", 6) is not None
    assert inj.take("nan_grad", 6) is None   # consumed; never re-fires
    inj.record_fired("nan_grad", 6)
    assert inj.injected_count == 1


def test_injector_repeat_budget():
    plan = faults.FaultPlan.parse("3:straggler@2:repeat=2")
    inj = faults.FaultInjector(plan)
    assert inj.take("straggler", 2) is not None
    assert inj.take("straggler", 3) is not None
    assert inj.take("straggler", 4) is None


def test_watchdog_stall_episodes():
    t = [0.0]
    wd = supervisor.Watchdog(timeout_s=1.0, clock=lambda: t[0])
    wd.beat("round", 1)
    t[0] = 2.5
    wd.check()
    wd.check()                  # same episode: recorded once
    assert len(wd.stalls) == 1 and wd.stalls[0]["phase"] == "round"
    wd.beat("push", 2)          # re-arms
    t[0] = 5.0
    wd.check()
    assert len(wd.stalls) == 2 and wd.stalls[1]["phase"] == "push"


# ---------------------------------------------------------------------------
# checkpoint integrity (satellite b)
# ---------------------------------------------------------------------------

def test_torn_checkpoint_rejected_and_skipped(tmp_path):
    d = str(tmp_path)
    loops.train(**_kwargs("fused", ckpt_dir=d, ckpt_every=3))
    mgr = CheckpointManager(d)
    assert mgr.steps() == [3, 6]
    faults.truncate_file(os.path.join(mgr.step_path(6), "leaves.msgpack"))
    assert not mgr.step_valid(6)
    assert mgr.step_valid(3)
    assert mgr.latest_step() == 3           # the torn step is skipped
    tmpl = [np.zeros(tuple(s["shape"]), np.dtype(s["dtype"]))
            for s in mgr.manifest(6)["leaves"]]
    with pytest.raises(ValueError, match="checksum mismatch"):
        mgr.restore(6, tmpl)
    mgr.restore(3, tmpl)                    # the valid one still loads


def test_resume_skips_torn_newest_checkpoint(tmp_path):
    d = str(tmp_path)
    ref = loops.train(**_kwargs("fused", iterations=9))
    loops.train(**_kwargs("fused", iterations=6, ckpt_dir=d, ckpt_every=3))
    mgr = CheckpointManager(d)
    faults.truncate_file(os.path.join(mgr.step_path(6), "leaves.msgpack"))
    res = loops.train(**_kwargs("fused", iterations=9, ckpt_dir=d,
                                ckpt_every=3, resume=True))
    # resumed from step 3 (the newest *valid* step) and still lands
    # bitwise on the uninterrupted trajectory
    for a, b in zip(_leaves(ref.state.params), _leaves(res.state.params)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# serving hardening (satellite a)
# ---------------------------------------------------------------------------

def _serve_setup(**srv_kw):
    from repro.rl.env import EnvSpec
    from repro.serving import PolicyServer

    spec = EnvSpec(name="resilience-test", obs_shape=(4,), n_actions=2)
    params = make_network(spec.obs_shape, 2, hidden=(8,)).init(
        jax.random.PRNGKey(0))
    srv = PolicyServer(spec, actor_backend="int8", buckets=(4, 8),
                       max_wait_us=200, **srv_kw)
    srv.push_params(params)
    return srv, params


def test_bounded_queue_sheds_with_typed_error():
    from repro.serving import QueueFullError

    srv, _ = _serve_setup(max_queue=2)
    obs = np.zeros(4, np.float32)
    sid = srv.open_session()
    srv.submit(sid, obs), srv.submit(sid, obs)   # fill the bound
    with pytest.raises(QueueFullError, match="admission queue full"):
        srv.submit(sid, obs)
    assert srv.stats()["rejected"] == 1
    # draining the queue re-opens admission
    batch = srv.batcher.get_batch(timeout=1.0)
    srv.serve_batch(batch)
    srv.submit(sid, obs)


def test_request_deadline_expires_with_typed_error():
    from repro.serving import DeadlineExceededError

    srv, _ = _serve_setup(request_deadline_s=0.005)
    sid = srv.open_session()
    r = srv.submit(sid, np.zeros(4, np.float32))
    time.sleep(0.02)                        # worker not running: it waits
    batch = srv.batcher.get_batch(timeout=1.0)
    srv.serve_batch(batch)                  # pre-filters the expired one
    with pytest.raises(DeadlineExceededError):
        r.result(timeout=1.0)
    assert srv.stats()["deadline_expired"] == 1
    assert srv.stats()["served"] == 0


def test_worker_crash_restarts_and_counts():
    plan = faults.FaultPlan.parse("5:actor_crash@1")
    ctx = faults.ResilienceContext(faults.FaultInjector(plan))
    srv, _ = _serve_setup(fault_hook=ctx.serving_fault_hook())
    obs = np.zeros(4, np.float32)
    sid = srv.open_session()
    with srv:
        assert srv.submit(sid, obs).result(timeout=30) is not None
        # second dispatched batch crashes the worker; it auto-restarts
        crashed = srv.submit(sid, obs)
        with pytest.raises(faults.ActorCrashError):
            crashed.result(timeout=30)
        assert srv.submit(sid, obs).result(timeout=30) is not None
    stats = srv.stats()
    assert stats["worker"]["crashes"] >= 1
    assert stats["worker"]["restarts"] >= 1
    assert stats["last_error"] and "ActorCrashError" in stats["last_error"]
    assert stats["worker"]["wedged"] == 0


def test_hot_swap_integrity_verified():
    srv, params = _serve_setup()
    entry = srv.current
    assert entry.crc32 == guards.tree_crc32(entry.cache)
    srv.verify_current()
    srv._entry = dataclasses.replace(
        entry, cache=faults.bitflip_tree(entry.cache, seed=3))
    with pytest.raises(guards.IntegrityError, match="checksum"):
        srv.verify_current()


# ---------------------------------------------------------------------------
# the chaos matrix: every fault kind, all three topologies
# ---------------------------------------------------------------------------

# per topology: a plan covering every kind that can fire there, plus
# dropped_sync everywhere (recorded not-applicable under in-jit syncs)
MATRIX = (
    ("fused",
     "5:actor_crash@2,straggler@3:delay_s=0.01,nan_grad@4,"
     "bitflip_push@4,crash_commit@3,dropped_sync@2",
     {"actor_crash", "straggler", "nan_grad", "bitflip_push",
      "crash_commit"}, {"dropped_sync"}),
    ("actor-learner",
     "7:actor_crash@2,straggler@3:delay_s=0.01,nan_grad@5:mode=inf,"
     "bitflip_push@4,crash_commit@3,dropped_sync@2",
     {"actor_crash", "straggler", "nan_grad", "bitflip_push",
      "crash_commit"}, {"dropped_sync"}),
    ("async",
     "9:actor_crash@2,straggler@3:delay_s=0.01,nan_grad@5,"
     "bitflip_push@4,crash_commit@3,dropped_sync@6",
     {"actor_crash", "straggler", "nan_grad", "bitflip_push",
      "crash_commit", "dropped_sync"}, set()),
)


@pytest.mark.parametrize("topo,spec,expect_fired,expect_na",
                         [m for m in MATRIX],
                         ids=[m[0] for m in MATRIX])
def test_chaos_matrix_recovers(tmp_path, topo, spec, expect_fired,
                               expect_na):
    plan = rz.FaultPlan.parse(spec)
    kw = _kwargs(topo, iterations=8, ckpt_dir=str(tmp_path), ckpt_every=2)
    res, rep = rz.supervise(kw, plan=plan,
                            config=rz.SupervisorConfig(max_retries=4))
    assert rep.status == "ok"
    assert {k for k, _, _ in rep.faults_fired} == expect_fired
    assert {k for k, _, _ in rep.faults_not_applicable} == expect_na
    assert len(rep.faults_fired) + len(rep.faults_not_applicable) \
        == len(plan.faults)
    assert rep.retries >= 1                 # at least the actor_crash
    if "actor_crash" in expect_fired:
        assert rep.quarantined == [0]
    assert all(np.isfinite(r) for r in res.rewards)


def test_supervisor_abort_carries_report(tmp_path):
    # a fault that re-fires on every attempt exhausts the ladder
    plan = rz.FaultPlan.parse("3:actor_crash@2:repeat=99")
    kw = _kwargs("fused", iterations=4, ckpt_dir=str(tmp_path),
                 ckpt_every=2)
    cfg = rz.SupervisorConfig(max_retries=1, max_rollbacks=1,
                              backoff_base_s=0.001, backoff_cap_s=0.002)
    with pytest.raises(rz.SupervisorAbort) as ei:
        rz.supervise(kw, plan=plan, config=cfg)
    rep = ei.value.report
    assert rep.status == "aborted"
    # initial + retry, then the rollback resets the retry budget:
    # initial-from-rolled-back-step + retry again = 4 attempts
    assert rep.attempts == 4
    assert rep.retries == 2 and rep.rollbacks == 1
    assert rep.attempt_log[-1]["action"] == "abort"
    assert "ActorCrashError" in rep.error
    assert "aborted" in rep.summary()
    assert rep.to_dict()["retries"] == 2


def test_unrecoverable_errors_raise_through():
    def broken(**kw):
        raise TypeError("a programming error, not a fault")

    with pytest.raises(TypeError):
        rz.supervise(dict(algo="dqn", env_name="cartpole"),
                     train_fn=broken)


# ---------------------------------------------------------------------------
# acceptance regression: recovery is bitwise-invisible
# ---------------------------------------------------------------------------

def test_retry_recovery_bitwise_identical(tmp_path):
    """A retried run lands bitwise on the clean trajectory (fused)."""
    ref = loops.train(**_kwargs("fused", iterations=6))
    plan = rz.FaultPlan.parse("5:nan_grad@4")
    res, rep = rz.supervise(
        _kwargs("fused", iterations=6, ckpt_dir=str(tmp_path),
                ckpt_every=3), plan=plan)
    assert rep.retries == 1 and rep.rollbacks == 0
    for a, b in zip(_leaves(ref.state.params), _leaves(res.state.params)):
        np.testing.assert_array_equal(a, b)
    assert ref.rewards == res.rewards


def test_rollback_recovery_bitwise_identical(tmp_path):
    """Poison saved into a checkpoint forces a rollback; the re-run from
    the previous good step still lands bitwise on the clean trajectory.

    ``check_every=2`` delays detection past the round-3 checkpoint
    (``record_every=6`` keeps the eval-cache guard out of round 3 too),
    so the poisoned params are committed at step 3 and every resume from
    it re-trips the guard at round 4 — only discarding step 3 (the
    rollback) recovers, exactly the escalation the supervisor exists
    for."""
    ref = loops.train(**_kwargs("actor-learner", iterations=6,
                                record_every=6))
    plan = rz.FaultPlan.parse("5:nan_grad@3")
    guard = rz.GuardConfig(check_every=2)
    cfg = rz.SupervisorConfig(max_retries=1, max_rollbacks=1,
                              backoff_base_s=0.001, backoff_cap_s=0.002)
    res, rep = rz.supervise(
        _kwargs("actor-learner", iterations=6, record_every=6,
                ckpt_dir=str(tmp_path), ckpt_every=1),
        plan=plan, guard=guard, config=cfg)
    assert rep.rollbacks == 1
    for a, b in zip(_leaves(ref.state.params), _leaves(res.state.params)):
        np.testing.assert_array_equal(a, b)
    assert ref.rewards == res.rewards


@pytest.mark.slow
def test_supervised_run_still_converges(tmp_path):
    """Chaos must not cost convergence: a supervised DQN run under a
    multi-fault plan reaches the same reward regime as the clean run."""
    plan = rz.FaultPlan.parse(
        "11:actor_crash@5,nan_grad@10,bitflip_push@15,crash_commit@12")
    kw = dict(algo="dqn", env_name="cartpole", iterations=60, seed=0,
              record_every=20, eval_episodes=4, actor_backend="int8",
              topology="actor-learner", num_actors=2, sync_every=2,
              checkpoint_dir=str(tmp_path), checkpoint_every=5)
    ref = loops.train(**{k: v for k, v in kw.items()
                         if not k.startswith("checkpoint")})
    res, rep = rz.supervise(kw, plan=plan)
    assert rep.status == "ok" and len(rep.faults_fired) == 4
    for a, b in zip(_leaves(ref.state.params), _leaves(res.state.params)):
        np.testing.assert_array_equal(a, b)
    assert res.rewards[-1] == ref.rewards[-1]
