"""Fused quantized-MLP actor kernel + W4A8 packed weights (ISSUE 5).

Acceptance contracts:
* interpret-vs-ref parity of the single-pass kernel across
  bits {4, 8} x MLP depth {1, 2, 3} x head (logits / q / mu),
* the *bitwise anchor*: with static activation scales calibrated from the
  very batch being evaluated, the fused path reproduces the per-layer
  dynamic ``quantized_mlp_apply`` exactly (eager; under jit only XLA's
  FMA fusion may differ, bounded by a tight allclose),
* ``actor_backend="int4"`` halves the packed actor-cache codes and trains/
  deploys end to end through every topology.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affine, ptq
from repro.core.fake_quant import NullQATContext
from repro.core.qconfig import QuantConfig
from repro.rl import actorq, loops
from repro.rl.networks import make_network

SMALL_DQN = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                 buffer_size=512, batch_size=16, warmup=8)


# ---------------------------------------------------------------------------
# int4 byte packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 7, 16, 33])
def test_pack_unpack_int4_roundtrip(k):
    codes = jax.random.randint(jax.random.PRNGKey(k), (k, 6), -8, 8
                               ).astype(jnp.int8)
    packed = affine.pack_int4(codes)
    assert packed.shape == ((k + 1) // 2, 6) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(affine.unpack_int4(packed, k)),
                                  np.asarray(codes))


def test_quantize_with_params_matches_dynamic():
    """Static requant with params derived from the same tensor is the
    dynamic quantizer bit for bit — the fused kernel's core contract."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 33)) * 2.5
    q_dyn, p_dyn = affine.quantize_to_int(x, 8)
    p_cal = affine.calibration_params(x, 8)
    np.testing.assert_array_equal(np.asarray(p_dyn.delta),
                                  np.asarray(p_cal.delta))
    np.testing.assert_array_equal(np.asarray(p_dyn.zero_point),
                                  np.asarray(p_cal.zero_point))
    np.testing.assert_array_equal(
        np.asarray(q_dyn), np.asarray(affine.quantize_with_params(x, p_cal)))


# ---------------------------------------------------------------------------
# interpret-vs-ref parity matrix
# ---------------------------------------------------------------------------

_HEAD_OUT = {"logits": 4, "q": 3, "mu": 2}   # a2c/ppo (+value), dqn, ddpg


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("head", sorted(_HEAD_OUT))
def test_fused_kernel_interpret_matches_ref(bits, depth, head):
    out_dim = _HEAD_OUT[head]
    net = make_network((5,), out_dim, hidden=(24,) * depth)
    params = net.init(jax.random.PRNGKey(bits * 10 + depth))
    obs = jax.random.normal(jax.random.PRNGKey(depth), (9, 5)) * 2.0
    cache = actorq.calibrate_actor_cache(
        actorq.pack_actor_params(params, bits=bits), obs, backend="ref")
    assert actorq.ACT_QUANT in cache
    got_ref = actorq.quantized_apply(cache, obs, backend="ref")
    got_int = actorq.quantized_apply(cache, obs, backend="interpret")
    assert got_ref.shape == (9, out_dim)
    np.testing.assert_allclose(np.asarray(got_int), np.asarray(got_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# static-requant bitwise anchor vs the per-layer dynamic path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
def test_fused_static_anchor_matches_per_layer_dynamic(bits):
    """Calibrated on the batch it then evaluates, the fused single-pass
    kernel IS the per-layer dynamic path: identical affine params at every
    layer, identical integer codes, identical float epilogue order —
    bitwise equal eagerly; under jit only FMA re-association remains."""
    net = make_network((4,), 3, hidden=(32, 16, 8))
    params = net.init(jax.random.PRNGKey(1))
    obs = jax.random.normal(jax.random.PRNGKey(2), (50, 4)) * 2.0
    qp = actorq.pack_actor_params(params, bits=bits)
    with jax.disable_jit():
        cache = actorq.calibrate_actor_cache(qp, obs, backend="ref")
        fused = actorq.quantized_apply(cache, obs, backend="ref")
        per_layer = actorq.quantized_apply(qp, obs, backend="ref")
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(per_layer))
    cache = actorq.calibrate_actor_cache(qp, obs, backend="ref")
    fused_jit = actorq.quantized_apply(cache, obs, backend="ref")
    per_jit = actorq.quantized_apply(qp, obs, backend="ref")
    np.testing.assert_allclose(np.asarray(fused_jit), np.asarray(per_jit),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(fused_jit, -1)),
                                  np.asarray(jnp.argmax(per_jit, -1)))


def test_calibrated_cache_shifts_with_distribution():
    """Static scales are a property of the calibration batch: a cache
    calibrated elsewhere differs from dynamic on out-of-range data (the
    documented staleness of the static-requant contract)."""
    net = make_network((4,), 3, hidden=(16,))
    params = net.init(jax.random.PRNGKey(3))
    calib = jax.random.normal(jax.random.PRNGKey(4), (32, 4)) * 0.1
    wild = jax.random.normal(jax.random.PRNGKey(5), (32, 4)) * 10.0
    cache = actorq.calibrate_actor_cache(
        actorq.pack_actor_params(params), calib, backend="ref")
    fused = actorq.quantized_apply(cache, wild, backend="ref")
    dyn = actorq.quantized_apply(actorq.pack_actor_params(params), wild,
                                 backend="ref")
    assert np.isfinite(np.asarray(fused)).all()
    assert not np.array_equal(np.asarray(fused), np.asarray(dyn))


def test_calibrate_is_noop_for_conv_caches():
    net = make_network((6, 6, 2), 3, conv_filters=(4,), fc_width=16)
    qp = actorq.pack_actor_params(net.init(jax.random.PRNGKey(6)))
    obs = jax.random.normal(jax.random.PRNGKey(7), (3, 6, 6, 2))
    assert actorq.ACT_QUANT not in actorq.calibrate_actor_cache(qp, obs)


# ---------------------------------------------------------------------------
# W4A8: accuracy + footprint
# ---------------------------------------------------------------------------

def test_int4_mlp_close_to_fake_quant_4bit():
    net = make_network((4,), 2, hidden=(32, 32))
    params = net.init(jax.random.PRNGKey(8))
    obs = jax.random.normal(jax.random.PRNGKey(9), (32, 4)) * 2.0
    sim = net.apply(NullQATContext(),
                    ptq.ptq_simulate(params, QuantConfig.ptq_int(4)), obs)
    got = actorq.quantized_apply(actorq.pack_actor_params(params, bits=4),
                                 obs, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(sim), atol=1e-2)


def test_int4_conv_close_to_fake_quant_4bit():
    net = make_network((6, 6, 2), 3, conv_filters=(8, 8), fc_width=32)
    params = net.init(jax.random.PRNGKey(10))
    obs = jax.random.normal(jax.random.PRNGKey(11), (5, 6, 6, 2))
    sim = net.apply(NullQATContext(),
                    ptq.ptq_simulate(params, QuantConfig.ptq_int(4)), obs)
    got = actorq.quantized_apply(actorq.pack_actor_params(params, bits=4),
                                 obs, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(sim), atol=2e-2)


def test_int4_interpret_per_layer_matches_ref():
    """The packed-weight (in-kernel unpack) GEMM == the oracle."""
    net = make_network((5,), 3, hidden=(24, 24))
    qp = actorq.pack_actor_params(net.init(jax.random.PRNGKey(12)), bits=4)
    obs = jax.random.normal(jax.random.PRNGKey(13), (7, 5))
    np.testing.assert_allclose(
        np.asarray(actorq.quantized_apply(qp, obs, backend="interpret")),
        np.asarray(actorq.quantized_apply(qp, obs, backend="ref")),
        rtol=1e-5, atol=1e-5)


def test_int4_cache_halves_footprint():
    """ISSUE acceptance: the int4 actor cache is <= ~50% of int8
    ``packed_nbytes`` (codes halve exactly; the shared fp32 biases and
    per-layer affine params keep the total a whisker above half)."""
    net = make_network((9,), 25, hidden=(256, 256, 256))
    params = net.init(jax.random.PRNGKey(14))
    qp8 = actorq.pack_actor_params(params, bits=8)
    qp4 = actorq.pack_actor_params(params, bits=4)
    ratio = actorq.packed_nbytes(qp4) / actorq.packed_nbytes(qp8)
    assert ratio <= 0.55, ratio
    # the codes themselves halve exactly (two int4 per byte, odd-K padded)
    for name in qp8:
        c8, c4 = qp8[name]["w"].codes, qp4[name]["w"].codes
        k, n = c8.shape
        assert c4.shape == ((k + 1) // 2, n)


def test_dequantize_restores_packed_shapes():
    net = make_network((6, 6, 2), 3, conv_filters=(4,), fc_width=16)
    params = net.init(jax.random.PRNGKey(15))
    unpacked = ptq.ptq_unpack(actorq.pack_actor_params(params, bits=4))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(unpacked)):
        assert a.shape == b.shape


# ---------------------------------------------------------------------------
# int4 + static requant in training / deployment
# ---------------------------------------------------------------------------

def test_int4_actor_trains_fused_driver():
    res = loops.train("a2c", "cartpole", iterations=4, record_every=2,
                      eval_episodes=2, steps_per_call=2,
                      actor_backend="int4", calib_batch=8)
    assert all(np.isfinite(res.rewards))
    assert res.algo_cfg.actor_backend == "int4"
    assert res.algo_cfg.calib_batch == 8


def test_int4_actor_learner_topology():
    res = loops.train("dqn", "cartpole", topology="actor-learner",
                      num_actors=2, sync_every=2, actor_backend="int4",
                      calib_batch=8, iterations=4, record_every=2,
                      eval_episodes=2, algo_overrides=dict(SMALL_DQN))
    assert all(np.isfinite(res.rewards))
    assert len(res.divergences) > 0


def test_int4_async_topology_with_calibration():
    res = loops.train("dqn", "cartpole", topology="async", num_actors=2,
                      sync_every=4, steps_per_call=2, actor_backend="int4",
                      calib_batch=8, iterations=4, record_every=2,
                      eval_episodes=2, algo_overrides=dict(SMALL_DQN))
    assert all(np.isfinite(res.rewards))
    assert res.actor_lags and all(lag >= 4 for lag in res.actor_lags)


def test_int4_catch_conv_smoke():
    """Pixel env: the conv im2col GEMM consumes byte-packed int4 codes."""
    res = loops.train("dqn", "catch", iterations=2, record_every=2,
                      eval_episodes=2, actor_backend="int4",
                      net_kwargs=dict(conv_filters=(4,), fc_width=16),
                      algo_overrides=dict(SMALL_DQN))
    assert all(np.isfinite(res.rewards))


def test_eval_policy_int4_deployment():
    res = loops.train("ppo", "cartpole", iterations=6, record_every=6,
                      eval_episodes=2)
    key = jax.random.PRNGKey(0)
    r8 = loops.eval_policy(res, QuantConfig.ptq_int(8), key, episodes=2,
                           actor_backend="int8")
    r4 = loops.eval_policy(res, QuantConfig.ptq_int(4), key, episodes=2,
                           actor_backend="int4")
    assert np.isfinite(r8) and np.isfinite(r4)
    # int4 on an 8-bit quant config caps the packed width at 4
    r_cap = loops.eval_policy(res, QuantConfig.ptq_int(8), key, episodes=2,
                              actor_backend="int4")
    assert np.isfinite(r_cap)


def test_train_rejects_unknown_backend():
    with pytest.raises(ValueError):
        loops.train("a2c", "cartpole", iterations=2,
                    actor_backend="int2")


# ---------------------------------------------------------------------------
# slow: int4 convergence (the sub-8-bit viability claim, Lu et al.)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_int4_calibrated_actor_learner_four_device_mesh():
    """shard_map coverage for the calibrated repack: the cache (incl. the
    static ``act_quant`` scales) is carried replicated over the actor
    axis, so the sync-branch calibration all-gathers its obs batch and
    every device derives identical scales."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        from repro.rl import loops
        mesh = jax.make_mesh((4,), ("actor",))
        res = loops.train(
            "dqn", "cartpole", topology="actor-learner", num_actors=4,
            sync_every=2, actor_backend="int4", calib_batch=16,
            iterations=4, record_every=2, eval_episodes=2, mesh=mesh,
            algo_overrides=dict(n_envs=4, rollout_steps=4,
                                updates_per_iter=2, buffer_size=1024,
                                batch_size=32, warmup=16,
                                kernel_backend="ref"))
        assert all(np.isfinite(res.rewards)), res.rewards
        assert len(res.divergences) > 0
        print("INT4_CALIB_MESH_OK", res.rewards)
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "INT4_CALIB_MESH_OK" in out.stdout


@pytest.mark.slow
def test_int4_cartpole_dqn_convergence():
    """W4A8 actors with static requant still learn CartPole — the paper's
    bitwidth-sweep claim carried to the true-integer deployment path."""
    res = loops.train("dqn", "cartpole", iterations=400, record_every=50,
                      eval_episodes=8, steps_per_call=5,
                      actor_backend="int4", calib_batch=32, seed=0)
    # random play ~9.5; require clear learning progress
    assert max(res.rewards) > 100.0, res.rewards
