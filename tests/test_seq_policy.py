"""Sequence-policy stack (ISSUE 9): quantizer pin, op parity, env audit,
windowed ≡ cached equivalence, and the three-topology training contract.

Layer by layer:

* the shared symmetric int8 quantizer (``core.affine.quantize_symmetric``)
  is pinned bitwise to the formula ``models.attention`` used to own
  privately (``_quantize_token``);
* ``ops.int8_cache_attention`` backends: ref ≡ xla bitwise (aliases by
  construction), interpret matches ref allclose, pos broadcasting and
  window masking follow the documented contract;
* the windowed int8 forward (``actorq.quantized_seq_apply``) and the
  incremental KV-cache decode (``actorq.quantized_seq_step``) agree on
  real frame-stacked episodes within the docs/contracts.md tolerance
  (measured max |diff| ~3.3e-3 from activation-quant batching + KV
  re-coding; asserted at 2e-2);
* every env in the ``rl.envs`` registry exposes the uniform ``EnvSpec``
  surface and composes with ``batched_env`` + the rollout scan;
* DQN with the int8 KV-cache transformer actor trains on frame-stacked
  masked Catch across fused / actor-learner / async topologies (smoke in
  tier-1; the convergence thresholds ride the slow marker).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affine
from repro.core.qconfig import QuantConfig
from repro.kernels import ops, ref
from repro.models.seq_policy import make_seq_policy
from repro.rl import actorq, loops
from repro.rl import common as rl_common
from repro.rl.env import batched_env, rollout
from repro.rl.envs import ENVS, make
from repro.rl.networks import make_network

SEQ_NET = {"d_model": 16, "n_layers": 1, "d_ff": 32}


# ---------------------------------------------------------------------------
# shared symmetric quantizer — bitwise pin of the legacy formula
# ---------------------------------------------------------------------------

def test_symmetric_quantizer_matches_legacy():
    """``affine.quantize_symmetric`` is bitwise the formula that
    ``models.attention._quantize_token`` owned before the merge."""
    def legacy(x):
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        scale = jnp.where(amax == 0, 1.0, amax / 127.0)
        codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
        return codes, scale

    key = jax.random.PRNGKey(0)
    for dtype in (jnp.float32, jnp.bfloat16):
        x = (jax.random.normal(key, (4, 1, 3, 16)) * 5.0).astype(dtype)
        x = x.at[0, 0, 1].set(0.0)          # all-zero slice -> scale 1.0
        codes, scale = affine.quantize_symmetric(x)
        want_codes, want_scale = legacy(x)
        np.testing.assert_array_equal(codes, want_codes)
        np.testing.assert_array_equal(scale, want_scale)
        assert codes.dtype == jnp.int8 and scale.dtype == jnp.float32


# ---------------------------------------------------------------------------
# ops.int8_cache_attention — dispatch parity
# ---------------------------------------------------------------------------

def _decode_inputs(key, t=16, g=2, dh=8):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (g, dh), jnp.float32)
    k_codes = jax.random.randint(ks[1], (t, dh), -127, 128).astype(jnp.int8)
    v_codes = jax.random.randint(ks[2], (t, dh), -127, 128).astype(jnp.int8)
    k_scale = jax.random.uniform(ks[3], (t, 1), minval=0.01, maxval=0.1)
    v_scale = jax.random.uniform(ks[4], (t, 1), minval=0.01, maxval=0.1)
    return q, k_codes, k_scale, v_codes, v_scale


@pytest.mark.parametrize("window", [None, 4])
def test_int8_cache_attention_ref_xla_bitwise(window):
    args = _decode_inputs(jax.random.PRNGKey(0))
    pos = jnp.asarray(9, jnp.int32)
    a = ops.int8_cache_attention(*args, pos, window=window, backend="ref")
    b = ops.int8_cache_attention(*args, pos, window=window, backend="xla")
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("window", [None, 4])
@pytest.mark.parametrize("pos", [0, 7, 15])
def test_int8_cache_attention_interpret_matches_ref(window, pos):
    args = _decode_inputs(jax.random.PRNGKey(pos))
    p = jnp.asarray(pos, jnp.int32)
    got = ops.int8_cache_attention(*args, p, window=window,
                                   backend="interpret")
    want = ref.int8_cache_decode_ref(*args, p, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_int8_cache_attention_pos_broadcasting():
    """pos (B,) broadcasts over the (B, KV) batch dims — each element
    matches the corresponding scalar-pos call."""
    b, kv, t, g, dh = 3, 2, 12, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
    kc = jax.random.randint(ks[1], (b, kv, t, dh), -127, 128
                            ).astype(jnp.int8)
    vc = jax.random.randint(ks[2], (b, kv, t, dh), -127, 128
                            ).astype(jnp.int8)
    ksc = jax.random.uniform(ks[3], (b, kv, t, 1), minval=0.01, maxval=0.1)
    vsc = jax.random.uniform(ks[4], (b, kv, t, 1), minval=0.01, maxval=0.1)
    pos = jnp.asarray([2, 5, 11], jnp.int32)
    got = ops.int8_cache_attention(q, kc, ksc, vc, vsc, pos, backend="ref")
    assert got.shape == (b, kv, g, dh)
    for i in range(b):
        for h in range(kv):
            want = ref.int8_cache_decode_ref(
                q[i, h], kc[i, h], ksc[i, h], vc[i, h], vsc[i, h], pos[i])
            np.testing.assert_array_equal(got[i, h], want)


def test_int8_cache_attention_rejects_bad_pos_rank():
    args = _decode_inputs(jax.random.PRNGKey(2))
    pos = jnp.zeros((4,), jnp.int32)   # rank 1 > batch rank 0
    with pytest.raises(ValueError, match="pos rank"):
        ops.int8_cache_attention(*args, pos, backend="ref")


def test_int8_cache_attention_window_masks_old_slots():
    """With window=w only slots (pos-w, pos] contribute: rewriting older
    slots must not change the output."""
    q, kc, ksc, vc, vsc = _decode_inputs(jax.random.PRNGKey(3))
    pos, w = jnp.asarray(10, jnp.int32), 4
    base = ops.int8_cache_attention(q, kc, ksc, vc, vsc, pos, window=w,
                                    backend="ref")
    kc2 = kc.at[:7].set(127)    # slots <= pos - w — outside the window
    vsc2 = vsc.at[:7].set(9.9)
    got = ops.int8_cache_attention(q, kc2, ksc, vc, vsc2, pos, window=w,
                                   backend="ref")
    np.testing.assert_array_equal(base, got)


# ---------------------------------------------------------------------------
# fp32 model layer
# ---------------------------------------------------------------------------

def test_make_seq_policy_rejects_flat_obs():
    with pytest.raises(ValueError, match="obs_shape"):
        make_seq_policy((8,), 3)


def test_seq_apply_shapes_and_masking():
    """Arbitrary leading batch dims; all-invalid rows don't NaN (the
    newest row is always valid by the framestack contract, but the
    forward must stay finite regardless)."""
    net = make_network((6, 12), 3, transformer=SEQ_NET)
    params = net.init(jax.random.PRNGKey(0))
    ctx = rl_common.make_ctx(QuantConfig.none(), {}, jnp.zeros((), jnp.int32))
    obs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 6, 12))
    obs = obs.at[..., -1].set(1.0)
    out = net.apply(ctx, params, obs)
    assert out.shape == (4, 2, 3)
    assert bool(jnp.all(jnp.isfinite(out)))
    # masking: invalid (pre-episode) rows must not affect the output
    obs2 = obs.at[..., 0, :].set(123.0).at[..., 0, -1].set(0.0)
    obs1 = obs.at[..., 0, :].set(-55.0).at[..., 0, -1].set(0.0)
    np.testing.assert_allclose(net.apply(ctx, params, obs2),
                               net.apply(ctx, params, obs1),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# packed int8 forms — dispatch + windowed ≡ cached
# ---------------------------------------------------------------------------

def _seq_net_and_cache(env, seed=0):
    net = make_network(env.spec.obs_shape, env.spec.n_actions,
                       transformer=SEQ_NET)
    params = net.init(jax.random.PRNGKey(seed))
    return net, actorq.pack_actor_params(params, 8)


def test_quantized_apply_dispatches_on_embed():
    """A packed seq-policy tree routes ``quantized_apply`` to the
    windowed transformer mirror (the eval / divergence path)."""
    env = make("catch_seq")
    _, qp = _seq_net_and_cache(env)
    obs = jax.random.normal(jax.random.PRNGKey(2),
                            (5,) + env.spec.obs_shape)
    got = actorq.quantized_apply(qp, obs, backend="xla")
    want = actorq.quantized_seq_apply(qp, obs, backend="xla")
    np.testing.assert_array_equal(got, want)
    assert got.shape == (5, env.spec.n_actions)


def test_calibration_noops_on_seq_params():
    env = make("catch_seq")
    _, qp = _seq_net_and_cache(env)
    obs = jnp.zeros((4,) + env.spec.obs_shape)
    assert actorq.calibrate_actor_cache(qp, obs) is qp


def test_seq_cache_nbytes():
    env = make("catch_seq")
    net, _ = _seq_net_and_cache(env)
    size = env.spec.max_steps + 1
    ps = actorq.seq_cache_zeros(net.seq_cfg, 4, size)
    d = net.seq_cfg.d_model
    per_layer = 4 * size * d * 1 * 2 + 4 * size * 1 * 4 * 2  # codes + scales
    assert actorq.seq_cache_nbytes(ps) == \
        net.seq_cfg.n_layers * per_layer + 4 * 4            # + count


def test_windowed_matches_cached_on_episode():
    """The deployment hot path (incremental int8 KV-cache decode) agrees
    with the stateless windowed form over a real frame-stacked episode.

    The two differ only by activation-quantization batching and the int8
    re-coding of cached K/V — measured max |diff| ~3.3e-3 on these q
    scales (see docs/contracts.md "Attention parity"); asserted with
    margin, plus exact argmax agreement (what the behaviour policy uses).
    """
    env = make("catch_seq")
    net, qp = _seq_net_and_cache(env)
    cfg = net.seq_cfg
    state, obs = env.reset(jax.random.PRNGKey(3))
    pstate = actorq.seq_cache_zeros(cfg, 1, env.spec.max_steps + 1)
    for t in range(env.spec.max_steps):
        q_w = actorq.quantized_seq_apply(qp, obs[None], backend="xla")
        q_c, pstate = actorq.quantized_seq_step(
            qp, obs[None, -1, :], pstate, context=cfg.context,
            backend="xla")
        np.testing.assert_allclose(q_c, q_w, atol=2e-2)
        assert int(jnp.argmax(q_c)) == int(jnp.argmax(q_w))
        key = jax.random.PRNGKey(t)
        action = jax.random.randint(key, (), 0, env.spec.n_actions)
        state, obs, _, done = env.step(state, action, key)
        if bool(done):
            break
    assert int(pstate["count"][0]) >= 2   # actually stepped the cache


# ---------------------------------------------------------------------------
# env registry — uniform EnvSpec surface + rollout composability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ENVS))
def test_env_registry_uniform_surface(name):
    env = make(name)
    spec = env.spec
    assert isinstance(spec.name, str) and spec.name
    assert spec.max_steps > 0
    assert spec.continuous == (spec.n_actions == 0)   # exactly one family
    state, obs = jax.jit(env.reset)(jax.random.PRNGKey(0))
    assert obs.shape == tuple(spec.obs_shape)
    assert obs.dtype == jnp.float32
    if spec.continuous:
        action = jnp.zeros((spec.action_dim,), jnp.float32)
    else:
        action = jnp.zeros((), jnp.int32)
    state, obs2, reward, done = jax.jit(env.step)(
        state, action, jax.random.PRNGKey(1))
    assert obs2.shape == tuple(spec.obs_shape)
    assert reward.shape == () and done.shape == ()


@pytest.mark.parametrize("name", ["catch_masked", "airnav_flicker",
                                  "catch_seq", "airnav_seq"])
def test_wrapped_envs_compose_with_rollout(name):
    """Wrappers ride ``batched_env`` + the auto-reset rollout scan like
    any env (the ``steps_per_call`` fusion scans this very rollout)."""
    env = make(name)
    benv = batched_env(env, 3)
    state, obs = benv.reset(jax.random.PRNGKey(0))

    def policy(_params, obs, key):
        a = jax.random.randint(key, (obs.shape[0],), 0, env.spec.n_actions)
        return a, jnp.zeros((obs.shape[0], 1))

    state, obs, traj = jax.jit(
        lambda s, o, k: rollout(benv, policy, None, s, o, k, 5)
    )(state, obs, jax.random.PRNGKey(1))
    assert traj.obs.shape == (5, 3) + tuple(env.spec.obs_shape)
    assert traj.reward.shape == (5, 3)


def test_masked_catch_hides_ball_below_visible_rows():
    env = make("catch_masked", visible_rows=2)
    state, obs = env.reset(jax.random.PRNGKey(0))
    done = jnp.zeros((), bool)
    seen = [obs]
    while not bool(done):
        state, obs, _, done = env.step(state, jnp.ones((), jnp.int32),
                                       jax.random.PRNGKey(0))
        seen.append(obs)
    for o in seen:
        assert not bool(jnp.any(o[2:] == 1.0))   # ball never visible below
        assert bool(jnp.any(o == 0.5))           # paddle always visible


def test_framestack_obs_contract():
    """Rows are [obs..., t/max_steps, valid], oldest first; pre-episode
    rows all-zero; the stack shifts by one row per step."""
    env = make("catch_seq", context=6)
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (6, 27)                  # 5*5 board + time + valid
    np.testing.assert_array_equal(obs[:-1], 0.0)
    assert float(obs[-1, -1]) == 1.0 and float(obs[-1, -2]) == 0.0
    state, obs2, _, _ = env.step(state, jnp.ones((), jnp.int32),
                                 jax.random.PRNGKey(1))
    np.testing.assert_array_equal(obs2[-2], obs[-1])   # shifted up
    assert float(obs2[-1, -2]) == pytest.approx(1.0 / env.spec.max_steps)


# ---------------------------------------------------------------------------
# training topologies
# ---------------------------------------------------------------------------

def _train_seq(topo, iterations, net=SEQ_NET, **overrides):
    algo = dict(n_envs=8, rollout_steps=8, updates_per_iter=4,
                buffer_size=4096, batch_size=32, warmup=64,
                eps_decay_updates=600, target_update_every=50, lr=1e-3)
    algo.update(overrides)
    multi = topo != "fused"
    return loops.train(
        "dqn", "catch_seq", iterations=iterations, seed=0,
        actor_backend="int8", topology=topo,
        num_actors=2 if multi else 1, sync_every=2 if multi else 1,
        net_kwargs={"transformer": dict(net)},
        algo_overrides=algo, record_every=max(iterations // 6, 1),
        eval_episodes=32)


def test_train_smoke_fused_seq_int8():
    r = _train_seq("fused", 3, n_envs=2, rollout_steps=2,
                   updates_per_iter=1, buffer_size=64, batch_size=8,
                   warmup=8)
    assert all(np.isfinite(x) for x in r.rewards)


@pytest.mark.slow
@pytest.mark.parametrize("topo", ["fused", "actor-learner", "async"])
def test_seq_policy_convergence(topo):
    """The ISSUE 9 acceptance bar: the int8-KV-cache transformer DQN
    actor clears the reward threshold on frame-stacked masked Catch in
    every topology (probed sizing reaches eval reward 1.0 by ~iter 250;
    random play sits near 0)."""
    r = _train_seq(topo, 300,
                   net={"d_model": 32, "n_layers": 2, "d_ff": 64})
    assert r.rewards[-1] >= 0.5, r.rewards
