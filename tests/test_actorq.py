"""ActorQ tests: true int8 actor inference + the scan-fused training driver.

Acceptance contract (ISSUE 1):
* the int8 path (``backend="ref"`` on CPU) agrees with the fake-quant fp32
  actor within atol=1e-2 on MLP and CNN policies,
* the scan-fused driver is numerically equivalent to the per-step driver
  (same seed -> same final params, bitwise on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ptq
from repro.core.fake_quant import NullQATContext
from repro.core.qconfig import QuantConfig
from repro.rl import actorq, loops
from repro.rl.envs import make as make_env
from repro.rl.networks import make_network


# ---------------------------------------------------------------------------
# int8 actor vs fake-quant fp32 actor
# ---------------------------------------------------------------------------

def _fake_quant_outputs(net, params, obs):
    """The fp32 simulation the repo used before ActorQ (same quantizer)."""
    sim = ptq.ptq_simulate(params, QuantConfig.ptq_int(8))
    return net.apply(NullQATContext(), sim, obs)


def test_int8_mlp_matches_fake_quant_actor():
    net = make_network((4,), 2)
    params = net.init(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (32, 4)) * 2.0
    want = _fake_quant_outputs(net, params, obs)
    got = actorq.quantized_apply(actorq.pack_actor_params(params), obs,
                                 backend="ref")
    np.testing.assert_allclose(got, want, atol=1e-2)


def test_int8_cnn_matches_fake_quant_actor():
    net = make_network((6, 6, 2), 3, conv_filters=(8, 8), fc_width=32)
    params = net.init(jax.random.PRNGKey(2))
    obs = jax.random.normal(jax.random.PRNGKey(3), (5, 6, 6, 2))
    want = _fake_quant_outputs(net, params, obs)
    got = actorq.quantized_apply(actorq.pack_actor_params(params), obs,
                                 backend="ref")
    np.testing.assert_allclose(got, want, atol=1e-2)


def test_int8_interpret_kernel_matches_ref_oracle():
    """The Pallas kernel path (interpret on CPU) == the pure-jnp oracle."""
    net = make_network((4,), 2)
    params = net.init(jax.random.PRNGKey(4))
    obs = jax.random.normal(jax.random.PRNGKey(5), (16, 4))
    qp = actorq.pack_actor_params(params)
    ref = actorq.quantized_apply(qp, obs, backend="ref")
    interp = actorq.quantized_apply(qp, obs, backend="interpret")
    np.testing.assert_allclose(interp, ref, rtol=1e-5, atol=1e-5)


def test_packed_actor_is_4x_smaller():
    net = make_network((9,), 25, hidden=(256, 256, 256))
    params = net.init(jax.random.PRNGKey(6))
    qp = actorq.pack_actor_params(params)
    assert actorq.packed_nbytes(qp) < ptq.tree_nbytes(params) / 3.0


def test_make_act_fn_heads():
    # discrete: argmax over action logits, value head sliced off
    env = make_env("cartpole")
    net = make_network(env.spec.obs_shape, env.spec.n_actions + 1)
    qp = actorq.pack_actor_params(net.init(jax.random.PRNGKey(7)))
    act = actorq.make_act_fn(env.spec, backend="ref")
    obs = jax.random.normal(jax.random.PRNGKey(8), (10, 4))
    a = act(qp, obs)
    assert a.dtype == jnp.int32 and a.shape == (10,)
    assert int(a.max()) < env.spec.n_actions
    # continuous: tanh * action_scale
    penv = make_env("pendulum")
    pnet = make_network(penv.spec.obs_shape, penv.spec.action_dim)
    pqp = actorq.pack_actor_params(pnet.init(jax.random.PRNGKey(9)))
    pact = actorq.make_act_fn(penv.spec, backend="ref")
    pa = pact(pqp, jax.random.normal(jax.random.PRNGKey(10), (10, 3)))
    assert pa.shape == (10, 1)
    assert float(jnp.abs(pa).max()) <= penv.spec.action_scale + 1e-6


def test_validate_actor_backend():
    # "int4" joined the backend matrix in PR 5; junk strings still fail in
    # the one shared validator every entry point routes through
    assert actorq.validate_actor_backend("int8") == "int8"
    assert actorq.validate_actor_backend("int4") == "int4"
    for bad in ("int2", "INT8", "", "fp16"):
        with pytest.raises(ValueError):
            actorq.validate_actor_backend(bad)
    assert actorq.backend_bits("int8") == 8
    assert actorq.backend_bits("int4") == 4
    with pytest.raises(ValueError):
        actorq.backend_bits("fp32")       # quantized backends only
    assert actorq.is_quantized("int4") and not actorq.is_quantized("fp32")


def test_pack_actor_params_rejects_bad_bits():
    """ValueError (not assert — asserts vanish under ``python -O``)."""
    net = make_network((4,), 2)
    params = net.init(jax.random.PRNGKey(0))
    for bad in (9, 0, -1, 16):
        with pytest.raises(ValueError):
            actorq.pack_actor_params(params, bits=bad)


# ---------------------------------------------------------------------------
# scan-fused driver
# ---------------------------------------------------------------------------

def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


@pytest.mark.parametrize("algo,env", [("a2c", "cartpole"),
                                      ("dqn", "cartpole")])
def test_scan_fused_driver_bitwise_equivalent(algo, env):
    kw = dict(iterations=8, record_every=4, eval_episodes=2, seed=7)
    per_step = loops.train(algo, env, steps_per_call=1, **kw)
    fused = loops.train(algo, env, steps_per_call=4, **kw)
    for a, b in zip(_leaves(per_step.state.params),
                    _leaves(fused.state.params)):
        np.testing.assert_array_equal(a, b)
    assert per_step.rewards == fused.rewards        # same eval PRNG chain
    assert per_step.action_variances == fused.action_variances


def test_scan_fused_chunks_clip_to_record_boundaries():
    # steps_per_call larger than record_every: chunks clip, records match
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=1)
    a = loops.train("a2c", "cartpole", steps_per_call=1, **kw)
    b = loops.train("a2c", "cartpole", steps_per_call=100, **kw)
    assert a.rewards == b.rewards
    for x, y in zip(_leaves(a.state.params), _leaves(b.state.params)):
        np.testing.assert_array_equal(x, y)


def test_make_scan_iteration_stacks_metrics():
    from repro.rl import a2c
    env = make_env("cartpole")
    cfg = a2c.A2CConfig(n_envs=4, n_steps=4)
    net = make_network(env.spec.obs_shape, env.spec.n_actions + 1)
    state = a2c.init(jax.random.PRNGKey(0), env, net, cfg)
    iteration, _, benv = a2c.make_iteration(env, net, cfg)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    chunk = loops.make_scan_iteration(iteration, 3)
    state, env_state, obs, key, metrics = chunk(state, env_state, obs,
                                                jax.random.PRNGKey(2))
    assert metrics["loss"].shape == (3,)
    assert bool(jnp.all(jnp.isfinite(metrics["loss"])))


# ---------------------------------------------------------------------------
# int8 actor in training + deployment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["a2c", "dqn"])
def test_int8_actor_rollouts_train_finite(algo):
    res = loops.train(algo, "cartpole", iterations=6, record_every=3,
                      eval_episodes=2, steps_per_call=3,
                      actor_backend="int8")
    assert all(np.isfinite(res.rewards))
    assert res.algo_cfg.actor_backend == "int8"


def test_eval_policy_int8_deployment():
    res = loops.train("ppo", "cartpole", iterations=10, record_every=10,
                      eval_episodes=2)
    key = jax.random.PRNGKey(0)
    r_sim = loops.eval_policy(res, QuantConfig.ptq_int(8), key, episodes=4)
    r_int8 = loops.eval_policy(res, QuantConfig.ptq_int(8), key, episodes=4,
                               actor_backend="int8")
    assert np.isfinite(r_sim) and np.isfinite(r_int8)


def test_eval_policy_int8_ddpg_actor_only():
    """DDPG deployment packs only the actor — the critic stays in extras."""
    res = loops.train("ddpg", "pendulum", iterations=4, record_every=4,
                      eval_episodes=2)
    qp = actorq.pack_actor_params(res.state.params)
    # packed tree mirrors the actor MLP spec exactly (no critic keys)
    assert set(qp) == set(res.state.params)
    r = loops.eval_policy(res, QuantConfig.ptq_int(8), jax.random.PRNGKey(1),
                          episodes=2, actor_backend="int8")
    assert np.isfinite(r)


def test_conv_quant_delay_respected():
    """conv2d honours ctx.enabled (the old hasattr guard silently skipped
    the quant_delay gate for contexts without the attribute)."""
    from repro.core import fake_quant
    cfg = QuantConfig.qat(8, quant_delay=10)
    net = make_network((6, 6, 2), 3, conv_filters=(4,), fc_width=16)
    params = net.init(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 6, 2))
    before = net.apply(fake_quant.make_context(cfg, {}, step=0), params, obs)
    plain = net.apply(NullQATContext(), params, obs)
    np.testing.assert_allclose(before, plain, rtol=1e-6)   # delay: identity
    after = net.apply(fake_quant.make_context(cfg, {}, step=10), params, obs)
    assert not np.allclose(after, plain)                   # quant active
