"""Checkpoint subsystem tests (ISSUE 8).

* the seed's correctness sweep: writeable loaded leaves (donation-safe),
  ``ValueError`` validation with per-leaf shape/dtype detail (no bare
  asserts), tolerant ``latest_step`` parsing, orphan tmp sweep,
* round-trips parametrized over the containers training actually
  checkpoints: fp32 params, packed int8/int4 actor caches, PER sum-tree
  state, optimizer state,
* ``CheckpointManager``: manifest contents, retention GC, validated
  restore, re-save of a step,
* ``AsyncCheckpointer``: FIFO commits, ``wait``/``last_committed_step``,
  writer-error propagation,
* crash injection: a save killed between staging and the rename leaves
  the directory loadable at the previous committed step, and the next
  successful save sweeps the debris.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.checkpoint import manager as mgr_lib
from repro.rl import actorq, dqn
from repro.rl import buffer as rb
from repro.rl.envs import make as make_env
from repro.rl.networks import make_network


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_tree_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# seed bugfixes
# ---------------------------------------------------------------------------

def test_loaded_leaves_are_writeable_and_donatable(tmp_path):
    """Regression: ``np.frombuffer`` views were read-only — resumed
    leaves must survive in-place mutation and buffer donation."""
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    path = ck.save_checkpoint(str(tmp_path / "t.msgpack"), tree)
    loaded = ck.load_checkpoint(path, tree)
    loaded["w"][0, 0] = 42.0                  # ValueError before the fix
    assert loaded["w"].flags.writeable

    bump = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    out = bump(jnp.asarray(loaded["w"]))
    assert float(out[0, 0]) == 43.0


def test_load_rejects_wrong_shape_with_detail(tmp_path):
    """Same leaf count, wrong shape: must be a loud ``ValueError`` (the
    seed's count-only assert silently reshaped garbage, and vanished
    under ``python -O``)."""
    tree = {"a": np.zeros((2, 3), np.float32), "b": np.zeros(4, np.int32)}
    path = ck.save_checkpoint(str(tmp_path / "t.msgpack"), tree)
    bad = {"a": np.zeros((3, 2), np.float32), "b": np.zeros(4, np.int32)}
    with pytest.raises(ValueError, match=r"\['a'\].*\(2, 3\).*\(3, 2\)"):
        ck.load_checkpoint(path, bad)


def test_load_rejects_wrong_dtype_and_count(tmp_path):
    tree = {"a": np.zeros((2,), np.float32)}
    path = ck.save_checkpoint(str(tmp_path / "t.msgpack"), tree)
    with pytest.raises(ValueError, match="<i4"):
        ck.load_checkpoint(path, {"a": np.zeros((2,), np.int32)})
    with pytest.raises(ValueError, match="leaf count"):
        ck.load_checkpoint(path, {"a": np.zeros((2,), np.float32),
                                  "b": np.zeros((2,), np.float32)})


def test_latest_step_tolerates_stray_files(tmp_path):
    """The seed raised ``ValueError`` on any non-step ``ckpt_*`` entry."""
    ck.save_checkpoint(str(tmp_path), {"x": np.zeros(2)}, step=3)
    (tmp_path / "ckpt_notastep.msgpack").write_bytes(b"junk")
    (tmp_path / "ckpt_README").write_text("hands off")
    (tmp_path / "other.txt").write_text("")
    os.makedirs(tmp_path / "ckpt_00000009")   # dir without manifest: not
    assert ck.latest_step(str(tmp_path)) == 3  # a committed step
    assert ck.latest_step(str(tmp_path / "missing")) is None


def test_sweep_orphans_removes_only_debris(tmp_path):
    ck.save_checkpoint(str(tmp_path), {"x": np.zeros(2)}, step=1)
    (tmp_path / "ckpt-tmp-dead1").write_bytes(b"partial")
    os.makedirs(tmp_path / "ckpt_00000002.tmp-beef")
    (tmp_path / "ckpt_00000002.tmp-beef" / "leaves.msgpack").write_bytes(b"")
    (tmp_path / "keepme.txt").write_text("")
    removed = ck.sweep_orphans(str(tmp_path))
    assert sorted(removed) == ["ckpt-tmp-dead1", "ckpt_00000002.tmp-beef"]
    assert (tmp_path / "keepme.txt").exists()
    assert ck.latest_step(str(tmp_path)) == 1


def test_stepped_save_sweeps_previous_orphans(tmp_path):
    (tmp_path / "ckpt-tmp-leftover").write_bytes(b"x")
    ck.save_checkpoint(str(tmp_path), {"x": np.zeros(2)}, step=2)
    names = os.listdir(tmp_path)
    assert "ckpt-tmp-leftover" not in names
    assert "ckpt_00000002.msgpack" in names


# ---------------------------------------------------------------------------
# container round-trips (the quantized-container claim, now tested)
# ---------------------------------------------------------------------------

def _fp32_params():
    net = make_network((5,), 3, hidden=(8,))
    return net.init(jax.random.PRNGKey(0))


def _packed_cache(backend):
    return actorq.make_actor_cache(_fp32_params(), backend)


def _per_state():
    state = rb.per_init(16, (4,))
    batch = rb.Transition(
        obs=jnp.ones((4, 4)), action=jnp.arange(4, dtype=jnp.int32),
        reward=jnp.arange(4.0), done=jnp.zeros(4),
        next_obs=jnp.full((4, 4), 2.0))
    state = rb.per_add(state, batch)
    return rb.per_update_priorities(state, jnp.arange(4),
                                    jnp.arange(4.0) + 0.5, 0.6)


def _opt_state():
    env = make_env("catch")
    net = make_network(env.spec.obs_shape, env.spec.n_actions, hidden=(8,))
    cfg = dqn.DQNConfig(n_envs=2, rollout_steps=2, buffer_size=32,
                        batch_size=4, warmup=4)
    return dqn.init(jax.random.PRNGKey(1), env, net, cfg).opt


@pytest.mark.parametrize("build", [
    _fp32_params,
    lambda: _packed_cache("int8"),
    lambda: _packed_cache("int4"),
    _per_state,
    _opt_state,
], ids=["fp32_params", "int8_cache", "int4_cache", "per_sum_tree",
        "optimizer_state"])
def test_container_roundtrip(tmp_path, build):
    tree = build()
    path = ck.save_checkpoint(str(tmp_path / "c.msgpack"), tree)
    _assert_tree_equal(ck.load_checkpoint(path, tree), tree)

    mgr = mgr_lib.CheckpointManager(str(tmp_path / "mgr"))
    mgr.save(4, tree, extra={"note": "hi"})
    restored, extra = mgr.restore(4, tree)
    _assert_tree_equal(restored, tree)
    assert extra == {"note": "hi"}


def test_replay_export_import_roundtrip():
    state = _per_state()
    snap = rb.export_state(state)
    back = rb.import_state(state, snap)
    _assert_tree_equal(back, state)
    # capacity mismatch is loud, with the offending leaf named
    with pytest.raises(ValueError, match="tree"):
        rb.import_state(rb.per_init(32, (4,)), snap)
    # structural mismatch too
    with pytest.raises(ValueError, match="structure"):
        rb.import_state(state.replay, snap)


# ---------------------------------------------------------------------------
# CheckpointManager
# ---------------------------------------------------------------------------

def test_manager_manifest_contents(tmp_path):
    mgr = mgr_lib.CheckpointManager(str(tmp_path))
    tree = {"w": np.zeros((2, 3), np.float32),
            "n": np.zeros((), np.int32)}
    mgr.save(7, tree, extra={"iteration": 7})
    m = json.loads((tmp_path / "ckpt_00000007" / "manifest.json"
                    ).read_text())
    assert m["format"] == mgr_lib.FORMAT
    assert m["step"] == 7 and m["leaf_count"] == 2
    assert {"shape": [2, 3], "dtype": "<f4"} in m["leaves"]
    assert m["extra"] == {"iteration": 7}


def test_manager_validates_restore_template(tmp_path):
    mgr = mgr_lib.CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": np.zeros((4,), np.float32)})
    with pytest.raises(ValueError, match=r"\['w'\]"):
        mgr.restore(1, {"w": np.zeros((5,), np.float32)})


def test_manager_retention_gc_and_resave(tmp_path):
    mgr = mgr_lib.CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": np.full(3, float(s))})
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    # re-saving an existing step replaces it atomically
    mgr.save(4, {"x": np.full(3, 99.0)})
    restored, _ = mgr.restore(4, {"x": np.zeros(3)})
    np.testing.assert_array_equal(restored["x"], np.full(3, 99.0))


# ---------------------------------------------------------------------------
# AsyncCheckpointer
# ---------------------------------------------------------------------------

def test_async_checkpointer_commits_in_order(tmp_path):
    with mgr_lib.AsyncCheckpointer(str(tmp_path), keep=0) as ac:
        for s in (2, 4, 6):
            ac.save_async(s, {"x": np.full(2, float(s))},
                          extra={"iteration": s})
        assert ac.wait() == 6
        assert ac.last_committed_step() == 6
        assert ac.manager.steps() == [2, 4, 6]
        tree, extra = ac.restore(4, {"x": np.zeros(2)})
    np.testing.assert_array_equal(tree["x"], np.full(2, 4.0))
    assert extra["iteration"] == 4


def test_async_checkpointer_snapshot_isolation(tmp_path):
    """The host copy happens at ``save_async`` time: later caller-side
    mutation (the donated-buffer regime) must not leak into the commit,
    and a live ``extra`` list may keep growing."""
    x = np.zeros(3, np.float32)
    metrics = [1.0]
    with mgr_lib.AsyncCheckpointer(str(tmp_path)) as ac:
        ac.save_async(1, {"x": x}, extra={"rewards": metrics})
        x[:] = -1.0                    # simulate donation reuse
        metrics.append(2.0)
        ac.wait()
        tree, extra = ac.restore(1, {"x": np.zeros(3, np.float32)})
    np.testing.assert_array_equal(tree["x"], np.zeros(3))
    assert extra["rewards"] == [1.0]


def test_async_checkpointer_propagates_writer_errors(tmp_path):
    ac = mgr_lib.AsyncCheckpointer(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk on fire")

    ac.manager.commit_hosted = boom
    ac.save_async(1, {"x": np.zeros(2)})
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        ac.wait()
    ac.close()


# ---------------------------------------------------------------------------
# crash injection
# ---------------------------------------------------------------------------

def test_crash_mid_save_keeps_previous_step(tmp_path, monkeypatch):
    """Kill the writer between staging and the rename: the directory must
    stay loadable at the previous committed step, and the next successful
    save must sweep the debris."""
    mgr = mgr_lib.CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": np.full(2, 1.0)}, extra={"iteration": 1})

    real_replace = os.replace

    def killed(src, dst):
        raise RuntimeError("SIGKILL'd mid-commit")

    monkeypatch.setattr(os, "replace", killed)
    with pytest.raises(RuntimeError, match="mid-commit"):
        mgr.save(2, {"x": np.full(2, 2.0)})
    monkeypatch.setattr(os, "replace", real_replace)

    # debris from the dead save is present, but invisible to readers
    debris = [n for n in os.listdir(tmp_path) if ".tmp-" in n]
    assert debris
    assert mgr.latest_step() == 1
    assert ck.latest_step(str(tmp_path)) == 1
    tree, extra = mgr.restore(1, {"x": np.zeros(2)})
    np.testing.assert_array_equal(tree["x"], np.full(2, 1.0))
    assert extra["iteration"] == 1

    # a fresh writer on the same dir (the restarted process) sweeps on
    # construction; its next save leaves no tmp entries behind
    with mgr_lib.AsyncCheckpointer(str(tmp_path)) as ac:
        ac.save_async(2, {"x": np.full(2, 2.0)})
        ac.wait()
    assert not [n for n in os.listdir(tmp_path) if ".tmp-" in n]
    assert mgr.latest_step() == 2
