"""Infrastructure tests: optimizer, checkpoint, data, schedules, HLO
analysis, launch-step plumbing."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # guarded hypothesis import

from repro.optim import (AdamConfig, adam_init, adam_update,
                         block_quantize, block_dequantize,
                         clip_by_global_norm, schedule, sgd)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray([[1.0, -1.0]])}


@pytest.mark.parametrize("eightbit", [False, True])
def test_adam_minimizes_quadratic(eightbit):
    params = _quadratic_params()
    cfg = AdamConfig(lr=0.1, eightbit=eightbit, grad_clip=None)
    state = adam_init(params, cfg)

    def loss(p):
        return sum(jnp.sum(jnp.square(x))
                   for x in jax.tree_util.tree_leaves(p))

    @jax.jit
    def step(params, state):
        grads = jax.grad(loss)(params)
        return adam_update(grads, state, params, cfg)[:2]

    for _ in range(150):
        params, state = step(params, state)
    assert float(loss(params)) < 1e-2


def test_block_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 3
    q = block_quantize(x)
    assert q.codes.dtype == jnp.int8 and q.codes.shape == x.shape
    err = jnp.abs(block_dequantize(q) - x)
    per_block_max = jnp.max(jnp.abs(x.reshape(8, 2, 256)), axis=-1)
    # symmetric int8: error <= scale/2 = amax/254
    assert float(err.max()) <= float(per_block_max.max()) / 127.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 7), st.integers(1, 600))
def test_prop_block_quantize_shapes(rows, cols):
    x = jax.random.normal(jax.random.PRNGKey(rows * cols), (rows, cols))
    q = block_quantize(x)
    out = block_dequantize(q)
    assert out.shape == x.shape
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(out - x).max()) <= amax / 127.0 + 1e-6


def test_8bit_adam_state_is_4x_smaller():
    params = {"w": jnp.zeros((1024, 256))}
    fp = adam_init(params, AdamConfig())
    q8 = adam_init(params, AdamConfig(eightbit=True))

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree))
    assert nbytes(fp.m) / nbytes(q8.m) > 3.0


def test_grad_clip():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(norm, 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_sgd_momentum_descends():
    params = _quadratic_params()
    cfg = sgd.SGDConfig(lr=0.05, momentum=0.9)
    state = sgd.sgd_init(params, cfg)
    def loss(p):
        return sum(jnp.sum(jnp.square(x))
                   for x in jax.tree_util.tree_leaves(p))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state = sgd.sgd_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_schedules():
    fn = schedule.warmup_cosine(10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(fn(jnp.asarray(100))) < 0.2
    eps = schedule.linear_epsilon(1.0, 0.1, 100)
    np.testing.assert_allclose(float(eps(jnp.asarray(50))), 0.55)


# ---------------------------------------------------------------------------
# Test-suite hygiene: collection must not depend on optional extras
# ---------------------------------------------------------------------------

def test_no_direct_hypothesis_imports_in_tests():
    """Tier-1 runs in minimal containers; every property test must import
    hypothesis through ``tests/hypcompat.py`` so collection stays clean
    when the package is absent (CI also enforces ``pytest --co -q``)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    offenders = []
    for name in sorted(os.listdir(tests_dir)):
        if not name.endswith(".py") or name == "hypcompat.py":
            continue
        with open(os.path.join(tests_dir, name)) as f:
            for lineno, line in enumerate(f, 1):
                stripped = line.strip()
                if (stripped.startswith("import hypothesis")
                        or stripped.startswith("from hypothesis")):
                    offenders.append(f"{name}:{lineno}: {stripped}")
    assert not offenders, (
        "direct hypothesis imports found (route them through hypcompat):\n"
        + "\n".join(offenders))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ck
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.asarray(7, jnp.int32)}
    path = ck.save_checkpoint(str(tmp_path), tree, step=7)
    assert ck.latest_step(str(tmp_path)) == 7
    loaded = ck.load_checkpoint(path, tree)
    np.testing.assert_allclose(loaded["params"]["w"], tree["params"]["w"])
    assert int(loaded["step"]) == 7


def test_checkpoint_quantized_params(tmp_path):
    from repro import checkpoint as ck
    from repro.core import ptq
    from repro.core.qconfig import QuantConfig
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 16))}
    packed = ptq.ptq_pack(params, QuantConfig.ptq_int(8))
    path = ck.save_checkpoint(str(tmp_path / "q.msgpack"), packed)
    loaded = ck.load_checkpoint(path, packed)
    np.testing.assert_allclose(ptq.ptq_unpack(loaded)["w"],
                               ptq.ptq_unpack(packed)["w"])
    # on-disk artifact carries the ~4x reduction
    assert os.path.getsize(path) < 16 * 16 * 4 * 2


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_dataset_learnable_structure():
    from repro.data import SyntheticLMDataset
    ds = SyntheticLMDataset(vocab=64, seq_len=32, batch=4, seed=0)
    b1 = next(ds.batches())
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # markov structure: every successor must be in the transition table
    succ = ds._succ
    ok = [b1["labels"][i, t] in succ[b1["tokens"][i, t]]
          for i in range(4) for t in range(31)]
    assert all(ok)


def test_sharded_batcher_no_mesh():
    from repro.data import ShardedBatcher
    sb = ShardedBatcher(None)
    out = sb.put({"tokens": np.zeros((4, 8), np.int32)})
    assert out["tokens"].shape == (4, 8)


# ---------------------------------------------------------------------------
# HLO analysis unit tests
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]{0}) parameter(0)
  %c = s32[] constant(6)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]{0}) parameter(0)
  %ag = f32[4]{0} all-gather(%x), replica_groups={}, dimensions={0}
  ROOT %t = (s32[], f32[4]{0}) tuple(%i, %ag)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %ar = f32[2,8]{1,0} all-reduce(%a), to_apply=%add
  %w = (s32[], f32[4]{0}) while(%init), condition=%cond, body=%body
  ROOT %r = f32[4]{0} get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_trip_weighting():
    from repro.launch import hlo_analysis as H
    stats = H.collective_stats(HLO_SAMPLE)
    # all-reduce f32[2,8] once = 64B; all-gather f32[4] x6 trips = 96B
    assert stats["all-reduce"] == 64.0
    assert stats["all-gather"] == 6 * 16.0
    assert stats["total"] == 64.0 + 96.0


def test_hlo_memory_summary():
    from repro.launch.hlo_analysis import summarize_memory

    class FakeMem:
        argument_size_in_bytes = 100.0
        output_size_in_bytes = 50.0
        temp_size_in_bytes = 200.0
        generated_code_size_in_bytes = 1.0
        alias_size_in_bytes = 50.0
    out = summarize_memory(FakeMem())
    assert out["total_nonalias_bytes"] == 300.0


# ---------------------------------------------------------------------------
# Launch steps (local, no production mesh)
# ---------------------------------------------------------------------------

def test_input_specs_all_arch_shape_pairs():
    from repro.configs import base as cfgs
    from repro.launch import steps
    for arch in cfgs.names():
        cfg = cfgs.get(arch)
        for shape in cfgs.INPUT_SHAPES.values():
            cfg2, variant = steps.resolve_arch_for_shape(cfg, shape)
            specs = steps.input_specs(cfg2, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert specs["tokens"].shape[1] == 1
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
            if shape.name == "long_500k" and not cfg.supports_long_500k:
                assert variant == "swa-variant"
                assert cfg2.long_context_window is not None


def test_analytic_flops_sane():
    from repro.configs import base as cfgs
    from repro.launch import analytic
    cfg = cfgs.get("stablelm-12b")
    shape = cfgs.INPUT_SHAPES["train_4k"]
    got = analytic.step_flops(cfg, shape)
    model = analytic.model_flops(cfg, shape)
    # train step ~ 2x the 6ND number (remat + attention) — same decade
    assert 0.8 * model < got < 4.0 * model
    # decode flops are tiny vs train
    dec = analytic.step_flops(cfg, cfgs.INPUT_SHAPES["decode_32k"])
    assert dec < got / 1000


def test_make_host_mesh_and_train_step_local():
    """One real train step through the launcher plumbing on CPU."""
    from repro.configs import base as cfgs
    from repro.launch import steps as steps_lib
    from repro.models import transformer
    from repro.optim import adam as adam_lib

    cfg = cfgs.get_reduced("h2o-danube-1.8b")
    train_step, adam_cfg = steps_lib.make_train_step(cfg)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = adam_lib.adam_init(params, adam_cfg)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    params2, opt2, qat, metrics = jax.jit(train_step)(params, opt, batch, {})
    assert jnp.isfinite(metrics["loss"])
    assert int(opt2.step) == 1


@pytest.mark.slow
def test_dryrun_subprocess_end_to_end():
    """The real dry-run entry point: 512 fake devices, lower+compile."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--out", "/tmp/test_dryrun"],
        capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "All dry-runs compiled successfully" in out.stdout
