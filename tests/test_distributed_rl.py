"""shard_map data-parallel RL: single-device degenerate path inline; the
8-device path runs in a subprocess (device count is locked at jax init)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MESH_CTX = textwrap.dedent("""
    import contextlib
    def mesh_ctx(mesh):
        # newer jax requires an ambient mesh; older versions have no
        # context manager and shard_map carries the mesh explicitly
        for name in ("set_mesh", "use_mesh"):
            if hasattr(jax.sharding, name):
                return getattr(jax.sharding, name)(mesh)
        return contextlib.nullcontext()
""")

# single source for the shim: the in-process tests exec the same code the
# subprocess script embeds
_ns = {"jax": jax}
exec(MESH_CTX, _ns)
_mesh_ctx = _ns["mesh_ctx"]


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.rl import a2c, distributed
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network
""") + MESH_CTX + textwrap.dedent("""
    env = make_env("cartpole")
    cfg = a2c.A2CConfig(n_envs=16, n_steps=8, actor_backend=BACKEND)
    net = make_network(env.spec.obs_shape, env.spec.n_actions + 1)
    mesh = jax.make_mesh((8,), ("data",))
    state = a2c.init(jax.random.PRNGKey(0), env, net, cfg)
    iteration, act_fn, benv = distributed.make_distributed_a2c(
        env, net, cfg, mesh)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    with mesh_ctx(mesh):
        for i in range(5):
            key, k = jax.random.split(key)
            state, env_state, obs, m = iteration(state, env_state, obs, k)
            assert jnp.isfinite(m["loss"]), m
    print("DISTRIBUTED_OK", float(m["loss"]))
""")


def test_distributed_a2c_one_device():
    """Degenerate mesh (1 device): shard_map path == plain data parallel."""
    from repro.rl import a2c, distributed
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env("cartpole")
    cfg = a2c.A2CConfig(n_envs=8, n_steps=8)
    net = make_network(env.spec.obs_shape, env.spec.n_actions + 1)
    mesh = jax.make_mesh((1,), ("data",))
    state = a2c.init(jax.random.PRNGKey(0), env, net, cfg)
    iteration, act_fn, benv = distributed.make_distributed_a2c(
        env, net, cfg, mesh)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    with _mesh_ctx(mesh):
        for i in range(3):
            state, env_state, obs, m = iteration(
                state, env_state, obs, jax.random.PRNGKey(10 + i))
    assert np.isfinite(float(m["loss"]))
    assert int(state.step) == 3


def test_distributed_a2c_int8_actor_one_device():
    """ActorQ inside the shard_map rollout (degenerate 1-device mesh)."""
    from repro.rl import a2c, distributed
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env("cartpole")
    cfg = a2c.A2CConfig(n_envs=8, n_steps=8, actor_backend="int8",
                        kernel_backend="ref")
    net = make_network(env.spec.obs_shape, env.spec.n_actions + 1)
    mesh = jax.make_mesh((1,), ("data",))
    state = a2c.init(jax.random.PRNGKey(0), env, net, cfg)
    iteration, act_fn, benv = distributed.make_distributed_a2c(
        env, net, cfg, mesh)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    with _mesh_ctx(mesh):
        for i in range(3):
            state, env_state, obs, m = iteration(
                state, env_state, obs, jax.random.PRNGKey(10 + i))
    assert np.isfinite(float(m["loss"]))
    assert int(state.step) == 3


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["fp32", "int8"])
def test_distributed_a2c_eight_devices(backend):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    script = f"BACKEND = {backend!r}\n" + SCRIPT
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DISTRIBUTED_OK" in out.stdout
