"""Unit + property tests for the paper-faithful quantization core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st, hnp  # guarded hypothesis import

from repro.core import affine, fake_quant, ptq, mixed_precision as mp
from repro.core.qconfig import QuantConfig, QuantMode

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Affine quantizer (paper Sec 3.1)
# ---------------------------------------------------------------------------

def test_zero_exactly_representable():
    w = jnp.array([-1.3, 0.0, 2.7, 0.0])
    for bits in (2, 4, 8):
        out = affine.ptq_tensor(w, bits)
        assert out[1] == 0.0 and out[3] == 0.0


def test_delta_matches_paper_formula():
    w = jnp.array([-2.0, 3.0, 1.0])
    p = affine.compute_affine_params(w, 8)
    np.testing.assert_allclose(p.delta, (2.0 + 3.0) / 256.0, rtol=1e-6)
    np.testing.assert_allclose(p.zero_point, round(2.0 / ((2 + 3) / 256)))


def test_range_extended_to_include_zero():
    # All-positive tensor: min(W,0)=0 so range is [0, max]
    w = jnp.array([1.0, 2.0, 4.0])
    p = affine.compute_affine_params(w, 8)
    np.testing.assert_allclose(p.delta, 4.0 / 256.0, rtol=1e-6)
    np.testing.assert_allclose(p.zero_point, 0.0)


def test_all_zero_tensor_safe():
    w = jnp.zeros((4, 4))
    out = affine.ptq_tensor(w, 8)
    assert jnp.all(out == 0.0) and jnp.all(jnp.isfinite(out))


@settings(max_examples=60, deadline=None)
@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                               min_side=1, max_side=16),
                  elements=st.floats(-100, 100, width=32)),
       st.sampled_from([2, 4, 6, 8]))
def test_prop_quant_error_bounded_by_delta(w, bits):
    """|W - D(Q(W))| <= 1.5*delta everywhere (paper-quantizer bound).

    Note on the bound: the paper's formula uses delta = range/2^n (not
    range/(2^n - 1)) and z = round(-min/delta), so the max of the range maps
    to code 2^n which clips to 2^n - 1 — the edge value can lose up to one
    full delta, plus 0.5*delta from rounding z. Interior values obey the
    usual 0.5*delta bound.
    """
    w = jnp.asarray(w)
    p = affine.compute_affine_params(w, bits)
    err = jnp.abs(w - affine.quantize_dequantize(w, p))
    assert float(err.max()) <= float(p.delta) * 1.5001 + 1e-6


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, (8, 8),
                  elements=st.floats(-50, 50, width=32)),
       st.sampled_from([4, 8]))
def test_prop_quantize_idempotent(w, bits):
    """Quantize-dequantize is a projection: applying twice == once."""
    w = jnp.asarray(w)
    p = affine.compute_affine_params(w, bits)
    once = affine.quantize_dequantize(w, p)
    twice = affine.quantize_dequantize(once, p)
    np.testing.assert_allclose(once, twice, atol=float(p.delta) * 0.51 + 1e-6)


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, (16,), elements=st.floats(-10, 10, width=32)))
def test_prop_codes_in_range(w):
    w = jnp.asarray(w)
    for bits in (2, 8):
        p = affine.compute_affine_params(w, bits)
        q = affine.quantize(w, p)
        assert float(q.min()) >= 0.0
        assert float(q.max()) <= 2.0 ** bits - 1.0


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, (4, 6), elements=st.floats(-20, 20, width=32)))
def test_prop_int_pack_roundtrip_matches_simulation(w):
    w = jnp.asarray(w)
    sim = affine.ptq_tensor(w, 8)
    codes, p = affine.quantize_to_int(w, 8)
    assert codes.dtype == jnp.int8
    unpacked = affine.dequantize_from_int(codes, p)
    np.testing.assert_allclose(sim, unpacked, rtol=1e-5, atol=1e-5)


def test_per_axis_less_error_than_per_tensor():
    # Channels with very different scales: per-axis must win.
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (3, 3, 8, 4))
    w = w * jnp.array([0.01, 0.1, 1.0, 10.0])  # scale per output channel
    err_pt = float(affine.quantization_error(w, 8, axis=None))
    err_pa = float(affine.quantization_error(w, 8, axis=3))
    assert err_pa < err_pt


def test_fp16_quantization():
    w = jnp.array([1.0000001, -2.5, 65504.0, 1e-8], jnp.float32)
    out = affine.fp16_quantize(w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, np.asarray(w, np.float16).astype(np.float32))


def test_wider_distribution_higher_error():
    """Fig 3/4's mechanism: wider weight distribution -> more int8 error."""
    key = jax.random.PRNGKey(1)
    narrow = jax.random.normal(key, (256, 256)) * 0.05
    wide = jax.random.normal(key, (256, 256)) * 1.0
    assert float(affine.quantization_error(wide, 8)) > \
        float(affine.quantization_error(narrow, 8))


# ---------------------------------------------------------------------------
# Fake quantization / STE (paper Sec 3.2)
# ---------------------------------------------------------------------------

def test_ste_gradient_is_identity():
    w = jnp.array([-1.0, 0.3, 2.0])

    def loss(w):
        return jnp.sum(fake_quant.fake_quant_self_range(w, 4) ** 2)

    g = jax.grad(loss)(w)
    fq = fake_quant.fake_quant_self_range(w, 4)
    np.testing.assert_allclose(g, 2 * fq, rtol=1e-5)  # d/dw (fq^2) with STE


def test_fake_quant_matches_affine_oracle():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (32, 32))
    for bits in (2, 4, 8):
        got = fake_quant.fake_quant_self_range(w, bits)
        want = affine.ptq_tensor(w, bits)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_observer_monitoring_and_freeze():
    st0 = fake_quant.ObserverState.init()
    x1 = jnp.array([-1.0, 2.0])
    st1 = fake_quant.observe(st0, x1, ema_decay=0.9,
                             monitoring=jnp.asarray(True))
    assert bool(st1.initialized)
    np.testing.assert_allclose(st1.vmin, -1.0)
    np.testing.assert_allclose(st1.vmax, 2.0)
    # EMA pull toward new batch
    x2 = jnp.array([-3.0, 0.5])
    st2 = fake_quant.observe(st1, x2, ema_decay=0.9,
                             monitoring=jnp.asarray(True))
    np.testing.assert_allclose(st2.vmin, 0.9 * -1.0 + 0.1 * -3.0, rtol=1e-6)
    # Frozen after delay: no change
    st3 = fake_quant.observe(st2, jnp.array([-100.0, 100.0]), 0.9,
                             monitoring=jnp.asarray(False))
    np.testing.assert_allclose(st3.vmin, st2.vmin)
    np.testing.assert_allclose(st3.vmax, st2.vmax)


def test_qat_context_delay_semantics():
    cfg = QuantConfig.qat(bits=8, quant_delay=10)
    w = jnp.linspace(-1, 1, 64).reshape(8, 8)

    # Before the delay: identity on weights and activations.
    ctx = fake_quant.make_context(cfg, {}, step=0)
    np.testing.assert_allclose(ctx.weight("w", w), w)
    a = ctx.activation("a", w)
    np.testing.assert_allclose(a, w)
    coll = ctx.merged_collection()
    assert "a" in coll and bool(coll["a"].initialized)

    # After the delay: fake quantization active, using monitored ranges.
    ctx2 = fake_quant.make_context(cfg, coll, step=10)
    wq = ctx2.weight("w", w)
    assert not np.allclose(wq, w)
    np.testing.assert_allclose(wq, affine.ptq_tensor(w, 8), rtol=1e-5)
    aq = ctx2.activation("a", w)
    assert not np.allclose(aq, w)


def test_null_context_passthrough():
    ctx = fake_quant.make_context(QuantConfig.none(), None, 0)
    w = jnp.ones((4, 4))
    assert ctx.weight("w", w) is w
    assert ctx.activation("a", w) is w


# ---------------------------------------------------------------------------
# PTQ over pytrees
# ---------------------------------------------------------------------------

def _toy_params():
    k = jax.random.PRNGKey(3)
    return {
        "dense": {"kernel": jax.random.normal(k, (16, 8)),
                  "bias": jnp.zeros((8,))},
        "conv": {"kernel": jax.random.normal(k, (3, 3, 4, 8))},
        "norm": {"scale": jnp.ones((16,))},
    }


def test_ptq_simulate_only_touches_weights():
    params = _toy_params()
    out = ptq.ptq_simulate(params, QuantConfig.ptq_int(8))
    assert not np.allclose(out["dense"]["kernel"], params["dense"]["kernel"])
    np.testing.assert_allclose(out["dense"]["bias"], params["dense"]["bias"])
    np.testing.assert_allclose(out["norm"]["scale"], params["norm"]["scale"])


def test_ptq_pack_unpack_roundtrip_and_memory():
    params = _toy_params()
    cfg = QuantConfig.ptq_int(8)
    packed = ptq.ptq_pack(params, cfg)
    unpacked = ptq.ptq_unpack(packed)
    sim = ptq.ptq_simulate(params, cfg)
    np.testing.assert_allclose(unpacked["dense"]["kernel"],
                               sim["dense"]["kernel"], rtol=1e-5, atol=1e-5)
    # Paper: ~4x parameter-memory reduction from fp32 -> int8.
    fp32_bytes = ptq.tree_nbytes(params)
    int8_bytes = ptq.tree_nbytes(packed)
    assert int8_bytes < fp32_bytes / 3.0


def test_ptq_fp16_simulation():
    params = _toy_params()
    out = ptq.ptq_simulate(params, QuantConfig.ptq_fp16())
    want = np.asarray(params["dense"]["kernel"], np.float16).astype(np.float32)
    np.testing.assert_allclose(out["dense"]["kernel"], want)


# ---------------------------------------------------------------------------
# Mixed precision
# ---------------------------------------------------------------------------

def test_cast_and_loss_scale_roundtrip():
    from repro.core.qconfig import MixedPrecisionConfig
    params = _toy_params()
    half = mp.to_compute(params, MixedPrecisionConfig.bf16())
    assert half["dense"]["kernel"].dtype == jnp.bfloat16

    ls = mp.DynamicLossScale.init(1024.0)
    loss = jnp.asarray(0.5)
    scaled = mp.scale_loss(loss, ls)
    np.testing.assert_allclose(scaled, 512.0)
    grads = {"g": jnp.asarray([2048.0])}
    np.testing.assert_allclose(mp.unscale_grads(grads, ls)["g"], [2.0])


def test_dynamic_loss_scale_halves_on_nan_and_grows():
    ls = mp.DynamicLossScale.init(1024.0)
    ls2 = mp.update_loss_scale(ls, jnp.asarray(False))
    np.testing.assert_allclose(ls2.scale, 512.0)
    ls3 = mp.update_loss_scale(ls2, jnp.asarray(True), growth_interval=1)
    np.testing.assert_allclose(ls3.scale, 1024.0)


def test_all_finite_detects_nan():
    assert bool(mp.all_finite({"a": jnp.ones(3)}))
    assert not bool(mp.all_finite({"a": jnp.array([1.0, jnp.nan])}))


# ---------------------------------------------------------------------------
# Config parsing
# ---------------------------------------------------------------------------

def test_quant_config_parse():
    assert QuantConfig.parse("none").mode == QuantMode.NONE
    assert QuantConfig.parse("ptq_int8").bits == 8
    assert QuantConfig.parse("ptq_fp16").mode == QuantMode.PTQ_FP16
    c = QuantConfig.parse("qat4:delay=100")
    assert c.bits == 4 and c.quant_delay == 100 and c.is_qat
    with pytest.raises(ValueError):
        QuantConfig.parse("int9000")
