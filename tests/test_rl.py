"""RL substrate tests: envs, buffer, algorithms, QuaRL pipelines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # guarded hypothesis import

from repro.core.qconfig import QuantConfig
from repro.rl import buffer as rb
from repro.rl import loops
from repro.rl.env import batched_env, rollout
from repro.rl.envs import ENVS, make as make_env


# ---------------------------------------------------------------------------
# Environments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ENVS))
def test_env_api_contract(name):
    env = make_env(name)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    assert obs.shape == env.spec.obs_shape
    if env.spec.continuous:
        action = jnp.zeros((env.spec.action_dim,))
    else:
        action = jnp.zeros((), jnp.int32)
    state, obs2, reward, done = env.step(state, action, key)
    assert obs2.shape == env.spec.obs_shape
    assert reward.shape == () and done.shape == ()
    assert bool(jnp.isfinite(reward))
    # jittable
    jitted = jax.jit(env.step)
    jitted(state, action, key)


@pytest.mark.parametrize("name", sorted(ENVS))
def test_env_episodes_terminate(name):
    """Random policy: every env terminates within its max_steps budget."""
    env = make_env(name)
    key = jax.random.PRNGKey(1)
    state, obs = env.reset(key)
    done_seen = False
    for i in range(env.spec.max_steps + 5):
        key, k1, k2 = jax.random.split(key, 3)
        if env.spec.continuous:
            action = jax.random.uniform(k1, (env.spec.action_dim,),
                                        minval=-1, maxval=1)
        else:
            action = jax.random.randint(k1, (), 0, env.spec.n_actions)
        state, obs, reward, done = env.step(state, action, k2)
        if bool(done):
            done_seen = True
            break
    assert done_seen, f"{name} never terminated"


def test_cartpole_dynamics_match_gym():
    """One analytic step against hand-computed gym physics."""
    env = make_env("cartpole")
    from repro.rl.envs.cartpole import CartPoleState
    s = CartPoleState(jnp.asarray(0.1), jnp.asarray(0.2), jnp.asarray(0.05),
                      jnp.asarray(-0.1), jnp.zeros((), jnp.int32))
    ns, obs, r, d = env.step(s, jnp.asarray(1), jax.random.PRNGKey(0))
    # x' = x + tau * x_dot
    np.testing.assert_allclose(ns.x, 0.1 + 0.02 * 0.2, rtol=1e-6)
    np.testing.assert_allclose(ns.theta, 0.05 + 0.02 * -0.1, rtol=1e-6)
    assert float(r) == 1.0 and float(d) == 0.0


def test_airnav_reward_equation():
    """Paper Eq. 1: reaching the goal pays 1000*alpha - D_g - D_c - 1."""
    env = make_env("airnav")
    from repro.rl.envs.airnav import AirNavState, V_MAX, T_MAX
    s = AirNavState(pos=jnp.array([5.0, 5.0]), vel=jnp.zeros(2),
                    heading=jnp.zeros(()), goal=jnp.array([6.2, 5.0]),
                    obstacles=jnp.zeros((5, 3)), t=jnp.zeros((), jnp.int32))
    # action 22 = full speed, straight ahead (speed idx 4, yaw idx 2)
    ns, obs, r, d = env.step(s, jnp.asarray(22), jax.random.PRNGKey(0))
    assert float(d) == 1.0          # goal 1.2m ahead < 1.25m step + 1m radius
    d_goal = float(jnp.linalg.norm(ns.goal - ns.pos))
    expect = 1000.0 - d_goal - (V_MAX - V_MAX) * T_MAX - 1.0
    np.testing.assert_allclose(float(r), expect, rtol=1e-5)


def test_batched_rollout_and_autoreset():
    env = make_env("cartpole")
    benv = batched_env(env, 4)
    key = jax.random.PRNGKey(0)
    state, obs = benv.reset(key)
    assert obs.shape == (4, 4)

    def policy(params, obs, key):
        return jax.random.randint(key, (4,), 0, 2), jnp.zeros((4, 2))

    state, obs, traj = rollout(benv, policy, None, state, obs, key, 100)
    assert traj.reward.shape == (100, 4)
    assert float(traj.done.sum()) > 0  # episodes ended and auto-reset
    # time index of env state resets after done
    assert int(state.t.max()) < 100


# ---------------------------------------------------------------------------
# Replay buffer
# ---------------------------------------------------------------------------

def test_replay_circular_write_and_sample():
    state = rb.replay_init(8, (2,))
    batch = rb.Transition(
        obs=jnp.arange(12, dtype=jnp.float32).reshape(6, 2),
        action=jnp.arange(6, dtype=jnp.int32),
        reward=jnp.arange(6, dtype=jnp.float32),
        done=jnp.zeros(6), next_obs=jnp.zeros((6, 2)))
    state = rb.replay_add_batch(state, batch)
    assert int(state.size) == 6 and int(state.index) == 6
    state = rb.replay_add_batch(state, batch)   # wraps
    assert int(state.size) == 8 and int(state.index) == 4
    sample = rb.replay_sample(state, jax.random.PRNGKey(0), 16)
    assert sample.obs.shape == (16, 2)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 10))
def test_prop_replay_size_invariant(n1, n2):
    cap = 16
    state = rb.replay_init(cap, (1,))
    for n in (n1, n2):
        batch = rb.Transition(jnp.zeros((n, 1)), jnp.zeros((n,), jnp.int32),
                              jnp.ones((n,)), jnp.zeros((n,)),
                              jnp.zeros((n, 1)))
        state = rb.replay_add_batch(state, batch)
    assert int(state.size) == min(n1 + n2, cap)
    assert int(state.index) == (n1 + n2) % cap


def test_uniform_sample_restricted_to_written_prefix():
    """At ``size < capacity`` sampling must stay inside the written prefix
    — a partially-filled buffer never yields garbage (all-zero) slots."""
    cap = 64
    for n_written in (1, 3, 17):
        state = rb.replay_init(cap, (2,))
        batch = rb.Transition(
            obs=jnp.ones((n_written, 2)),
            action=jnp.arange(n_written, dtype=jnp.int32),
            reward=1.0 + jnp.arange(n_written, dtype=jnp.float32),
            done=jnp.zeros(n_written), next_obs=jnp.ones((n_written, 2)))
        state = rb.replay_add_batch(state, batch)
        for seed in range(4):
            s = rb.replay_sample(state, jax.random.PRNGKey(seed), 32)
            # rewards were written strictly positive; an out-of-prefix
            # draw would surface as a 0.0 reward
            assert float(np.asarray(s.reward).min()) >= 1.0
            assert int(np.asarray(s.action).max()) < n_written


def test_uniform_sample_duplicates_by_contract():
    """Sampling is with replacement: batch_size > size must produce
    duplicates (documented contract, not a bug)."""
    state = rb.replay_init(8, (1,))
    batch = rb.Transition(jnp.zeros((2, 1)), jnp.arange(2, dtype=jnp.int32),
                          jnp.zeros((2,)), jnp.zeros((2,)),
                          jnp.zeros((2, 1)))
    state = rb.replay_add_batch(state, batch)
    s = rb.replay_sample(state, jax.random.PRNGKey(0), 16)
    actions = np.asarray(s.action)
    assert len(np.unique(actions)) <= 2
    assert len(actions) == 16


# ---------------------------------------------------------------------------
# Algorithms (short runs: learning signal, not convergence)
# ---------------------------------------------------------------------------

def test_ppo_learns_cartpole():
    res = loops.train("ppo", "cartpole", iterations=120, record_every=40,
                      seed=3)
    assert max(res.rewards) > 100, res.rewards


def test_a2c_runs_and_improves():
    res = loops.train("a2c", "cartpole", iterations=500, record_every=250,
                      seed=1)
    assert max(res.rewards) > 50, res.rewards


def test_dqn_runs_finite():
    res = loops.train("dqn", "cartpole", iterations=60, record_every=30)
    assert all(np.isfinite(res.rewards))


def test_ddpg_runs_finite():
    res = loops.train("ddpg", "pendulum", iterations=40, record_every=20)
    assert all(np.isfinite(res.rewards))


def test_qat_training_runs_with_delay():
    from repro.core.qconfig import QuantConfig
    res = loops.train("ppo", "cartpole", iterations=30,
                      quant=QuantConfig.qat(8, quant_delay=10),
                      record_every=15)
    assert all(np.isfinite(res.rewards))
    assert res.state.observers, "QAT observers were never populated"


# ---------------------------------------------------------------------------
# QuaRL pipelines (Algorithms 1 & 2)
# ---------------------------------------------------------------------------

def test_quarl_ptq_pipeline():
    out = loops.quarl_ptq("ppo", "cartpole", bits_list=(8,), iterations=60)
    r = out[0]
    assert r.label == "ptq_int8"
    assert np.isfinite(r.fp32_reward) and np.isfinite(r.quant_reward)
    assert "range" in r.extra["weight_stats"]


def test_eval_params_changes_weights_ptq():
    res = loops.train("ppo", "cartpole", iterations=10, record_every=10)
    from repro.rl.common import eval_params
    q = eval_params(res.state.params, QuantConfig.ptq_int(4))
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), res.state.params, q)
    assert max(jax.tree_util.tree_leaves(diff)) > 0
