"""Async actor–learner topology tests (ISSUE 4 acceptance contract).

* anchor — ``topology="async"`` with chunk size 1, a full barrier
  (``async_barrier=True``) and ``sync_every = updates_per_iter`` matches
  the bulk-synchronous driver's learner trajectory *bitwise* (params,
  rewards, update counter) for DQN and DDPG — and transitively the fused
  driver via the existing ``num_actors=1, sync_every=1`` parity,
* the double-buffered overlapped mode trains finite with int8 actors,
  records per-sync divergence + actor lag, and honours the
  learner-update staleness contract,
* the double-buffer layout itself: independent slots, host-level swap,
  capacity conservation,
* the pixel (Catch) envs run the conv int8 im2col path under async
  fan-out (fast smoke + slow convergence),
* a 4-device mesh smoke run (slow, subprocess) drives both async
  programs through shard_map.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import actor_learner, dqn, loops
from repro.rl import buffer as rb
from repro.rl.envs import make as make_env
from repro.rl.networks import make_network

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_DQN = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                 buffer_size=512, batch_size=16, warmup=8)
SMALL_DDPG = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                  buffer_size=512, batch_size=16, warmup=8)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# anchor: chunk-1 async + full barrier == the bulk-synchronous driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env,overrides", [
    ("dqn", "cartpole", SMALL_DQN),
    ("ddpg", "pendulum", SMALL_DDPG),
])
def test_async_barrier_anchor_matches_synchronous_driver(algo, env,
                                                         overrides):
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=7,
              algo_overrides=dict(overrides))
    sync = loops.train(algo, env, topology="actor-learner", num_actors=1,
                       sync_every=1, **kw)
    anc = loops.train(algo, env, topology="async", num_actors=1,
                      sync_every=overrides["updates_per_iter"],
                      async_barrier=True, steps_per_call=1, **kw)
    for a, b in zip(_leaves(sync.state.params), _leaves(anc.state.params)):
        np.testing.assert_array_equal(a, b)
    assert sync.rewards == anc.rewards
    assert int(sync.state.extras.updates) == int(anc.state.extras.updates)


def test_async_barrier_anchor_with_int8_actors():
    # the int8 snapshot path keeps the contract too (cache packed at the
    # same param values as the sync topology's carried cache)
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=11,
              actor_backend="int8", algo_overrides=dict(SMALL_DQN))
    sync = loops.train("dqn", "cartpole", topology="actor-learner",
                       num_actors=1, sync_every=1, **kw)
    anc = loops.train("dqn", "cartpole", topology="async", num_actors=1,
                      sync_every=SMALL_DQN["updates_per_iter"],
                      async_barrier=True, steps_per_call=1, **kw)
    for a, b in zip(_leaves(sync.state.params), _leaves(anc.state.params)):
        np.testing.assert_array_equal(a, b)
    assert sync.rewards == anc.rewards


# ---------------------------------------------------------------------------
# the overlapped double-buffered mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env,overrides", [
    ("dqn", "cartpole", SMALL_DQN),
    ("ddpg", "pendulum", SMALL_DDPG),
])
def test_async_int8_trains_finite_with_staleness_metrics(algo, env,
                                                         overrides):
    res = loops.train(algo, env, topology="async", num_actors=2,
                      sync_every=4, steps_per_call=2, actor_backend="int8",
                      iterations=8, record_every=4, eval_episodes=2,
                      seed=3, algo_overrides=dict(overrides))
    assert all(np.isfinite(res.rewards))
    # divergence recorded once per true push, per actor, and the int8
    # actors genuinely diverge from the fp32 learner head
    assert len(res.divergences) == len(res.actor_lags) > 0
    assert all(len(d) == 2 for d in res.divergences)
    assert all(np.isfinite(d).all() for d in res.divergences)
    assert any(v > 0 for d in res.divergences for v in d)
    # staleness contract in learner updates: each round dispatches
    # steps_per_call * updates_per_iter = 4 updates, so every retiring
    # snapshot served exactly sync_every = 4 updates
    assert all(lag == 4 for lag in res.actor_lags)
    assert int(res.state.extras.updates) > 0


def test_async_fp32_divergence_is_zero_at_push():
    # a push mints the snapshot from the live learner params — with fp32
    # actors the behaviour head IS the fresh learner head at every sync
    res = loops.train("dqn", "cartpole", topology="async", num_actors=2,
                      sync_every=2, steps_per_call=1, iterations=6,
                      record_every=3, eval_episodes=2, seed=0,
                      algo_overrides=dict(SMALL_DQN))
    assert len(res.divergences) > 0
    assert all(v == 0.0 for d in res.divergences for v in d)


def test_async_learner_consumes_double_buffered_data():
    # data written during one sync period becomes sampleable after the
    # swap: the read slot the final learner state carries must hold
    # transitions, and learner updates must have landed past warmup
    res = loops.train("dqn", "cartpole", topology="async", num_actors=2,
                      sync_every=2, steps_per_call=1, iterations=8,
                      record_every=4, eval_episodes=2, seed=5,
                      algo_overrides=dict(SMALL_DQN))
    read_size = int(rb.replay_total_size(res.state.extras.replay))
    assert read_size > 0
    assert int(res.state.extras.updates) > 0
    # slots are half-capacity: buffer_size / (2 * num_actors) per shard
    assert res.state.extras.replay.data.reward.shape == (2, 128)


def test_async_catch_pixel_smoke():
    # the conv int8 im2col path under async fan-out (fast finiteness
    # smoke; convergence is the slow test below)
    res = loops.train("dqn", "catch", topology="async", num_actors=2,
                      sync_every=4, steps_per_call=2, actor_backend="int8",
                      iterations=4, record_every=2, eval_episodes=2,
                      seed=0, net_kwargs=dict(conv_filters=(4,),
                                              fc_width=16),
                      algo_overrides=dict(SMALL_DQN))
    assert all(np.isfinite(res.rewards))
    assert len(res.divergences) > 0
    assert any(v > 0 for d in res.divergences for v in d)


def test_async_rejects_invalid_configs():
    with pytest.raises(ValueError):
        loops.train("ppo", "cartpole", topology="async", iterations=2)
    # async_barrier is an async-only knob
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", async_barrier=True, iterations=2)
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", topology="actor-learner",
                    async_barrier=True, iterations=2,
                    algo_overrides=dict(SMALL_DQN))
    # batch divisibility (raised by the shared _validate)
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", topology="async", num_actors=3,
                    iterations=2, algo_overrides=dict(SMALL_DQN))
    # double-buffer divisibility: batch divides but
    # buffer_size % (num_actors * 2 slots) != 0 -> init_async refuses
    # rather than silently truncating the slot capacity
    with pytest.raises(ValueError, match="double-buffered"):
        loops.train("dqn", "cartpole", topology="async", num_actors=2,
                    iterations=2,
                    algo_overrides=dict(SMALL_DQN, buffer_size=510))


# ---------------------------------------------------------------------------
# the double-buffer layout
# ---------------------------------------------------------------------------

def test_double_buffer_slots_are_independent():
    db = rb.double_buffer_init(rb.replay_init_sharded, 2, 8, (3,))
    batch = rb.Transition(
        obs=jnp.ones((2, 5, 3)), action=jnp.zeros((2, 5), jnp.int32),
        reward=jnp.ones((2, 5)), done=jnp.zeros((2, 5)),
        next_obs=jnp.ones((2, 5, 3)))
    db = db._replace(write=rb.replay_add_sharded(db.write, batch))
    # writes land in the write slot only
    assert int(rb.replay_total_size(db.write)) == 10
    assert int(rb.replay_total_size(db.read)) == 0
    assert int(rb.double_buffer_total_size(db)) == 10
    # slots never share arrays (the async programs' independence invariant)
    read_ids = {id(x) for x in jax.tree_util.tree_leaves(db.read)}
    write_ids = {id(x) for x in jax.tree_util.tree_leaves(db.write)}
    assert not read_ids & write_ids


def test_double_buffer_swap_is_reference_exchange():
    db = rb.double_buffer_init(rb.replay_init_sharded, 1, 4, (2,))
    batch = rb.Transition(
        obs=jnp.ones((1, 2, 2)), action=jnp.zeros((1, 2), jnp.int32),
        reward=jnp.ones((1, 2)), done=jnp.zeros((1, 2)),
        next_obs=jnp.ones((1, 2, 2)))
    filled = rb.replay_add_sharded(db.write, batch)
    db = db._replace(write=filled)
    swapped = rb.double_buffer_swap(db)
    # the exact objects trade places — no copy, no device op
    assert swapped.read is filled
    assert swapped.write is db.read
    back = rb.double_buffer_swap(swapped)
    assert back.read is db.read and back.write is db.write


# ---------------------------------------------------------------------------
# slow: convergence on pixel Catch + 4-device mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_int8_catch_convergence():
    """ISSUE acceptance: async int8 fan-out learns sparse-reward Catch —
    the conv im2col int8 path under true overlapped collection."""
    cfg = dict(n_envs=8, rollout_steps=8, updates_per_iter=4,
               buffer_size=8192, batch_size=32, warmup=256,
               eps_decay_updates=800, target_update_every=100)
    res = loops.train("dqn", "catch", topology="async", num_actors=2,
                      sync_every=16, steps_per_call=4,
                      actor_backend="int8", iterations=800,
                      record_every=100, eval_episodes=16, seed=0,
                      net_kwargs=dict(conv_filters=(8, 8), fc_width=32),
                      algo_overrides=cfg)
    # random play is ~ -5 on [-5, 5]; require clear learning progress
    assert max(res.rewards) > 0.0, res.rewards


@pytest.mark.slow
def test_async_actor_learner_four_device_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import contextlib
        import jax, jax.numpy as jnp, numpy as np
        from repro.rl import actor_learner, dqn
        from repro.rl.envs import make as make_env
        from repro.rl.networks import make_network

        def mesh_ctx(mesh):
            for name in ("set_mesh", "use_mesh"):
                if hasattr(jax.sharding, name):
                    return getattr(jax.sharding, name)(mesh)
            return contextlib.nullcontext()

        env = make_env("cartpole")
        cfg = dqn.DQNConfig(n_envs=4, rollout_steps=4, updates_per_iter=2,
                            buffer_size=1024, batch_size=32, warmup=16,
                            actor_backend="int8", kernel_backend="ref")
        net = make_network(env.spec.obs_shape, env.spec.n_actions)
        al = actor_learner.ActorLearnerConfig(num_actors=4, sync_every=8)
        mesh = jax.make_mesh((4,), ("actor",))
        progs = actor_learner.make_async_actor_learner(
            "dqn", env, net, cfg, al, mesh=mesh)
        learner, wbuf = actor_learner.init_async(
            jax.random.PRNGKey(0), env, net, "dqn", cfg, al)
        snap = progs.make_snapshot(learner)
        env_state, obs = progs.benv_global.reset(jax.random.PRNGKey(1))
        key = jax.random.PRNGKey(2)
        chunk, upd = 2, 4
        with mesh_ctx(mesh):
            for r in range(4):
                key, k_it = jax.random.split(key)
                k_roll, k_up = jax.random.split(k_it)
                env_state, obs, wbuf, a_m = progs.actor_chunk(
                    snap, env_state, obs, wbuf, k_roll, n_chunks=chunk)
                learner, l_m = progs.learner_chunk(learner, k_up,
                                                   n_updates=upd)
                learner, wbuf = actor_learner.swap_read_slot(learner,
                                                             wbuf)
                snap = progs.make_snapshot(learner)
            div = progs.divergence(learner, snap, obs)
            assert jnp.isfinite(l_m["loss"]), l_m
            assert jnp.isfinite(a_m["reward"]), a_m
        assert div.shape == (4,)
        assert np.isfinite(np.asarray(div)).all()
        print("ASYNC_MESH_OK", float(l_m["loss"]))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ASYNC_MESH_OK" in out.stdout


# ---------------------------------------------------------------------------
# overlap: the async driver never blocks between records
# ---------------------------------------------------------------------------

def test_async_round_dispatch_returns_futures():
    """The two hot-path programs are dispatchable back-to-back without a
    host sync: after dispatching a full round, every output is a live
    (uncommitted-to-host) jax.Array we can keep feeding forward, and the
    final block resolves the whole pipeline at once."""
    env = make_env("cartpole")
    cfg = dqn.DQNConfig(**dict(SMALL_DQN, actor_backend="int8"))
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=2, sync_every=4)
    progs = actor_learner.make_async_actor_learner("dqn", env, net, cfg,
                                                   al)
    learner, wbuf = actor_learner.init_async(jax.random.PRNGKey(0), env,
                                             net, "dqn", cfg, al)
    snap = progs.make_snapshot(learner)
    env_state, obs = progs.benv_global.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    for _ in range(3):
        key, k_it = jax.random.split(key)
        k_roll, k_up = jax.random.split(k_it)
        env_state, obs, wbuf, a_m = progs.actor_chunk(
            snap, env_state, obs, wbuf, k_roll, n_chunks=2)
        learner, l_m = progs.learner_chunk(learner, k_up, n_updates=4)
        learner, wbuf = actor_learner.swap_read_slot(learner, wbuf)
        snap = progs.make_snapshot(learner)
    jax.block_until_ready((learner.params, obs))
    assert np.isfinite(float(l_m["loss"]))
    assert np.isfinite(float(a_m["reward"]))
    assert int(rb.replay_total_size(learner.extras.replay)) > 0
