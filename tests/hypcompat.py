"""Optional-dependency shim for ``hypothesis``.

Tier-1 runs in minimal containers where hypothesis may not be installed.
When it is available this module re-exports the real API unchanged; when it
is not, ``@given`` replaces each property test with a skip stub (zero-arg so
pytest requests no fixtures) and ``st``/``hnp`` become permissive dummies so
strategy expressions in decorator arguments still evaluate at import time.
Either way, ``pytest -x -q`` collects and runs every module.
"""
try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for strategy modules: any attribute/call returns self."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
