"""Bitwise-resume anchor tests (ISSUE 8 acceptance contract).

The headline fault-tolerance claim: training to step k with
checkpointing on, then resuming from the newest committed step and
training to n, produces final params, optimizer state, and every
recorded metric **bitwise identical** to the uninterrupted run to n —
for DQN and DDPG across all three topologies with the packed int8 actor
cache in the state.  Checkpoint cadence never clips chunk/round
boundaries and the save lands after each loop body's eval PRNG split,
so enabling checkpointing cannot perturb the trajectory either (also
asserted: the uninterrupted reference runs *without* a checkpoint dir).

The slow marker carries the fresh-process variant: phase 1 trains and
checkpoints in one subprocess, phase 2 resumes in a second subprocess —
nothing shared but the checkpoint directory.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.rl import loops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL = dict(n_envs=2, rollout_steps=2, updates_per_iter=2,
             buffer_size=64, batch_size=8, warmup=8)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _train(algo, env, topo, *, ckpt_dir=None, iterations=6, resume=False,
           net_kwargs=None, **kw):
    multi = topo != "fused"
    return loops.train(
        algo, env, iterations=iterations, seed=3, record_every=3,
        eval_episodes=2, actor_backend="int8",
        algo_overrides=dict(SMALL),
        net_kwargs=net_kwargs or dict(hidden=(16,)),
        topology=topo, num_actors=2 if multi else 1,
        sync_every=2 if multi else 1,
        checkpoint_dir=ckpt_dir, checkpoint_every=3 if ckpt_dir else 0,
        resume=resume, **kw)


def _assert_bitwise(full, res):
    for a, b in zip(_leaves(full.state), _leaves(res.state)):
        np.testing.assert_array_equal(a, b)
    assert full.rewards == res.rewards
    assert full.action_variances == res.action_variances
    assert full.divergences == res.divergences
    assert full.actor_lags == res.actor_lags


@pytest.mark.parametrize("topo", ["fused", "actor-learner", "async"])
@pytest.mark.parametrize("algo,env", [("dqn", "catch"),
                                      ("ddpg", "pendulum")])
def test_resume_bitwise_identical(tmp_path, algo, env, topo):
    d = str(tmp_path / "ckpt")
    full = _train(algo, env, topo)                     # no checkpointing
    _train(algo, env, topo, ckpt_dir=d, iterations=3)  # killed at k=3
    res = _train(algo, env, topo, ckpt_dir=d, resume=True)
    _assert_bitwise(full, res)
    # the final-boundary save committed too, and retention kept both
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(d).steps() == [3, 6]


def test_resume_bitwise_prioritized_replay(tmp_path):
    """PER sum-trees (per-shard) ride the same contract."""
    d = str(tmp_path / "ckpt")
    kw = dict(replay="prioritized", priority_exponent=0.6)
    full = _train("dqn", "catch", "actor-learner", **kw)
    _train("dqn", "catch", "actor-learner", ckpt_dir=d, iterations=3, **kw)
    res = _train("dqn", "catch", "actor-learner", ckpt_dir=d, resume=True,
                 **kw)
    _assert_bitwise(full, res)


@pytest.mark.parametrize("topo", ["fused", "async"])
def test_resume_bitwise_seq_policy(tmp_path, topo):
    """Sequence policies ride the contract too: the int8 KV-cache actor
    state (``rl.actorq.seq_cache_zeros`` riding in the env state via
    ``attach_policy_state``) is checkpointed and restored bitwise with
    the rest of the training state."""
    d = str(tmp_path / "ckpt")
    kw = dict(net_kwargs={"transformer": dict(d_model=16, n_layers=1,
                                              d_ff=32)})
    full = _train("dqn", "catch_seq", topo, **kw)
    _train("dqn", "catch_seq", topo, ckpt_dir=d, iterations=3, **kw)
    res = _train("dqn", "catch_seq", topo, ckpt_dir=d, resume=True, **kw)
    _assert_bitwise(full, res)


def test_resume_noop_without_checkpoint(tmp_path):
    """resume=True over an empty directory starts from scratch."""
    full = _train("dqn", "catch", "fused")
    res = _train("dqn", "catch", "fused",
                 ckpt_dir=str(tmp_path / "empty"), resume=True)
    _assert_bitwise(full, res)


def test_checkpoint_knobs_validated():
    with pytest.raises(ValueError, match="needs checkpoint_dir"):
        loops.train("dqn", "catch", iterations=1, resume=True,
                    algo_overrides=dict(SMALL))
    with pytest.raises(ValueError, match="needs checkpoint_dir"):
        loops.train("dqn", "catch", iterations=1, checkpoint_every=5,
                    algo_overrides=dict(SMALL))


_PHASE_SCRIPT = textwrap.dedent("""
    import json, sys
    import numpy as np, jax
    from repro.rl import loops

    ckpt_dir, iterations, resume, out = (
        sys.argv[1], int(sys.argv[2]), sys.argv[3] == "1", sys.argv[4])
    res = loops.train(
        "dqn", "catch", iterations=iterations, seed=3, record_every=3,
        eval_episodes=2, actor_backend="int8", topology="async",
        num_actors=2, sync_every=2,
        algo_overrides=dict(n_envs=2, rollout_steps=2, updates_per_iter=2,
                            buffer_size=64, batch_size=8, warmup=8),
        net_kwargs=dict(hidden=(16,)),
        checkpoint_dir=ckpt_dir or None,
        checkpoint_every=3 if ckpt_dir else 0, resume=resume)
    leaves = [np.asarray(x).tolist()
              for x in jax.tree_util.tree_leaves(res.state.params)]
    json.dump({"params": leaves, "rewards": res.rewards}, open(out, "w"))
""")


@pytest.mark.slow
def test_resume_across_processes(tmp_path):
    """Fresh process-level state: nothing survives phase 1 except the
    checkpoint directory, and phase 2 still matches the uninterrupted
    single-process reference bitwise."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

    def phase(ckpt_dir, iterations, resume, out):
        subprocess.run(
            [sys.executable, "-c", _PHASE_SCRIPT, ckpt_dir,
             str(iterations), "1" if resume else "0", out],
            check=True, env=env, cwd=REPO, timeout=600)

    d = str(tmp_path / "ckpt")
    phase("", 6, False, str(tmp_path / "full.json"))
    phase(d, 3, False, str(tmp_path / "phase1.json"))
    phase(d, 6, True, str(tmp_path / "resumed.json"))

    full = json.load(open(tmp_path / "full.json"))
    res = json.load(open(tmp_path / "resumed.json"))
    assert full["rewards"] == res["rewards"]
    for a, b in zip(full["params"], res["params"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
