"""Per-architecture smoke tests: reduced variants (2 layers, d_model<=512,
<=4 experts) run one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.core.qconfig import QuantConfig
from repro.models import transformer

ARCHS = ["h2o-danube-1.8b", "xlstm-125m", "stablelm-12b", "whisper-tiny",
         "mixtral-8x7b", "gemma2-9b", "codeqwen1.5-7b",
         "llama-3.2-vision-90b", "recurrentgemma-2b", "grok-1-314b"]

BATCH, SEQ = 2, 16


def _reduced(name):
    cfg = cfgs.get_reduced(name)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    return cfg


def _inputs(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab),
        "labels": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab),
    }
    if cfg.cross_attn or cfg.encoder_layers:
        batch["encoder_out"] = jax.random.normal(
            key, (BATCH, max(cfg.encoder_seq, 4), cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = _reduced(name)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = transformer.forward(
        cfg, params, batch["tokens"], encoder_out=batch.get("encoder_out"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_decreases_loss_and_finite(name):
    cfg = _reduced(name)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(params):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True)(params)
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - 1e-2 * g, params, grads)
        return loss, new_params

    loss0, params = step(params)
    assert bool(jnp.isfinite(loss0)), f"{name}: non-finite loss"
    loss1, _ = step(params)
    assert bool(jnp.isfinite(loss1))
    # one SGD step on the same batch should not increase loss (sanity)
    assert float(loss1) <= float(loss0) + 1e-3, (name, loss0, loss1)


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(name):
    cfg = _reduced(name)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    caches = transformer.init_caches(cfg, BATCH, 64, dtype=jnp.float32)
    tok = batch["tokens"][:, :1]
    logits, new_caches = transformer.decode_step(
        cfg, params, tok, caches, jnp.asarray(0),
        encoder_out=batch.get("encoder_out"))
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # structure is stable across steps (required for lax.while_loop serving)
    jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


@pytest.mark.parametrize("name", ["h2o-danube-1.8b", "mixtral-8x7b",
                                  "xlstm-125m", "recurrentgemma-2b"])
def test_qat_forward(name):
    """QAT contexts thread through scanned stacks without shape drift."""
    cfg = _reduced(name)
    cfg = type(cfg)(**{**cfg.__dict__,
                       "quant": QuantConfig.qat(8, quant_delay=0)})
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    coll = transformer.init_qat_collection(cfg)
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    loss, metrics = transformer.loss_fn(cfg, params, batch,
                                        qat_collection=coll, step=0)
    assert bool(jnp.isfinite(loss))
    new_coll = metrics["qat_collection"]
    assert set(new_coll) == set(coll)


def test_full_configs_match_assignment():
    """The exact assigned dimensions, per the public-pool table."""
    expect = {
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    }
    for name, (nl, d, h, kv, f, v) in expect.items():
        cfg = cfgs.get(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (nl, d, h, kv, f, v), name
    # MoE extras
    assert cfgs.get("mixtral-8x7b").n_experts == 8
    assert cfgs.get("grok-1-314b").moe_top_k == 2
    # pattern lengths cover n_layers
    for name in expect:
        cfg = cfgs.get(name)
        assert (len(cfg.pattern) * cfg.pattern_repeats
                + len(cfg.pattern_remainder)) == cfg.n_layers


def test_param_counts_sane():
    """Analytic parameter counts are in the advertised ballpark."""
    approx = {
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "xlstm-125m": (0.8e8, 2.2e8),
        "stablelm-12b": (1.0e10, 1.5e10),
        "mixtral-8x7b": (4.2e10, 5.2e10),
        "gemma2-9b": (8e9, 1.15e10),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "llama-3.2-vision-90b": (7.5e10, 1.1e11),
        "recurrentgemma-2b": (1.8e9, 3.5e9),
        "grok-1-314b": (2.8e11, 3.4e11),
    }
    for name, (lo, hi) in approx.items():
        n = cfgs.get(name).n_params()
        assert lo <= n <= hi, (name, f"{n:.3e}")


@pytest.mark.parametrize("name", ["h2o-danube-1.8b", "gemma2-9b",
                                  "recurrentgemma-2b", "xlstm-125m",
                                  "mixtral-8x7b"])
def test_decode_matches_forward(name):
    """Teacher-forced decode reproduces the full forward pass logits —
    KV caches (incl. ring buffers) and recurrent state are consistent.

    MoE archs run with capacity_factor=4 (no token dropping) so the
    comparison isolates cache correctness — at production capacity factors
    batched forward and per-token decode drop different tokens (an inherent
    GShard train/serve skew, not a cache bug).
    """
    import dataclasses
    cfg = _reduced(name)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    enc = None
    if cfg.cross_attn or cfg.encoder_layers:
        enc = jax.random.normal(jax.random.PRNGKey(2),
                                (1, max(cfg.encoder_seq, 4),
                                 cfg.d_model)) * 0.02
    full_logits, _, _ = transformer.forward(cfg, params, toks,
                                            encoder_out=enc)
    caches = transformer.init_caches(cfg, 1, 12, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c, pos: transformer.decode_step(
        cfg, p, t, c, pos, encoder_out=enc))
    for t in range(12):
        logits, caches = step(params, toks[:, t:t + 1], caches,
                              jnp.asarray(t))
        np.testing.assert_allclose(
            np.asarray(logits[0, 0]), np.asarray(full_logits[0, t]),
            rtol=2e-2, atol=2e-2, err_msg=f"{name} step {t}")


def test_int8_kv_cache_decode_close_to_fp():
    """int8 cache decode ~ fp cache decode (paper's small-noise regime)."""
    import dataclasses
    from repro.core.qconfig import QuantConfig
    cfg = _reduced("h2o-danube-1.8b")
    cfg8 = dataclasses.replace(cfg, quant=dataclasses.replace(
        QuantConfig.none(), int8_kv_cache=True))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    out = {}
    for tag, c in (("fp", cfg), ("int8", cfg8)):
        caches = transformer.init_caches(c, 1, 8, dtype=jnp.float32)
        logits = None
        for t in range(8):
            logits, caches = transformer.decode_step(
                c, params, toks[:, t:t + 1], caches, jnp.asarray(t))
        out[tag] = np.asarray(logits)
    corr = np.corrcoef(out["fp"].ravel(), out["int8"].ravel())[0, 1]
    assert corr > 0.99, corr
