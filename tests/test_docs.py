"""Docs tree gate: the link checker passes and the tree is complete."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_doc_links  # noqa: E402


def test_docs_tree_exists():
    for name in ("architecture.md", "serving.md", "contracts.md",
                 "checkpointing.md", "resilience.md"):
        assert (ROOT / "docs" / name).is_file(), f"docs/{name} missing"


def test_all_doc_links_resolve():
    errors = check_doc_links.check(ROOT)
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_link(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "a.md").write_text(
        "# A\n\nsee [b](missing.md) and [c](a.md#no-such-anchor)\n")
    errors = check_doc_links.check(tmp_path)
    assert len(errors) == 2
    assert any("broken link" in e for e in errors)
    assert any("missing anchor" in e for e in errors)


def test_checker_skips_external_and_code(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "a.md").write_text(
        "# A\n\n[x](https://example.com) [badge](../../actions/foo.svg)\n"
        "`[not a link](nope.md)`\n\n```\n[also not](gone.md)\n```\n")
    assert check_doc_links.check(tmp_path) == []


def test_cli_exit_status():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_links.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr + proc.stdout
    assert "0 broken link(s)" in proc.stdout
