"""Property-based tests for the prioritized replay sum-tree (ISSUE 3).

Replay invariants are exactly what example-based tests miss, so the whole
surface is driven by hypothesis (via ``tests/hypcompat.py`` — property
tests skip cleanly where hypothesis is not installed) plus deterministic
anchors that always run:

* total priority mass equals the root after arbitrary add/update sequences,
* the sampled index distribution matches the normalized priorities
  (chi-squared tolerance),
* ``per_sample`` never returns an unwritten slot, at any fill level,
* sharded stack/unstack round-trips preserve the trees bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st  # guarded hypothesis import

from repro.rl import buffer as rb


def _transitions(n, obs_dim=3, offset=0):
    r = np.arange(offset, offset + n, dtype=np.float32)
    return rb.Transition(
        obs=jnp.asarray(np.tile(r[:, None], (1, obs_dim))),
        action=jnp.asarray(r.astype(np.int32)),
        reward=jnp.asarray(r),
        done=jnp.zeros((n,), jnp.float32),
        next_obs=jnp.asarray(np.tile(r[:, None] + 0.5, (1, obs_dim))))


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# mass conservation: root == sum of leaves == oracle, under arbitrary ops
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(3, 17),
       st.lists(st.one_of(
           st.tuples(st.just("add"), st.integers(1, 7)),
           st.tuples(st.just("update"),
                     st.lists(st.tuples(st.integers(0, 30),
                                        st.floats(0.0, 10.0)),
                              min_size=1, max_size=5))),
           min_size=1, max_size=12))
def test_total_mass_equals_root_after_arbitrary_ops(capacity, ops):
    """Numpy oracle replays the same op sequence; root must track it."""
    state = rb.per_init(capacity, (3,))
    tsize = state.tree.shape[0] // 2
    oracle = np.zeros(tsize, np.float64)
    max_p, cursor, written = 1.0, 0, 0
    for op, arg in ops:
        if op == "add":
            state = rb.per_add(state, _transitions(arg, offset=cursor))
            for j in range(arg):
                oracle[(cursor + j) % capacity] = max_p
            cursor += arg
            written = min(written + arg, capacity)
        else:
            # only update slots that exist (the learner only ever pushes
            # priorities for indices it sampled, i.e. written ones)
            if written == 0:
                continue
            idx = np.asarray([i % written for i, _ in arg], np.int32)
            td = np.asarray([t for _, t in arg], np.float32)
            # duplicate indices must carry equal values (the PER contract:
            # duplicates in a batch are the same transition / same TD)
            seen = {}
            for k, i in enumerate(idx):
                td[k] = seen.setdefault(int(i), td[k])
            state = rb.per_update_priorities(state, jnp.asarray(idx),
                                             jnp.asarray(td), 0.6)
            p = (np.abs(td) + 1e-6) ** 0.6
            oracle[idx] = p
            max_p = max(max_p, float(p.max()))
    root = float(rb.sum_tree_total(state.tree))
    leaves = np.asarray(rb.sum_tree_leaves(state.tree), np.float64)
    np.testing.assert_allclose(root, leaves.sum(), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(leaves, oracle, rtol=1e-4, atol=1e-5)
    assert int(state.replay.size) == written


def test_total_mass_anchor_deterministic():
    """Non-hypothesis anchor for containers without hypothesis installed."""
    state = rb.per_init(10, (3,))
    state = rb.per_add(state, _transitions(7))
    assert np.isclose(float(rb.sum_tree_total(state.tree)), 7.0)
    state = rb.per_update_priorities(
        state, jnp.asarray([0, 3, 6]), jnp.asarray([2.0, 0.25, 1.0]), 0.6)
    leaves = np.asarray(rb.sum_tree_leaves(state.tree))
    np.testing.assert_allclose(float(rb.sum_tree_total(state.tree)),
                               leaves.sum(), rtol=1e-6)
    want = (np.abs([2.0, 0.25, 1.0]) + 1e-6) ** 0.6
    np.testing.assert_allclose(leaves[[0, 3, 6]], want, rtol=1e-5)
    # wrap-around: 5 more adds overwrite slots 7,8,9,0,1 at max_priority
    state = rb.per_add(state, _transitions(5, offset=7))
    leaves = np.asarray(rb.sum_tree_leaves(state.tree))
    np.testing.assert_allclose(float(rb.sum_tree_total(state.tree)),
                               leaves.sum(), rtol=1e-6)
    mp = float(state.max_priority)
    np.testing.assert_allclose(leaves[[7, 8, 9, 0, 1]], mp, rtol=1e-6)


# ---------------------------------------------------------------------------
# sampled distribution matches normalized priorities (chi-squared)
# ---------------------------------------------------------------------------

def _chi_squared(counts, probs):
    n = counts.sum()
    expected = probs * n
    mask = expected > 0
    return float(((counts[mask] - expected[mask]) ** 2
                  / expected[mask]).sum())


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(0.05, 5.0), min_size=4, max_size=12),
       st.integers(0, 2 ** 31 - 1))
def test_sample_distribution_matches_priorities(priorities, seed):
    state = rb.per_init(len(priorities), (2,))
    state = rb.per_add(state, _transitions(len(priorities), obs_dim=2))
    td = jnp.asarray(priorities, jnp.float32)
    # alpha=1 so the tree holds (p + eps) directly
    state = rb.per_update_priorities(
        state, jnp.arange(len(priorities)), td, 1.0)
    n_samples = 40_000
    _, idx, _ = rb.per_sample(state, jax.random.PRNGKey(seed), n_samples,
                              1.0)
    counts = np.bincount(np.asarray(idx), minlength=len(priorities))
    leaves = np.asarray(rb.sum_tree_leaves(state.tree))[:len(priorities)]
    probs = leaves / leaves.sum()
    # chi-squared 99.9%-ile for df <= 11 is < 32; allow slack for the
    # float32 tree
    assert _chi_squared(counts.astype(np.float64), probs) < 45.0, (
        counts, probs)


def test_sample_distribution_anchor_deterministic():
    state = rb.per_init(8, (2,))
    state = rb.per_add(state, _transitions(8, obs_dim=2))
    td = jnp.asarray([8.0, 4.0, 2.0, 1.0, 1.0, 2.0, 4.0, 8.0])
    state = rb.per_update_priorities(state, jnp.arange(8), td, 1.0)
    _, idx, _ = rb.per_sample(state, jax.random.PRNGKey(0), 60_000, 1.0)
    counts = np.bincount(np.asarray(idx), minlength=8)
    leaves = np.asarray(rb.sum_tree_leaves(state.tree))[:8]
    probs = leaves / leaves.sum()
    assert _chi_squared(counts.astype(np.float64), probs) < 40.0, (
        counts / counts.sum(), probs)
    # the sampled batch carries the right transitions for its indices
    batch, idx, _ = rb.per_sample(state, jax.random.PRNGKey(1), 64, 1.0)
    np.testing.assert_array_equal(np.asarray(batch.action),
                                  np.asarray(idx))


def test_is_weights_uniform_at_equal_priorities_and_beta_scaling():
    state = rb.per_init(8, (2,))
    state = rb.per_add(state, _transitions(8, obs_dim=2))
    state = rb.per_update_priorities(state, jnp.arange(8),
                                     jnp.ones((8,)), 1.0)
    _, _, w = rb.per_sample(state, jax.random.PRNGKey(0), 32, 0.7)
    np.testing.assert_allclose(np.asarray(w), 1.0, rtol=1e-5)
    # skewed priorities: rarer transitions get larger IS weights
    state = rb.per_update_priorities(
        state, jnp.arange(8),
        jnp.asarray([9.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]), 1.0)
    _, idx, w = rb.per_sample(state, jax.random.PRNGKey(1), 256, 1.0)
    idx, w = np.asarray(idx), np.asarray(w)
    if (idx == 0).any() and (idx != 0).any():
        assert w[idx == 0].max() < w[idx != 0].min()


# ---------------------------------------------------------------------------
# no unwritten slots, at any fill level
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_per_sample_never_returns_unwritten_slot(capacity, n_add, seed):
    state = rb.per_init(capacity, (3,))
    state = rb.per_add(state, _transitions(n_add))
    size = int(state.replay.size)
    assert size == min(n_add, capacity)
    _, idx, w = rb.per_sample(state, jax.random.PRNGKey(seed), 64, 0.4)
    idx = np.asarray(idx)
    assert (idx >= 0).all() and (idx < size).all(), (idx, size)
    assert np.isfinite(np.asarray(w)).all()


def test_per_sample_unwritten_anchor_deterministic():
    for n_add in (1, 3, 5):
        state = rb.per_init(8, (3,))
        state = rb.per_add(state, _transitions(n_add))
        for seed in range(4):
            _, idx, _ = rb.per_sample(state, jax.random.PRNGKey(seed),
                                      128, 0.4)
            assert np.asarray(idx).max() < n_add
    # empty buffer: clamped to slot 0, finite weights (warmup discards it)
    state = rb.per_init(8, (3,))
    _, idx, w = rb.per_sample(state, jax.random.PRNGKey(0), 16, 0.4)
    assert (np.asarray(idx) == 0).all()
    assert np.isfinite(np.asarray(w)).all()


# ---------------------------------------------------------------------------
# sharded stack/unstack round-trips preserve trees
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.lists(st.integers(0, 9), min_size=1,
                                   max_size=4))
def test_sharded_stack_unstack_round_trip(n_shards, fills):
    shards = []
    for i in range(n_shards):
        s = rb.per_init(8, (3,))
        n = fills[i % len(fills)]
        if n:
            s = rb.per_add(s, _transitions(n, offset=10 * i))
            s = rb.per_update_priorities(
                s, jnp.zeros((1,), jnp.int32),
                jnp.full((1,), 1.0 + i), 0.6)
        shards.append(s)
    stacked = rb.per_stack(shards)
    assert stacked.replay.size.shape == (n_shards,)
    assert stacked.tree.shape == (n_shards, 2 * 8)
    back = rb.per_unstack(stacked)
    assert len(back) == n_shards
    for orig, got in zip(shards, back):
        for a, b in zip(_leaves(orig), _leaves(got)):
            np.testing.assert_array_equal(a, b)
    # per-shard roots survive the round trip through the stacked layout
    for i, orig in enumerate(shards):
        np.testing.assert_array_equal(
            np.asarray(stacked.tree[i, 1]),
            np.asarray(rb.sum_tree_total(orig.tree)))


def test_sharded_ops_match_independent_shards():
    """vmap'd sharded ops == running each shard's ops independently."""
    sharded = rb.per_init_sharded(2, 8, (3,))
    batch = jax.tree_util.tree_map(
        lambda a, b: jnp.stack([a, b]), _transitions(5), _transitions(5, offset=5))
    sharded = rb.per_add_sharded(sharded, batch)
    idx = jnp.asarray([[0, 2], [1, 3]])
    td = jnp.asarray([[1.0, 2.0], [3.0, 0.5]])
    sharded = rb.per_update_priorities_sharded(sharded, idx, td, 0.6)
    for i in range(2):
        solo = rb.per_init(8, (3,))
        solo = rb.per_add(
            solo, jax.tree_util.tree_map(lambda x, i=i: x[i], batch))
        solo = rb.per_update_priorities(solo, idx[i], td[i], 0.6)
        got = jax.tree_util.tree_map(lambda x, i=i: x[i], sharded)
        for a, b in zip(_leaves(solo), _leaves(got)):
            np.testing.assert_array_equal(a, b)
