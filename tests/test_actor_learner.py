"""Actor–learner topology tests (ISSUE 2 acceptance contract).

* parity — a single-actor actor–learner run with ``sync_every=1`` is
  bitwise identical to the fused ``loops.train`` driver for DQN (same
  seeds -> same params, same recorded rewards),
* int8 conv compute (im2col through the W8A8 kernel) agrees with the
  fake-quant conv simulation within the ``test_actorq.py`` tolerance,
* the sharded replay layout round-trips,
* DDPG/PPO rollout collection accepts ``actor_backend="int8"`` and stays
  finite on the smoke envs,
* multi-actor runs populate per-actor divergence metrics and honour the
  ``sync_every`` staleness knob.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import actor_learner, actorq, dqn, loops
from repro.rl import buffer as rb
from repro.rl.envs import make as make_env
from repro.rl.networks import make_network

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_DQN = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                 buffer_size=512, batch_size=16, warmup=8)
SMALL_DDPG = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                  buffer_size=512, batch_size=16, warmup=8)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# parity: 1 actor + sync_every=1 == the fused driver
# ---------------------------------------------------------------------------

def test_single_actor_parity_with_fused_dqn():
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=7,
              algo_overrides=dict(SMALL_DQN))
    fused = loops.train("dqn", "cartpole", **kw)
    al = loops.train("dqn", "cartpole", topology="actor-learner",
                     num_actors=1, sync_every=1, **kw)
    for a, b in zip(_leaves(fused.state.params), _leaves(al.state.params)):
        np.testing.assert_array_equal(a, b)
    assert fused.rewards == al.rewards
    # learner extras line up too (target net, update counter)
    for a, b in zip(_leaves(fused.state.extras.target_params),
                    _leaves(al.state.extras.target_params)):
        np.testing.assert_array_equal(a, b)
    assert int(fused.state.extras.updates) == int(al.state.extras.updates)


def test_single_actor_parity_survives_scan_fused_driver():
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=11,
              algo_overrides=dict(SMALL_DQN))
    fused = loops.train("dqn", "cartpole", steps_per_call=1, **kw)
    al = loops.train("dqn", "cartpole", topology="actor-learner",
                     num_actors=1, sync_every=1, steps_per_call=3, **kw)
    for a, b in zip(_leaves(fused.state.params), _leaves(al.state.params)):
        np.testing.assert_array_equal(a, b)
    assert fused.rewards == al.rewards


# ---------------------------------------------------------------------------
# multi-actor topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env,overrides", [
    ("dqn", "cartpole", SMALL_DQN),
    ("ddpg", "pendulum", SMALL_DDPG),
])
def test_multi_actor_int8_trains_finite(algo, env, overrides):
    res = loops.train(algo, env, topology="actor-learner", num_actors=2,
                      sync_every=2, actor_backend="int8", iterations=4,
                      record_every=2, eval_episodes=2, seed=3,
                      algo_overrides=dict(overrides))
    assert all(np.isfinite(res.rewards))
    # per-actor divergence recorded at every record point
    assert len(res.divergences) == 2
    assert all(len(d) == 2 for d in res.divergences)
    assert all(np.isfinite(d).all() for d in res.divergences)
    # int8 actors genuinely diverge from the fp32 learner head
    assert any(v > 0 for d in res.divergences for v in d)


def test_sync_every_staleness_contract():
    """Actors keep the stale copy between syncs; a sync point pushes the
    learner's fresh params bitwise."""
    env = make_env("cartpole")
    cfg = dqn.DQNConfig(**dict(SMALL_DQN, warmup=1))
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=2, sync_every=3)
    state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                               cfg, al)
    iteration, _, benv = actor_learner.make_actor_learner(
        "dqn", env, net, cfg, al)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    p0 = _leaves(state.actor_params)
    key = jax.random.PRNGKey(2)
    for t in range(1, 4):
        key, k = jax.random.split(key)
        state, env_state, obs, _ = iteration(state, env_state, obs, k)
        actors = _leaves(state.actor_params)
        learner = _leaves(state.learner.params)
        if t < 3:    # no sync yet: actors still run the init-time params
            for a, b in zip(actors, p0):
                np.testing.assert_array_equal(a, b)
            assert any(not np.array_equal(a, b)
                       for a, b in zip(actors, learner))
        else:        # t == sync_every: fresh learner params pushed bitwise
            for a, b in zip(actors, learner):
                np.testing.assert_array_equal(a, b)


def test_divergence_recorded_only_at_true_pushes():
    """Staleness-contract regression: with ``sync_every=K`` the first real
    param push happens at iteration K — record points before it must NOT
    emit the init-time zero divergence sample (the actors hold a fresh
    copy at t=0 by construction; that is not a sync)."""
    res = loops.train("dqn", "cartpole", topology="actor-learner",
                      num_actors=2, sync_every=4, actor_backend="int8",
                      iterations=8, record_every=2, eval_episodes=2,
                      seed=3, algo_overrides=dict(SMALL_DQN))
    # record points at i = 2, 4, 6, 8; pushes at t = 4, 8 -> the i=2
    # sample (pre-first-push zeros) is skipped
    assert len(res.divergences) == 3
    # every recorded sample comes from a true push of int8-packed params
    assert all(any(v > 0 for v in d) for d in res.divergences)


def test_int8_cache_is_bitwise_stable_between_syncs():
    """Repack-gating regression: the packed int8 actor cache is carried in
    state and repacked under ``lax.cond`` only at sync points — between
    pushes the actor params are unchanged, so the cache must be bitwise
    identical; the sync at t=K repacks from the freshly-pushed params."""
    env = make_env("cartpole")
    cfg = dqn.DQNConfig(**dict(SMALL_DQN, warmup=1, actor_backend="int8"))
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=2, sync_every=3)
    state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                               cfg, al)
    iteration, _, benv = actor_learner.make_actor_learner(
        "dqn", env, net, cfg, al)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    cache0 = _leaves(state.actor_cache)
    key = jax.random.PRNGKey(2)
    for t in range(1, 4):
        key, k = jax.random.split(key)
        state, env_state, obs, _ = iteration(state, env_state, obs, k)
        cache_t = _leaves(state.actor_cache)
        if t < 3:    # no sync yet: the carried cache is bitwise-stable
            for a, b in zip(cache_t, cache0):
                np.testing.assert_array_equal(a, b)
        else:        # t == sync_every: repacked from the pushed params
            assert any(not np.array_equal(a, b)
                       for a, b in zip(cache_t, cache0))
            # and it matches a fresh pack of the synced actor params
            fresh = _leaves(actorq.pack_actor_params(state.actor_params))
            for a, b in zip(cache_t, fresh):
                np.testing.assert_array_equal(a, b)


def test_fp32_divergence_is_pure_staleness():
    # with sync_every=1 and fp32 actors, the behaviour head IS the fresh
    # learner head -> divergence identically zero
    res = loops.train("dqn", "cartpole", topology="actor-learner",
                      num_actors=2, sync_every=1, iterations=4,
                      record_every=2, eval_episodes=2, seed=0,
                      algo_overrides=dict(SMALL_DQN))
    assert all(v == 0.0 for d in res.divergences for v in d)


def test_actor_learner_rejects_on_policy_algos():
    with pytest.raises(ValueError):
        loops.train("ppo", "cartpole", topology="actor-learner",
                    iterations=2)
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", topology="ring", iterations=2)
    # topology knobs are meaningless under the fused driver — loud error
    # instead of silently ignoring them
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", num_actors=4, iterations=2)
    # divisibility contracts surface as ValueError, not bare asserts
    with pytest.raises(ValueError):
        loops.train("dqn", "cartpole", topology="actor-learner",
                    num_actors=3, iterations=2,
                    algo_overrides=dict(SMALL_DQN))


@pytest.mark.slow
def test_actor_learner_eight_device_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import contextlib
        import jax, jax.numpy as jnp, numpy as np
        from repro.rl import actor_learner, dqn
        from repro.rl.envs import make as make_env
        from repro.rl.networks import make_network

        def mesh_ctx(mesh):
            for name in ("set_mesh", "use_mesh"):
                if hasattr(jax.sharding, name):
                    return getattr(jax.sharding, name)(mesh)
            return contextlib.nullcontext()

        env = make_env("cartpole")
        cfg = dqn.DQNConfig(n_envs=4, rollout_steps=4, updates_per_iter=2,
                            buffer_size=1024, batch_size=32, warmup=16,
                            actor_backend="int8", kernel_backend="ref")
        net = make_network(env.spec.obs_shape, env.spec.n_actions)
        al = actor_learner.ActorLearnerConfig(num_actors=8, sync_every=2)
        mesh = jax.make_mesh((8,), ("actor",))
        state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                                   cfg, al)
        iteration, act_fn, benv = actor_learner.make_actor_learner(
            "dqn", env, net, cfg, al, mesh=mesh)
        env_state, obs = benv.reset(jax.random.PRNGKey(1))
        key = jax.random.PRNGKey(2)
        with mesh_ctx(mesh):
            for i in range(4):
                key, k = jax.random.split(key)
                state, env_state, obs, m = iteration(state, env_state, obs,
                                                     k)
                assert jnp.isfinite(m["loss"]), m
        assert state.divergence.shape == (8,)
        assert np.isfinite(np.asarray(state.divergence)).all()
        print("ACTOR_LEARNER_MESH_OK", float(m["loss"]))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ACTOR_LEARNER_MESH_OK" in out.stdout


# ---------------------------------------------------------------------------
# int8 conv compute (im2col through the W8A8 kernel)
# ---------------------------------------------------------------------------

def _fake_quant_outputs(net, params, obs):
    from repro.core import ptq
    from repro.core.fake_quant import NullQATContext
    from repro.core.qconfig import QuantConfig
    sim = ptq.ptq_simulate(params, QuantConfig.ptq_int(8))
    return net.apply(NullQATContext(), sim, obs)


def test_int8_conv_matches_fake_quant_conv():
    net = make_network((6, 6, 2), 3, conv_filters=(8, 8), fc_width=32)
    params = net.init(jax.random.PRNGKey(2))
    obs = jax.random.normal(jax.random.PRNGKey(3), (5, 6, 6, 2))
    want = _fake_quant_outputs(net, params, obs)
    got = actorq.quantized_apply(actorq.pack_actor_params(params), obs,
                                 backend="ref")
    np.testing.assert_allclose(got, want, atol=1e-2)


def test_int8_conv_interpret_kernel_matches_ref():
    net = make_network((5, 5, 2), 2, conv_filters=(4,), fc_width=16)
    params = net.init(jax.random.PRNGKey(4))
    qp = actorq.pack_actor_params(params)
    obs = jax.random.normal(jax.random.PRNGKey(5), (3, 5, 5, 2))
    ref = actorq.quantized_apply(qp, obs, backend="ref")
    interp = actorq.quantized_apply(qp, obs, backend="interpret")
    np.testing.assert_allclose(interp, ref, rtol=1e-5, atol=1e-5)


def test_int8_conv_unpacked_weights_fall_back_to_fp32():
    # partially-packed trees (fp32 conv leaves) still compute correctly
    layer = {"w": jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 4)),
             "b": jnp.zeros((4,))}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 6, 2))
    y = actorq.int8_conv2d(layer, x, backend="ref")
    want = jax.nn.relu(jax.lax.conv_general_dilated(
        x, layer["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded replay
# ---------------------------------------------------------------------------

def _fill(state, key, n, obs_dim=3):
    batch = rb.Transition(
        obs=jax.random.normal(key, (n, obs_dim)),
        action=jnp.arange(n, dtype=jnp.int32),
        reward=jnp.arange(n, dtype=jnp.float32),
        done=jnp.zeros((n,)),
        next_obs=jax.random.normal(key, (n, obs_dim)))
    return rb.replay_add_batch(state, batch), batch


def test_replay_sharding_round_trip():
    shards = []
    for i in range(4):
        s = rb.replay_init(8, (3,))
        s, _ = _fill(s, jax.random.PRNGKey(i), 5)
        shards.append(s)
    stacked = rb.replay_stack(shards)
    assert stacked.size.shape == (4,)
    back = rb.replay_unstack(stacked)
    for orig, got in zip(shards, back):
        for a, b in zip(_leaves(orig), _leaves(got)):
            np.testing.assert_array_equal(a, b)
    assert int(rb.replay_total_size(stacked)) == 4 * 5


def test_sharded_add_matches_independent_shards():
    sharded = rb.replay_init_sharded(2, 8, (3,))
    batch = rb.Transition(
        obs=jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3)),
        action=jnp.stack([jnp.arange(5), 10 + jnp.arange(5)]
                         ).astype(jnp.int32),
        reward=jnp.ones((2, 5)), done=jnp.zeros((2, 5)),
        next_obs=jax.random.normal(jax.random.PRNGKey(1), (2, 5, 3)))
    sharded = rb.replay_add_sharded(sharded, batch)
    for i in range(2):
        solo = rb.replay_init(8, (3,))
        solo = rb.replay_add_batch(
            solo, jax.tree_util.tree_map(lambda x, i=i: x[i], batch))
        got = jax.tree_util.tree_map(lambda x, i=i: x[i], sharded)
        for a, b in zip(_leaves(solo), _leaves(got)):
            np.testing.assert_array_equal(a, b)


def test_sharded_sample_draws_from_own_shard():
    sharded = rb.replay_init_sharded(2, 8, (1,))
    batch = rb.Transition(
        obs=jnp.stack([jnp.zeros((4, 1)), jnp.ones((4, 1))]),
        action=jnp.zeros((2, 4), jnp.int32),
        reward=jnp.stack([jnp.zeros(4), jnp.ones(4)]),
        done=jnp.zeros((2, 4)),
        next_obs=jnp.stack([jnp.zeros((4, 1)), jnp.ones((4, 1))]))
    sharded = rb.replay_add_sharded(sharded, batch)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    out = rb.replay_sample_sharded(sharded, keys, 16)
    assert out.reward.shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(out.reward[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out.reward[1]), 1.0)


# ---------------------------------------------------------------------------
# DDPG / PPO int8 rollout collection (fused loop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env,overrides", [
    ("ddpg", "pendulum", SMALL_DDPG),
    ("ppo", "cartpole", dict(n_envs=4, n_steps=8)),
])
def test_int8_rollout_collection_trains_finite(algo, env, overrides):
    res = loops.train(algo, env, iterations=4, record_every=2,
                      eval_episodes=2, actor_backend="int8",
                      algo_overrides=dict(overrides))
    assert all(np.isfinite(res.rewards))
    assert res.algo_cfg.actor_backend == "int8"


@pytest.mark.slow
@pytest.mark.parametrize("algo,env,overrides,check", [
    # pendulum rewards are large negatives; require clear improvement over
    # the first record (fp32 training follows the same trajectory)
    ("ddpg", "pendulum", dict(n_envs=8, warmup=64),
     lambda r: max(r) > r[0] + 100.0),
    ("ppo", "cartpole", dict(), lambda r: max(r) > 50.0),
])
def test_int8_rollout_collection_converges(algo, env, overrides, check):
    """ISSUE acceptance: int8 rollout collection converges on smoke envs."""
    res = loops.train(algo, env, iterations=120, record_every=40,
                      eval_episodes=8, seed=0, actor_backend="int8",
                      algo_overrides=dict(overrides))
    assert check(res.rewards), res.rewards


# ---------------------------------------------------------------------------
# behaviour-policy builders stay consistent with the fused iteration
# ---------------------------------------------------------------------------

def test_dqn_behaviour_policy_builder_matches_q_head():
    env = make_env("cartpole")
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    cfg = dqn.DQNConfig(eps_start=0.0, eps_end=0.0)
    params = net.init(jax.random.PRNGKey(0))
    build = dqn.make_behaviour_policy(env, net, cfg)
    policy = build(params, {}, jnp.zeros((), jnp.int32),
                   jnp.zeros((), jnp.int32))
    obs = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    action, q = policy(None, obs, jax.random.PRNGKey(2))
    from repro.rl.common import make_ctx
    from repro.core.qconfig import QuantConfig
    q_want = net.apply(make_ctx(QuantConfig.none(), {}, 0), params, obs)
    np.testing.assert_allclose(q, q_want, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(action),
                                  np.asarray(jnp.argmax(q_want, -1)))
