"""Prioritized replay wiring tests (ISSUE 3 acceptance contract).

* ``priority_exponent=0.0`` parity — ``replay="prioritized"`` with a zero
  exponent is *bitwise identical* to ``replay="uniform"`` for DQN and DDPG
  under both topologies, including the scan-fused driver and int8 actors
  (the wiring statically dispatches alpha=0 onto the uniform path, the
  same by-construction contract as ``num_actors=1, sync_every=1``),
* seed determinism — identical seeds give identical ``TrainResult``
  (params, rewards, divergences) for ``kernel_backend`` in
  {ref, interpret}: the while/fori-loop tree sampling draws every bit from
  the traced PRNG chain, no hidden host-side RNG,
* prioritized sampling genuinely changes (and on sparse-reward Catch,
  accelerates) learning — the slow-marked convergence test,
* the sharded trees run inside an 8-device shard_map (slow, subprocess).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import common, ddpg, dqn, loops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_DQN = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                 buffer_size=512, batch_size=16, warmup=8)
SMALL_DDPG = dict(n_envs=4, rollout_steps=4, updates_per_iter=2,
                  buffer_size=512, batch_size=16, warmup=8)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_bitwise_equal(a: loops.TrainResult, b: loops.TrainResult):
    for x, y in zip(_leaves(a.state.params), _leaves(b.state.params)):
        np.testing.assert_array_equal(x, y)
    assert a.rewards == b.rewards
    assert a.divergences == b.divergences


# ---------------------------------------------------------------------------
# alpha=0 parity: prioritized degrades to bitwise-uniform
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,env,overrides,topo_kw,extra", [
    ("dqn", "cartpole", SMALL_DQN, {}, {}),
    ("dqn", "cartpole", SMALL_DQN,
     dict(topology="actor-learner", num_actors=2, sync_every=2), {}),
    ("ddpg", "pendulum", SMALL_DDPG, {}, {}),
    ("ddpg", "pendulum", SMALL_DDPG,
     dict(topology="actor-learner", num_actors=2, sync_every=2), {}),
    # scan-fused driver + int8 actors keep the contract
    ("dqn", "cartpole", SMALL_DQN,
     dict(topology="actor-learner", num_actors=2, sync_every=1),
     dict(steps_per_call=3, actor_backend="int8")),
    ("ddpg", "pendulum", SMALL_DDPG, {},
     dict(steps_per_call=3, actor_backend="int8")),
])
def test_priority_exponent_zero_is_bitwise_uniform(algo, env, overrides,
                                                   topo_kw, extra):
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=13,
              algo_overrides=dict(overrides), **topo_kw, **extra)
    uniform = loops.train(algo, env, replay="uniform", **kw)
    alpha0 = loops.train(algo, env, replay="prioritized",
                         priority_exponent=0.0, **kw)
    _assert_bitwise_equal(uniform, alpha0)


def test_priority_exponent_nonzero_changes_sampling():
    """Sanity counterpart: alpha > 0 must NOT match the uniform run."""
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=13,
              algo_overrides=dict(SMALL_DQN, warmup=8))
    uniform = loops.train("dqn", "cartpole", replay="uniform", **kw)
    per = loops.train("dqn", "cartpole", replay="prioritized",
                      priority_exponent=0.6, **kw)
    assert any(not np.array_equal(x, y) for x, y in
               zip(_leaves(uniform.state.params),
                   _leaves(per.state.params)))


def test_prioritized_state_carries_sum_tree():
    from repro.rl import buffer as rb
    res = loops.train("dqn", "cartpole", replay="prioritized",
                      iterations=4, record_every=2, eval_episodes=2,
                      seed=0, algo_overrides=dict(SMALL_DQN))
    per = res.state.extras.replay
    assert isinstance(per, rb.PrioritizedReplayState)
    root = float(rb.sum_tree_total(per.tree))
    leaves = np.asarray(rb.sum_tree_leaves(per.tree))
    assert root > 0 and np.isfinite(leaves).all()
    np.testing.assert_allclose(root, leaves.sum(), rtol=1e-4)
    # priorities were actually pushed: not all leaves still at max_priority
    written = leaves[:int(per.replay.size)]
    assert len(np.unique(np.round(written, 6))) > 1


# ---------------------------------------------------------------------------
# IS-beta anneal: counted in learner updates, not iterations/attempts
# ---------------------------------------------------------------------------

def _dqn_state_with_updates(n: int) -> common.TrainState:
    extras = dqn.DQNExtras(target_params=(), replay=(),
                           updates=jnp.asarray(n, jnp.int32))
    # step deliberately out of sync with updates: the anneal must ignore it
    return common.TrainState(params=(), opt=(), observers={},
                             step=jnp.asarray(10 * n + 999, jnp.int32),
                             extras=extras)


def test_is_beta_anneals_on_learner_update_counter():
    """Annealing-bug regression: beta is a function of the learner-update
    counter carried in state — NOT of iterations or attempted calls — so
    it reaches 1.0 at exactly ``is_beta_anneal_updates`` landed updates,
    whatever the driver (per-step, scan-fused, async) did to get there."""
    cfg = dqn.DQNConfig(is_beta=0.4, is_beta_anneal_updates=100)
    assert float(common.per_beta(_dqn_state_with_updates(0), cfg)) \
        == np.float32(0.4)
    mid = float(common.per_beta(_dqn_state_with_updates(50), cfg))
    np.testing.assert_allclose(mid, 0.7, rtol=1e-6)
    assert float(common.per_beta(_dqn_state_with_updates(100), cfg)) == 1.0
    # saturates, never overshoots
    assert float(common.per_beta(_dqn_state_with_updates(250), cfg)) == 1.0


def test_beta_schedule_ignores_warmup_discarded_updates():
    """Warmup calls revert their parameter update and must not advance the
    anneal: with an unreachable warmup the updates counter stays 0 and
    beta stays at is_beta."""
    kw = dict(iterations=3, record_every=3, eval_episodes=2, seed=0)
    res = loops.train("dqn", "cartpole", replay="prioritized",
                      algo_overrides=dict(SMALL_DQN, warmup=10 ** 6), **kw)
    assert int(res.state.extras.updates) == 0
    assert float(common.per_beta(res.state, res.algo_cfg)) \
        == np.float32(res.algo_cfg.is_beta)
    # past warmup the counter counts exactly the landed updates
    res2 = loops.train("dqn", "cartpole", replay="prioritized",
                       algo_overrides=dict(SMALL_DQN), **kw)
    assert int(res2.state.extras.updates) \
        == 3 * SMALL_DQN["updates_per_iter"]


def test_ddpg_carries_learner_update_counter():
    """DDPG's extras now carry the same warm-gated update counter DQN has
    (it drives per_beta and the async staleness accounting)."""
    kw = dict(iterations=3, record_every=3, eval_episodes=2, seed=0)
    res = loops.train("ddpg", "pendulum",
                      algo_overrides=dict(SMALL_DDPG, warmup=10 ** 6),
                      **kw)
    assert int(res.state.extras.updates) == 0
    res2 = loops.train("ddpg", "pendulum",
                       algo_overrides=dict(SMALL_DDPG), **kw)
    assert int(res2.state.extras.updates) \
        == 3 * SMALL_DDPG["updates_per_iter"]
    assert isinstance(res2.state.extras, ddpg.DDPGExtras)


def test_beta_anneal_is_driver_independent():
    """The same config must land the same beta whether driven per-step or
    scan-fused — the schedule depends only on landed learner updates."""
    kw = dict(iterations=6, record_every=3, eval_episodes=2, seed=13,
              replay="prioritized", algo_overrides=dict(SMALL_DQN))
    per_step = loops.train("dqn", "cartpole", steps_per_call=1, **kw)
    fused = loops.train("dqn", "cartpole", steps_per_call=3, **kw)
    assert int(per_step.state.extras.updates) \
        == int(fused.state.extras.updates)
    assert float(common.per_beta(per_step.state, per_step.algo_cfg)) \
        == float(common.per_beta(fused.state, fused.algo_cfg))


# ---------------------------------------------------------------------------
# seed determinism: no hidden host-side RNG in the tree sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel_backend", ["ref", "interpret"])
def test_seed_determinism_across_backends(kernel_backend):
    kw = dict(iterations=4, record_every=2, eval_episodes=2, seed=5,
              replay="prioritized", topology="actor-learner", num_actors=2,
              sync_every=2, actor_backend="int8",
              algo_overrides=dict(SMALL_DQN,
                                  kernel_backend=kernel_backend))
    a = loops.train("dqn", "cartpole", **kw)
    b = loops.train("dqn", "cartpole", **kw)
    _assert_bitwise_equal(a, b)
    for x, y in zip(_leaves(a.state.extras.replay),
                    _leaves(b.state.extras.replay)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# convergence: prioritized beats uniform on sparse-reward Catch
# ---------------------------------------------------------------------------

CATCH_CFG = dict(n_envs=8, rollout_steps=8, updates_per_iter=4,
                 buffer_size=8192, batch_size=32, warmup=256,
                 eps_decay_updates=800, target_update_every=100)
CATCH_NET = dict(conv_filters=(8, 8), fc_width=32)


def _updates_to_threshold(rewards, record_every, updates_per_iter,
                          threshold):
    """Learner updates consumed until the eval reward first clears the
    threshold (np.inf if it never does)."""
    for i, r in enumerate(rewards):
        if r >= threshold:
            return (i + 1) * record_every * updates_per_iter
    return np.inf


@pytest.mark.slow
def test_prioritized_reaches_catch_threshold_in_fewer_updates():
    """ISSUE acceptance: on sparse-reward Catch the prioritized learner
    clears the reward threshold in fewer learner updates than uniform.

    Measured margin at this seed/config (jax 0.4.37, CPU): prioritized
    crosses +2.0 around iteration 450, uniform around 600 (of 800) — a
    ~3-record-point gap on both of the seeds probed.
    """
    threshold = 2.0    # mean eval return over [-5, 5]; random play ~ -5
    kw = dict(iterations=800, record_every=50, eval_episodes=16, seed=0,
              steps_per_call=25, net_kwargs=dict(CATCH_NET),
              algo_overrides=dict(CATCH_CFG))
    uniform = loops.train("dqn", "catch", replay="uniform", **kw)
    per = loops.train("dqn", "catch", replay="prioritized", **kw)
    n_uniform = _updates_to_threshold(
        uniform.rewards, 50, CATCH_CFG["updates_per_iter"], threshold)
    n_per = _updates_to_threshold(
        per.rewards, 50, CATCH_CFG["updates_per_iter"], threshold)
    assert np.isfinite(n_per), f"prioritized never reached {threshold}: " \
        f"{per.rewards}"
    assert n_per < n_uniform, (
        f"prioritized needed {n_per} learner updates, uniform {n_uniform} "
        f"(uniform {uniform.rewards} vs prioritized {per.rewards})")


# ---------------------------------------------------------------------------
# sharded trees under a real device mesh (shard_map)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prioritized_actor_learner_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import contextlib
        import jax, jax.numpy as jnp, numpy as np
        from repro.rl import actor_learner, dqn
        from repro.rl.envs import make as make_env
        from repro.rl.networks import make_network

        def mesh_ctx(mesh):
            for name in ("set_mesh", "use_mesh"):
                if hasattr(jax.sharding, name):
                    return getattr(jax.sharding, name)(mesh)
            return contextlib.nullcontext()

        env = make_env("cartpole")
        cfg = dqn.DQNConfig(n_envs=4, rollout_steps=4, updates_per_iter=2,
                            buffer_size=512, batch_size=32, warmup=16,
                            replay="prioritized")
        net = make_network(env.spec.obs_shape, env.spec.n_actions)
        al = actor_learner.ActorLearnerConfig(num_actors=4, sync_every=2)
        mesh = jax.make_mesh((4,), ("actor",))
        state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                                   cfg, al)
        iteration, act_fn, benv = actor_learner.make_actor_learner(
            "dqn", env, net, cfg, al, mesh=mesh)
        env_state, obs = benv.reset(jax.random.PRNGKey(1))
        key = jax.random.PRNGKey(2)
        with mesh_ctx(mesh):
            for i in range(3):
                key, k = jax.random.split(key)
                state, env_state, obs, m = iteration(state, env_state, obs,
                                                     k)
                assert jnp.isfinite(m["loss"]), m
        roots = np.asarray(state.learner.extras.replay.tree[:, 1])
        assert roots.shape == (4,)
        assert np.isfinite(roots).all() and (roots > 0).all(), roots
        print("PER_MESH_OK", roots)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PER_MESH_OK" in out.stdout
