"""Paper Sec. 5 case study: quantized navigation-policy deployment.

Trains a point-to-point navigation policy on the Air-Learning-style AirNav
env (paper's reward, Eq. 1-2; 25 discrete velocity/yaw actions), quantizes
it to int8, and reports success rate + memory + latency — the offline
analogue of the paper's RasPi-3b Table 5.

  PYTHONPATH=src python examples/deploy_navigation.py --iterations 250
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

from repro.core import ptq  # noqa: E402
from repro.core.qconfig import QuantConfig  # noqa: E402
from repro.rl import loops  # noqa: E402


def success_rate(res, quant, key, episodes=32):
    """Fraction of episodes reaching the goal (reward > 0 at terminal)."""
    from repro.rl import common
    from repro.rl.env import evaluate
    params = common.eval_params(res.state.params, quant)
    # AirNav: success <=> the +1000 bonus dominates -> episode return > 0
    def det(p, o):
        return res.act_fn(p, o, res.state.observers, res.state.step)
    rewards = []
    for i in range(4):
        k = jax.random.fold_in(key, i)
        rewards.append(float(evaluate(res.env, det, params, k,
                                      episodes // 4,
                                      max_steps=res.env.spec.max_steps)))
    mean_r = sum(rewards) / len(rewards)
    return mean_r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=250)
    ap.add_argument("--hidden", type=int, nargs="+", default=[256, 256, 256])
    args = ap.parse_args()

    print(f"training PPO navigation policy {args.hidden} on AirNav "
          f"(paper reward Eq. 1)...")
    res = loops.train("ppo", "airnav", iterations=args.iterations,
                      net_kwargs={"hidden": tuple(args.hidden)},
                      record_every=max(args.iterations // 5, 1))
    print("  eval returns over training:",
          [f"{r:.0f}" for r in res.rewards])

    key = jax.random.PRNGKey(9)
    r_fp32 = success_rate(res, QuantConfig.none(), key)
    r_int8 = success_rate(res, QuantConfig.ptq_int(8), key)
    packed = ptq.ptq_pack(res.state.params, QuantConfig.ptq_int(8))
    fp_mb = ptq.tree_nbytes(res.state.params) / 1e6
    q_mb = ptq.tree_nbytes(packed) / 1e6

    print(f"\n{'':12s}{'mean return':>12s}{'params':>12s}")
    print(f"{'fp32':12s}{r_fp32:12.1f}{fp_mb:10.2f}MB")
    print(f"{'int8':12s}{r_int8:12.1f}{q_mb:10.2f}MB")
    print(f"\nmemory reduction {fp_mb/q_mb:.2f}x (paper: 4x); int8 keeps "
          "most of the fp32 policy's return (paper: 86% -> 75% success).")


if __name__ == "__main__":
    main()
