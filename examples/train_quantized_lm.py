"""End-to-end driver: QAT-train a ~100M-parameter language model.

This is the paper's Algorithm 2 applied to a modern LM stack: a ~100M dense
transformer (or any --arch from the assigned pool) trains on the synthetic
Markov corpus with int8 quantization-aware training — full-precision with
range monitoring for --quant-delay steps, fake-quantized weights+activations
after — using the same train_step that the multi-pod dry-run lowers.

A few hundred steps on TPU take minutes; this CPU container manages ~0.1
steps/s at the default size, so the default --steps is small. Run with
--steps 300 for the full driver.

  PYTHONPATH=src python examples/train_quantized_lm.py --steps 300
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ArchConfig, ATTN  # noqa: E402
from repro.core.qconfig import QuantConfig  # noqa: E402
from repro.data import SyntheticLMDataset  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.optim import adam as adam_lib  # noqa: E402

LM_100M = ArchConfig(
    name="dense-100m", family="dense", source="examples",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab=8192, pattern=(ATTN,), sharding="tp",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--quant-delay", type=int, default=None,
                    help="full-precision monitoring steps (default: 1/3)")
    args = ap.parse_args()

    delay = args.quant_delay if args.quant_delay is not None \
        else args.steps // 3
    import dataclasses
    cfg = dataclasses.replace(
        LM_100M, quant=QuantConfig.qat(args.bits, quant_delay=delay))

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}, {n/1e6:.1f}M params, QAT int{args.bits} "
          f"(delay {delay} steps), bf16 compute / fp32 master")

    adam_cfg = adam_lib.AdamConfig(lr=3e-4)
    train_step, _ = steps_lib.make_train_step(cfg, adam_cfg)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    opt = adam_lib.adam_init(params, adam_cfg)
    qat = transformer.init_qat_collection(cfg)
    print(f"QAT observer sites: {len(qat)}")

    data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                              batch=args.batch, seed=0)
    t0 = time.time()
    for step, batch in enumerate(data.batches()):
        if step >= args.steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, qat, metrics = train_step(params, opt, jb, qat)
        phase = "monitor" if step < delay else "quantized"
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} [{phase:9s}] "
                  f"loss {float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(step+1):.1f}s/step)")
    print("done — the loss keeps falling after quantization enables, "
          "which is Algorithm 2's claim.")


if __name__ == "__main__":
    main()
