"""Serve a small model with batched requests, int8 weights + int8 KV cache.

The paper's deployment case study (Sec. 5) applied to an LM: weights are
post-training-quantized to int8 (Algorithm 1), the decode KV cache is stored
as int8 codes + per-token scales (beyond-paper feature), and a batch of
requests decodes greedily through the same serve_step the dry-run lowers.

  PYTHONPATH=src python examples/serve_quantized.py --batch 4 --new-tokens 24
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import base as cfgs  # noqa: E402
from repro.core import ptq  # noqa: E402
from repro.core.qconfig import QuantConfig  # noqa: E402
from repro.models import transformer  # noqa: E402


def generate(cfg, params, tokens, total_len, batch, enc=None):
    caches = transformer.init_caches(cfg, batch, total_len,
                                     dtype=jnp.float32)

    @jax.jit
    def step(params, caches, tok, pos):
        logits, caches = transformer.decode_step(cfg, params, tok, caches,
                                                 pos, encoder_out=enc)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches

    out, tok = [], tokens[:, :1]
    prompt_len = tokens.shape[1]
    for pos in range(total_len - 1):
        nxt, caches = step(params, caches, tok, jnp.asarray(pos))
        if pos + 1 < prompt_len:
            tok = tokens[:, pos + 1:pos + 2]
        else:
            tok = nxt[:, None]
            out.append(nxt)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = cfgs.get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    total = args.prompt_len + args.new_tokens

    # fp32 reference
    t0 = time.time()
    ref = generate(cfg, params, tokens, total, args.batch)
    t_ref = time.time() - t0

    # int8 weights (simulated int math) + int8 KV cache
    qcfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(QuantConfig.ptq_int(8),
                                       int8_kv_cache=True))
    qparams = ptq.ptq_simulate(params, qcfg.quant)
    packed = ptq.ptq_pack(params, QuantConfig.ptq_int(8))
    t0 = time.time()
    out = generate(qcfg, qparams, tokens, total, args.batch)
    t_q = time.time() - t0

    agree = sum(bool(jnp.all(a == b)) for a, b in zip(ref, out))
    fp_mb = ptq.tree_nbytes(params) / 1e6
    q_mb = ptq.tree_nbytes(packed) / 1e6
    print(f"arch {cfg.name}: {args.batch} requests x {args.new_tokens} new "
          f"tokens")
    print(f"  weights: {fp_mb:.2f} MB fp32 -> {q_mb:.2f} MB int8 "
          f"({fp_mb/q_mb:.2f}x smaller); KV cache int8 (2x smaller)")
    print(f"  decode wall time: fp32 {t_ref:.2f}s, int8 {t_q:.2f}s (CPU)")
    print(f"  greedy tokens agree on {agree}/{len(ref)} steps "
          f"(int8 noise flips some argmaxes — the paper's 'small noise' "
          f"regime)")
    print("  int8 sequence 0:", [int(t[0]) for t in out][:12])


if __name__ == "__main__":
    main()
