"""Quickstart: the QuaRL result in two minutes on CPU.

Trains a PPO CartPole policy, applies the paper's post-training quantization
(Algorithm 1) at fp16/int8/int4, and prints the reward table — the
miniature version of paper Table 2.

Run:  PYTHONPATH=src python examples/quickstart.py [--iterations 150]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

from repro.core.qconfig import QuantConfig  # noqa: E402
from repro.rl import loops  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=150)
    args = ap.parse_args()

    print("training fp32 PPO on CartPole...")
    res = loops.train("ppo", "cartpole", iterations=args.iterations,
                      record_every=max(args.iterations // 5, 1))
    print("  eval rewards over training:", [f"{r:.0f}" for r in res.rewards])

    key = jax.random.PRNGKey(0)
    print(f"\n{'quantizer':12s} {'reward':>8s} {'E%':>8s}")
    fp32 = loops.eval_policy(res, QuantConfig.none(), key)
    print(f"{'fp32':12s} {fp32:8.1f} {'-':>8s}")
    for q in [QuantConfig.ptq_fp16(), QuantConfig.ptq_int(8),
              QuantConfig.ptq_int(4)]:
        r = loops.eval_policy(res, q, key)
        e = 100.0 * (fp32 - r) / max(abs(fp32), 1e-9)
        print(f"{q.label():12s} {r:8.1f} {e:+8.1f}")
    print("\nExpected (paper Sec 4): int8/fp16 within a few % of fp32 "
          "(sometimes better); int4 degrades.")


if __name__ == "__main__":
    main()
