"""Shared benchmark plumbing: scaling knob, timing, CSV output contract.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (repo contract)
plus a human-readable table, and returns a list of dict rows for run.py.

``SCALE`` (env REPRO_BENCH_SCALE, default 1.0) multiplies training budgets:
1.0 reproduces every qualitative claim in minutes on CPU; ~50x approaches
paper-scale budgets on real hardware.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(REPO, "src"))

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
ART = os.path.join(REPO, "artifacts", "bench")


def scaled(n: int, lo: int = 1) -> int:
    return max(int(n * SCALE), lo)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call (seconds); blocks on jax outputs."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_rows(bench: str, rows: List[Dict]) -> str:
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{bench}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path
