"""Paper Table 2 / Tables 5-8: post-training quantization rewards.

For each (algorithm × environment) pair: train fp32, evaluate fp32 / fp16 /
int8, report rewards and the paper's relative error E_%.

Claims checked (paper Sec. 4):
  * |mean E_int8| and |mean E_fp16| are small (paper: 2-5%) — policies are
    quantizable to 8/16 bits without meaningful reward loss.
  * occasional negative E (quantized beats fp32) appears.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks import common as C


# (algo, env, training iterations at SCALE=1) — mirrors the paper's matrix
# (Table 1) on the offline env suite; DDPG gets the continuous envs.
MATRIX = [
    ("ppo", "cartpole", 150), ("ppo", "catch", 150), ("ppo", "airnav", 200),
    ("a2c", "cartpole", 800), ("a2c", "catch", 250),
    ("dqn", "cartpole", 800), ("dqn", "catch", 150),
    ("ddpg", "pendulum", 400), ("ddpg", "mountaincar_continuous", 300),
]


def run(matrix=None) -> List[Dict]:
    from repro.rl import loops
    rows = []
    for algo, env, iters in (matrix or MATRIX):
        results = loops.quarl_ptq(algo, env, bits_list=(16, 8),
                                  iterations=C.scaled(iters), seed=0)
        row = {"algo": algo, "env": env,
               "fp32": results[0].fp32_reward,
               "fp16": results[0].quant_reward,
               "E_fp16": results[0].error_pct,
               "int8": results[1].quant_reward,
               "E_int8": results[1].error_pct,
               "weight_range": results[1].extra["weight_stats"]["range"]}
        rows.append(row)
        C.emit(f"ptq/{algo}/{env}", 0.0,
               f"fp32={row['fp32']:.1f};fp16={row['fp16']:.1f}"
               f";int8={row['int8']:.1f};E_int8={row['E_int8']:.1f}%")
    for label in ("E_fp16", "E_int8"):
        vals = [r[label] for r in rows]
        mean = sum(vals) / len(vals)
        C.emit(f"ptq/mean_{label}", 0.0, f"{mean:+.2f}%")
    C.save_rows("ptq_rewards", rows)
    return rows


if __name__ == "__main__":
    run()
