"""ActorQ hot-path benchmark: actor inference throughput + driver overhead.

Two measurements behind the paper's systems claim (8-bit actors collect data
1.5-5.41x faster):

1. Actor throughput — env-steps/sec of batched action selection for the
   three actor execution modes across env batch sizes {64, 256, 1024}:
     * fp32       — the plain policy,
     * fake-quant — fp32 math on quantize-dequantized weights (what the
       repo simulated before ActorQ; same arithmetic cost as fp32),
     * int8       — the true ActorQ path (``rl.actorq``): packed int8
       params + dynamic activation quantization through the W8A8 GEMM
       (``auto`` = Pallas on TPU; on this CPU host the native-XLA
       backend, ``kernels.xla_backend``).

2. Dispatch overhead — wall time of ``loops.train`` with the per-step
   driver (one jit dispatch per update) vs the scan-fused driver
   (``steps_per_call`` updates per dispatch), same seed and budget.

3. Fused single-pass kernel (ISSUE 5) — env-steps/sec of the fused
   quantized-MLP actor (static requant, ``kernels.fused_qmlp``) vs the
   per-layer dynamic path, across weight bits {8, 4} x MLP depth
   {1, 2, 3}.  Both modes of a cell are timed over one *shared* wall
   window (calls strictly interleaved) so host-load drift cannot fake a
   win; plus the int4-vs-int8 actor-cache footprint.

4. Kernel-backend matrix (ISSUE 6) — ref vs xla vs interpret at the
   depth-2 int8 cell, per-layer and fused, each timed strictly
   interleaved with the same fp32 actor so ``speedup_vs_fp32`` is
   drift-proof and the fallback-vs-native gap stays visible in the perf
   trajectory.

Emits ``BENCH_actor_throughput.json`` via ``benchmarks/common.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common as C

BATCH_SIZES = (64, 256, 1024)
HIDDEN = (256, 256, 256)          # paper Table 5 "policy II" deployment MLP
FUSED_DEPTHS = (1, 2, 3)
FUSED_BITS = ((8, "int8"), (4, "int4"))
FUSED_BATCH = 256


def _interleaved_pair(a, b, warmup: int = 3, iters: int = 30):
    """Median per-call seconds of two ``(fn, args)`` pairs, alternated
    call by call over one shared wall-clock window (host-load drift hits
    both sides equally — the only trustworthy ratio on a noisy host)."""
    (fn_a, args_a), (fn_b, args_b) = a, b
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args_a))
        jax.block_until_ready(fn_b(*args_b))
    times_a, times_b = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args_a))
        times_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args_b))
        times_b.append(time.perf_counter() - t0)
    times_a.sort()
    times_b.sort()
    return times_a[len(times_a) // 2], times_b[len(times_b) // 2]


def _interleaved_medians(fn, args_a, args_b, warmup: int = 3,
                         iters: int = 30):
    return _interleaved_pair((fn, args_a), (fn, args_b), warmup, iters)


def _actor_fns(net, params, n_act):
    """(label -> jitted act fn, params-for-that-fn) for the three modes."""
    from repro.core import ptq
    from repro.core.fake_quant import NullQATContext
    from repro.core.qconfig import QuantConfig
    from repro.rl import actorq

    ctx = NullQATContext()

    @jax.jit
    def fp32_act(p, obs):
        return jnp.argmax(net.apply(ctx, p, obs)[..., :n_act], -1)

    fake = ptq.ptq_simulate(params, QuantConfig.ptq_int(8))
    packed = actorq.pack_actor_params(params)

    @jax.jit
    def int8_act(p, obs):
        return jnp.argmax(
            actorq.quantized_apply(p, obs)[..., :n_act], -1)

    return {"fp32": (fp32_act, params),
            "fake_quant": (fp32_act, fake),
            "int8": (int8_act, packed)}


def run(train_iterations: int = 60) -> List[Dict]:
    from repro.rl import loops
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    rows = []

    # -- 1. actor inference throughput -----------------------------------
    env = make_env("airnav")
    n_act = env.spec.n_actions
    net = make_network(env.spec.obs_shape, n_act, hidden=HIDDEN)
    params = net.init(jax.random.PRNGKey(0))
    fns = _actor_fns(net, params, n_act)
    obs_dim = int(env.spec.obs_shape[0])

    for batch in BATCH_SIZES:
        obs = jax.random.normal(jax.random.PRNGKey(1), (batch, obs_dim))
        base_t = None
        for label, (fn, p) in fns.items():
            t = C.time_fn(fn, p, obs, warmup=2, iters=10)
            base_t = t if label == "fp32" else base_t
            sps = batch / t
            rows.append({"section": "actor_throughput", "actor": label,
                         "batch": batch, "us_per_call": t * 1e6,
                         "steps_per_sec": sps,
                         "speedup_vs_fp32": base_t / t})
            C.emit(f"actor/{label}/b{batch}", t * 1e6,
                   f"steps_per_sec={sps:.0f}"
                   f";speedup={base_t / t:.2f}x")

    # -- 1b. fused single-pass kernel vs per-layer (x int8/int4 x depth) --
    from repro.rl import actorq

    @jax.jit
    def quant_act(cache, obs):
        # one callable; the per-layer and fused (calibrated) caches have
        # different pytree structures, so jit compiles one program each
        return jnp.argmax(actorq.quantized_apply(cache, obs)[..., :n_act],
                          -1)

    obs = jax.random.normal(jax.random.PRNGKey(2), (FUSED_BATCH, obs_dim))
    nbytes = {}
    for bits, blabel in FUSED_BITS:
        for depth in FUSED_DEPTHS:
            dnet = make_network(env.spec.obs_shape, n_act,
                                hidden=(256,) * depth)
            dparams = dnet.init(jax.random.PRNGKey(depth))
            per_cache = actorq.pack_actor_params(dparams, bits=bits)
            fused_cache = actorq.calibrate_actor_cache(per_cache, obs)
            if depth == FUSED_DEPTHS[-1]:
                nbytes[blabel] = actorq.packed_nbytes(per_cache)
            t_per, t_fused = _interleaved_medians(
                quant_act, (per_cache, obs), (fused_cache, obs))
            for mode, t in (("per_layer", t_per), ("fused", t_fused)):
                rows.append({"section": "fused_qmlp", "actor": blabel,
                             "bits": bits, "depth": depth,
                             "batch": FUSED_BATCH, "mode": mode,
                             "us_per_call": t * 1e6,
                             "env_steps_per_sec": FUSED_BATCH / t,
                             "speedup_vs_per_layer": t_per / t})
            C.emit(f"fused/{blabel}/depth{depth}", t_fused * 1e6,
                   f"steps_per_sec={FUSED_BATCH / t_fused:.0f}"
                   f";speedup_vs_per_layer={t_per / t_fused:.2f}x")
    rows.append({"section": "fused_qmlp_footprint",
                 "int8_nbytes": nbytes["int8"],
                 "int4_nbytes": nbytes["int4"],
                 "int4_frac": nbytes["int4"] / nbytes["int8"]})
    C.emit("fused/footprint", 0.0,
           f"int4_frac={nbytes['int4'] / nbytes['int8']:.3f}")

    # -- 1c. kernel-backend matrix (ISSUE 6) ------------------------------
    # ref vs xla vs interpret at the depth-2 int8 cell, per-layer and
    # fused, each interleaved with the SAME fp32 actor so the recorded
    # speedup_vs_fp32 is drift-proof.
    from repro.core.fake_quant import NullQATContext

    mnet = make_network(env.spec.obs_shape, n_act, hidden=(256, 256))
    mparams = mnet.init(jax.random.PRNGKey(3))
    mctx = NullQATContext()

    @jax.jit
    def fp32_act2(p, o):
        return jnp.argmax(mnet.apply(mctx, p, o)[..., :n_act], -1)

    per_cache = actorq.pack_actor_params(mparams, bits=8)
    fused_cache = actorq.calibrate_actor_cache(per_cache, obs)

    def _backend_act(backend):
        @jax.jit
        def act(cache, o):
            return jnp.argmax(
                actorq.quantized_apply(cache, o, backend=backend
                                       )[..., :n_act], -1)
        return act

    for backend in ("ref", "xla", "interpret"):
        act = _backend_act(backend)
        for mode, cache in (("per_layer", per_cache),
                            ("fused", fused_cache)):
            iters = 10 if backend == "interpret" else 30
            t_fp, t_q = _interleaved_pair((fp32_act2, (mparams, obs)),
                                          (act, (cache, obs)),
                                          warmup=2, iters=iters)
            rows.append({"section": "backend_matrix", "backend": backend,
                         "mode": mode, "bits": 8, "depth": 2,
                         "batch": FUSED_BATCH, "us_per_call": t_q * 1e6,
                         "env_steps_per_sec": FUSED_BATCH / t_q,
                         "fp32_us_per_call": t_fp * 1e6,
                         "speedup_vs_fp32": t_fp / t_q})
            C.emit(f"backend/{backend}/{mode}", t_q * 1e6,
                   f"steps_per_sec={FUSED_BATCH / t_q:.0f}"
                   f";speedup_vs_fp32={t_fp / t_q:.2f}x")

    # -- 2. driver dispatch overhead: per-step vs scan-fused --------------
    # Same total update budget through both drivers, timed after compile,
    # so the difference is pure Python-dispatch + host-roundtrip overhead.
    import time as _time

    from repro.rl import a2c as a2c_mod

    cenv = make_env("cartpole")
    cnet = make_network(cenv.spec.obs_shape, cenv.spec.n_actions + 1)
    ccfg = a2c_mod.A2CConfig()
    iteration, _, benv = a2c_mod.make_iteration(cenv, cnet, ccfg)
    updates = C.scaled(train_iterations) * 10

    def drive(chunk_len: int) -> float:
        """us/update of the fused driver at the given chunk length."""
        state = a2c_mod.init(jax.random.PRNGKey(0), cenv, cnet, ccfg)
        env_state, obs = benv.reset(jax.random.PRNGKey(1))
        key = jax.random.PRNGKey(2)
        fused = loops.make_scan_iteration(iteration, chunk_len)
        state, env_state, obs, key, _ = fused(state, env_state, obs, key)
        jax.block_until_ready(state.params)        # compile + warm
        n_chunks = max(updates // chunk_len, 1)
        t0 = _time.perf_counter()
        for _ in range(n_chunks):
            state, env_state, obs, key, _ = fused(state, env_state, obs,
                                                  key)
        jax.block_until_ready(state.params)
        return (_time.perf_counter() - t0) / (n_chunks * chunk_len) * 1e6

    base_us = None
    for steps_per_call in (1, 10, 50):
        us_it = drive(steps_per_call)
        base_us = us_it if steps_per_call == 1 else base_us
        rows.append({"section": "driver_overhead",
                     "steps_per_call": steps_per_call,
                     "updates": updates, "us_per_update": us_it,
                     "speedup_vs_per_step": base_us / us_it})
        C.emit(f"driver/scan{steps_per_call}", us_it,
               f"speedup={base_us / us_it:.2f}x")

    path = C.save_rows("BENCH_actor_throughput", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
