"""Paper Figure 2 + Appendix E: QAT bitwidth sweep + PTQ sweet spot.

For one (algo, env): train QAT policies at 2/4/6/8 bits (with quantization
delay = half of training) and compare against fp32 and 8-bit PTQ; also sweep
PTQ 2..8 bits on the fp32 model (Appendix E's sweet-spot curve).

Claims checked:
  * QAT holds the fp32 baseline down to ~5-6 bits, degrading below.
  * QAT >= PTQ at matched bitwidths (esp. low bits).
  * PTQ reward vs bits has a task-dependent sweet spot (not monotone).
"""
from __future__ import annotations

from typing import Dict, List

import jax

from benchmarks import common as C


def run(algo: str = "ppo", env: str = "cartpole", iterations: int = 200
        ) -> List[Dict]:
    from repro.core.qconfig import QuantConfig
    from repro.rl import loops

    iters = C.scaled(iterations)
    fp = loops.train(algo, env, iterations=iters, seed=0)
    key = jax.random.PRNGKey(77)
    fp32_r = loops.eval_policy(fp, QuantConfig.none(), key)
    rows = [{"mode": "fp32", "bits": 32, "reward": fp32_r}]
    C.emit(f"qat_bw/{algo}/{env}/fp32", 0.0, f"reward={fp32_r:.1f}")

    # PTQ sweep (Appendix E)
    for bits in (8, 6, 4, 2):
        r = loops.eval_policy(fp, QuantConfig.ptq_int(bits), key)
        rows.append({"mode": "ptq", "bits": bits, "reward": r})
        C.emit(f"qat_bw/{algo}/{env}/ptq{bits}", 0.0, f"reward={r:.1f}")

    # QAT sweep (Fig 2)
    for bits in (8, 6, 4, 2):
        res = loops.quarl_qat(algo, env, bits, iterations=iters,
                              quant_delay_frac=0.5, seed=0)
        rows.append({"mode": "qat", "bits": bits,
                     "reward": res.quant_reward, "E_pct": res.error_pct})
        C.emit(f"qat_bw/{algo}/{env}/qat{bits}", 0.0,
               f"reward={res.quant_reward:.1f};E={res.error_pct:+.1f}%")

    # headline claims
    qat8 = next(r for r in rows if r["mode"] == "qat" and r["bits"] == 8)
    ptq4 = next(r for r in rows if r["mode"] == "ptq" and r["bits"] == 4)
    qat4 = next(r for r in rows if r["mode"] == "qat" and r["bits"] == 4)
    C.emit(f"qat_bw/{algo}/{env}/claim_qat8_holds_fp32", 0.0,
           f"{qat8['reward']:.1f}_vs_{fp32_r:.1f}")
    C.emit(f"qat_bw/{algo}/{env}/claim_qat4_beats_ptq4", 0.0,
           f"{qat4['reward']:.1f}_vs_{ptq4['reward']:.1f}")
    C.save_rows(f"qat_bitwidth_{algo}_{env}", rows)
    return rows


if __name__ == "__main__":
    run()
