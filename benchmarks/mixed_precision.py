"""Paper Table 4 + Figure 5: mixed/half-precision training speedup.

Trains the paper's Policy A / B / C conv networks (Table 10) with DQN-style
updates in fp32 vs mixed precision (bf16 compute + fp32 master — the TPU
analogue of the paper's fp16+loss-scale; fp16 is also measured) and compares
per-step wall time and convergence sanity.

Paper claim shape: small nets may not speed up (conversion overhead), large
nets gain (paper: 0.87x / 1.04x / 1.61x for A/B/C). On this CPU container
the absolute ratios differ, but the trend with model size is the check.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common as C


# Paper Table 10 architectures. The default benchmark uses 1/4-width
# variants (this container is a single CPU core; Policy C at paper width is
# ~1 TFLOP/step) — the claim under test is the *trend with model size*.
# Set REPRO_MP_PAPER_SIZES=1 for the exact paper widths.
import os as _os
if _os.environ.get("REPRO_MP_PAPER_SIZES", "0") == "1":
    POLICIES = {
        "policy_a": ((128, 128, 128), 128),
        "policy_b": ((512, 512, 512), 512),
        "policy_c": ((1024, 1024, 1024), 2048),
    }
else:
    POLICIES = {
        "policy_a": ((32, 32, 32), 32),
        "policy_b": ((128, 128, 128), 128),
        "policy_c": ((256, 256, 256), 512),
    }


def _step_fn(net, mp_cfg, batch):
    from repro.core import mixed_precision as mp
    from repro.core.fake_quant import NullQATContext
    from repro.optim.adam import AdamConfig, adam_init, adam_update

    adam_cfg = AdamConfig(lr=1e-4)
    ctx = NullQATContext()
    ls = mp.DynamicLossScale.init() if mp_cfg.dynamic_loss_scale else None

    def loss_fn(params):
        p_c = mp.to_compute(params, mp_cfg)
        obs = batch["obs"].astype(jnp.dtype(mp_cfg.compute_dtype))
        q = net.apply(ctx, p_c, obs)
        q_sel = jnp.take_along_axis(q, batch["action"][:, None], 1)[:, 0]
        loss = jnp.mean(jnp.square(
            q_sel.astype(jnp.float32) - batch["target"]))
        return mp.scale_loss(loss, ls)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = mp.unscale_grads(grads, ls)
        params, opt, _ = adam_update(grads, opt, params, adam_cfg)
        unscaled = loss / ls.scale if ls is not None else loss
        return params, opt, unscaled

    return step, adam_cfg


def run(batch: int = 16, grid: int = 10) -> List[Dict]:
    from repro.core.qconfig import MixedPrecisionConfig
    from repro.rl.networks import make_network

    rows = []
    key = jax.random.PRNGKey(0)
    batch_data = {
        "obs": jax.random.normal(key, (batch, grid, grid, 1)),
        "action": jax.random.randint(key, (batch,), 0, 3),
        "target": jax.random.normal(key, (batch,)),
    }
    for name, (filters, fc) in POLICIES.items():
        net = make_network((grid, grid, 1), 3, conv_filters=filters,
                           fc_width=fc)
        times = {}
        for mp_name, mp_cfg in [("fp32", MixedPrecisionConfig.fp32()),
                                ("bf16", MixedPrecisionConfig.bf16()),
                                ("fp16", MixedPrecisionConfig.fp16())]:
            from repro.optim.adam import AdamConfig, adam_init
            params = net.init(jax.random.PRNGKey(1))
            opt = adam_init(params, AdamConfig(lr=1e-4))
            step, _ = _step_fn(net, mp_cfg, batch_data)
            t = C.time_fn(lambda: step(params, opt), warmup=1, iters=3)
            times[mp_name] = t
            C.emit(f"mixed_precision/{name}/{mp_name}", t * 1e6,
                   f"step_time={t * 1e3:.1f}ms")
        speedup_bf16 = times["fp32"] / times["bf16"]
        speedup_fp16 = times["fp32"] / times["fp16"]
        rows.append({"policy": name, **{f"t_{k}": v for k, v in
                                        times.items()},
                     "speedup_bf16": speedup_bf16,
                     "speedup_fp16": speedup_fp16})
        C.emit(f"mixed_precision/{name}/speedup", 0.0,
               f"bf16={speedup_bf16:.2f}x;fp16={speedup_fp16:.2f}x")
    C.save_rows("mixed_precision", rows)
    return rows


def convergence_check(steps: int = 150, batch: int = 32, grid: int = 10
                      ) -> Dict:
    """Figure 5's claim: mixed precision converges like full precision.

    Fits the Policy-A conv net to a fixed Q-regression target under fp32 /
    bf16 / fp16(+dynamic loss scale) and compares final losses.
    """
    from repro.core.qconfig import MixedPrecisionConfig
    from repro.optim.adam import AdamConfig, adam_init
    from repro.rl.networks import make_network

    key = jax.random.PRNGKey(0)
    batch_data = {
        "obs": jax.random.normal(key, (batch, grid, grid, 1)),
        "action": jax.random.randint(key, (batch,), 0, 3),
        "target": jax.random.normal(jax.random.PRNGKey(5), (batch,)),
    }
    net = make_network((grid, grid, 1), 3, conv_filters=(32, 32, 32),
                       fc_width=64)
    out = {}
    for label, mp_cfg in [("fp32", MixedPrecisionConfig.fp32()),
                          ("bf16", MixedPrecisionConfig.bf16()),
                          ("fp16", MixedPrecisionConfig.fp16())]:
        params = net.init(jax.random.PRNGKey(1))
        opt = adam_init(params, AdamConfig(lr=1e-4))
        step, _ = _step_fn(net, mp_cfg, batch_data)
        loss = None
        for _ in range(C.scaled(steps)):
            params, opt, loss = step(params, opt)
        out[label] = float(loss)
        C.emit(f"mixed_precision/convergence/{label}", 0.0,
               f"final_loss={float(loss):.4f}")
    return out


if __name__ == "__main__":
    run()
    convergence_check()
