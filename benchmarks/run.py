"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. REPRO_BENCH_SCALE (default 1.0)
multiplies the training budgets; REPRO_BENCH_FAST=1 runs a reduced matrix
for CI-style runs.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"


def main() -> None:
    t0 = time.time()
    print("benchmark,us_per_call,derived")
    jobs = []

    from benchmarks import (actor_throughput, deployment, exploration,
                            mixed_precision, ptq_rewards, qat_bitwidth,
                            roofline, weight_distribution)

    if FAST:
        jobs = [
            ("table2_ptq", lambda: ptq_rewards.run(
                matrix=[("ppo", "cartpole", 120), ("ppo", "airnav", 100),
                        ("a2c", "cartpole", 600), ("dqn", "cartpole", 500),
                        ("ddpg", "pendulum", 200),
                        ("ddpg", "mountaincar_continuous", 150)])),
            ("fig2_qat_bitwidth", lambda: qat_bitwidth.run(
                "ppo", "cartpole", iterations=120)),
            ("table3_weight_distribution", lambda: weight_distribution.run(
                cases=[("dqn", "cartpole", 500), ("dqn", "catch", 60),
                       ("ppo", "cartpole", 120), ("a2c", "cartpole", 600)])),
            ("fig1_exploration", lambda: exploration.run(
                "a2c", "cartpole", iterations=400)),
            ("table4_mixed_precision", lambda: mixed_precision.run()),
            ("fig5_mp_convergence",
             lambda: mixed_precision.convergence_check(steps=60)),
            ("table5_deployment", lambda: deployment.run(iterations=100)),
            ("actorq_throughput",
             lambda: actor_throughput.run(train_iterations=30)),
        ]
    else:
        jobs = [
            ("table2_ptq", ptq_rewards.run),
            ("fig2_qat_bitwidth", qat_bitwidth.run),
            ("table3_weight_distribution", weight_distribution.run),
            ("fig1_exploration", exploration.run),
            ("table4_mixed_precision", mixed_precision.run),
            ("fig5_mp_convergence", mixed_precision.convergence_check),
            ("table5_deployment", deployment.run),
            ("actorq_throughput", actor_throughput.run),
        ]
    jobs.append(("roofline", roofline.main))

    failures = 0
    for name, fn in jobs:
        print(f"\n### {name}")
        t = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        print(f"### {name} done in {time.time() - t:.0f}s")
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s, "
          f"{failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
