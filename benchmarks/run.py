"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. REPRO_BENCH_SCALE (default 1.0)
multiplies the training budgets; REPRO_BENCH_FAST=1 (or ``--fast``) runs a
reduced matrix for CI-style runs; ``--smoke`` additionally shrinks the
training budgets (scale 0.25 unless REPRO_BENCH_SCALE is set) — the CI
benchmark job runs ``python benchmarks/run.py --smoke`` and uploads the
``artifacts/bench/BENCH_*.json`` files as workflow artifacts.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                       # the benchmarks package
sys.path.insert(0, os.path.join(_ROOT, "src"))  # the repro package


def _check_actor_learner_schema() -> None:
    """Schema gate on the emitted ``BENCH_actor_learner.json`` (ISSUE 4):
    the async overlap section must be present, every throughput field must
    be finite and positive (a NaN/zero rate means a cell silently broke),
    and every async row must carry both concurrently-measured rates."""
    import json
    import math

    path = os.path.join(_ROOT, "artifacts", "bench",
                        "BENCH_actor_learner.json")
    with open(path) as f:
        rows = json.load(f)
    async_rows = [r for r in rows
                  if r.get("section") == "actor_learner_async"]
    assert async_rows, "async overlap section missing from " + path
    for r in rows:
        for k in ("env_steps_per_sec", "learner_samples_per_sec",
                  "learner_updates_per_sec"):
            if k in r:
                v = float(r[k])
                assert math.isfinite(v) and v > 0, (k, r)
    for r in async_rows:
        for k in ("env_steps_per_sec", "learner_updates_per_sec",
                  "speedup_env_steps_vs_sync"):
            assert k in r and math.isfinite(float(r[k])), (k, r)
    # ISSUE 8: the checkpoint-overhead section must be present, carry
    # both the checkpointed and baseline rates, and show the async
    # writer adding no blocking sync (generous noise bound — CI hosts
    # are loaded; the committed artifact records the honest number)
    ckpt_rows = [r for r in rows
                 if r.get("section") == "checkpoint_overhead"]
    assert ckpt_rows, "checkpoint_overhead section missing from " + path
    for r in ckpt_rows:
        for k in ("env_steps_per_sec", "baseline_env_steps_per_sec"):
            v = float(r[k])
            assert math.isfinite(v) and v > 0, (k, r)
        assert math.isfinite(float(r["overhead_frac"])), r
        assert float(r["overhead_frac"]) < 0.5, (
            "async checkpointing cost exceeds 50% of throughput — the "
            "writer is blocking the driver", r)
        assert int(r["saves"]) > 0 and int(r["bytes_per_save"]) > 0, r
    print(f"BENCH_actor_learner.json schema OK "
          f"({len(async_rows)} async overlap rows, "
          f"{len(ckpt_rows)} checkpoint-overhead rows)")


def _check_actor_throughput_schema() -> None:
    """Schema gate on ``BENCH_actor_throughput.json`` (ISSUE 5): the fused
    single-pass section must be present with every (bits, depth) cell
    carrying BOTH modes — a fused row without its per-layer baseline means
    the comparison silently broke — all throughputs finite and positive,
    and the int4 footprint at most ~half the int8 cache.  ISSUE 6 adds the
    kernel-backend matrix: the xla backend must appear with both modes and
    a recorded ``speedup_vs_fp32`` per cell."""
    import json
    import math

    path = os.path.join(_ROOT, "artifacts", "bench",
                        "BENCH_actor_throughput.json")
    with open(path) as f:
        rows = json.load(f)
    fused = [r for r in rows if r.get("section") == "fused_qmlp"]
    assert fused, "fused_qmlp section missing from " + path
    for r in rows:
        for k in ("steps_per_sec", "env_steps_per_sec"):
            if k in r:
                v = float(r[k])
                assert math.isfinite(v) and v > 0, (k, r)
    cells = {}
    for r in fused:
        v = float(r["us_per_call"])
        assert math.isfinite(v) and v > 0, r
        cells.setdefault((r["bits"], r["depth"]), set()).add(r["mode"])
    for cell, modes in cells.items():
        assert modes == {"fused", "per_layer"}, (cell, modes)
    foot = [r for r in rows if r.get("section") == "fused_qmlp_footprint"]
    assert foot and float(foot[0]["int4_frac"]) <= 0.55, foot
    matrix = [r for r in rows if r.get("section") == "backend_matrix"]
    assert matrix, "backend_matrix section missing from " + path
    xla_modes = set()
    for r in matrix:
        for k in ("us_per_call", "env_steps_per_sec", "fp32_us_per_call",
                  "speedup_vs_fp32"):
            assert k in r, (k, r)
            v = float(r[k])
            assert math.isfinite(v) and v > 0, (k, r)
        if r["backend"] == "xla":
            xla_modes.add(r["mode"])
    assert xla_modes == {"fused", "per_layer"}, xla_modes
    print(f"BENCH_actor_throughput.json schema OK ({len(cells)} fused "
          f"cells, {len(matrix)} backend-matrix rows, "
          f"int4_frac={float(foot[0]['int4_frac']):.3f})")


def _check_serving_schema() -> None:
    """Schema gate on ``BENCH_serving.json`` (ISSUE 7): every actor
    backend must appear in BOTH sections, the open-loop rows must carry
    >= 512 concurrent sessions with finite positive rates and ordered
    latency percentiles (p50 <= p99), and the quantized caches must be
    smaller than fp32 (the cache column is the paper's footprint claim)."""
    import json
    import math

    path = os.path.join(_ROOT, "artifacts", "bench", "BENCH_serving.json")
    with open(path) as f:
        rows = json.load(f)
    cap = {r["backend"]: r for r in rows
           if r.get("section") == "serve_capacity"}
    load = {r["backend"]: r for r in rows
            if r.get("section") == "serve_load"}
    want = {"fp32", "int8", "int4"}
    assert set(cap) == want and set(load) == want, (set(cap), set(load))
    for b, r in load.items():
        assert int(r["sessions"]) >= 512, r
        for k in ("offered_rps", "sustained_rps", "p50_ms", "p99_ms",
                  "mean_batch"):
            v = float(r[k])
            assert math.isfinite(v) and v > 0, (b, k, r)
        assert float(r["p50_ms"]) <= float(r["p99_ms"]), (b, r)
        assert int(r["dispatches"]) < int(r["requests"]), (b, r)
    for b in ("int8", "int4"):
        assert cap[b]["cache_nbytes"] < cap["fp32"]["cache_nbytes"], b
    assert cap["int4"]["cache_nbytes"] < cap["int8"]["cache_nbytes"]
    print(f"BENCH_serving.json schema OK ({len(load)} backends, "
          f"{load['int8']['sessions']} sessions)")


def _check_transformer_actor_schema() -> None:
    """Schema gate on ``BENCH_transformer_actor.json`` (ISSUE 9): every
    context cell must carry all three execution modes with finite
    positive rates — a missing mode means one side of the windowed vs
    KV-cache comparison silently broke — and the footprint row must show
    the int8-coded cache well under the fp32 cache (codes are 1 byte of
    4; the per-token scales add the rest)."""
    import json
    import math

    path = os.path.join(_ROOT, "artifacts", "bench",
                        "BENCH_transformer_actor.json")
    with open(path) as f:
        rows = json.load(f)
    cells = {}
    for r in rows:
        if r.get("section") != "transformer_actor":
            continue
        for k in ("us_per_call", "env_steps_per_sec"):
            v = float(r[k])
            assert math.isfinite(v) and v > 0, (k, r)
        cells.setdefault(int(r["context"]), set()).add(r["mode"])
    assert cells, "transformer_actor section missing from " + path
    want = {"fp32_windowed", "int8_windowed", "int8_kv_cache"}
    for context, modes in cells.items():
        assert modes == want, (context, modes)
    foot = [r for r in rows
            if r.get("section") == "transformer_actor_footprint"]
    assert foot, "footprint row missing from " + path
    assert 0 < float(foot[0]["int8_frac"]) <= 0.5, foot
    print(f"BENCH_transformer_actor.json schema OK ({len(cells)} context "
          f"cells, int8_frac={float(foot[0]['int8_frac']):.3f})")


def _check_resilience_schema() -> None:
    """Schema gate on ``BENCH_resilience.json`` (ISSUE 10): the guard
    stack must cost under 5% of steady-state training throughput, every
    supervised recovery row must recover exactly what it injected (all
    three topologies present), and the bounded-queue overload row must
    shed with typed rejections while answering every accepted request."""
    import json
    import math

    path = os.path.join(_ROOT, "artifacts", "bench",
                        "BENCH_resilience.json")
    with open(path) as f:
        rows = json.load(f)
    guard = [r for r in rows if r.get("section") == "guard_overhead"]
    assert guard, "guard_overhead section missing from " + path
    for r in guard:
        frac = float(r["overhead_frac"])
        assert math.isfinite(frac) and frac < 0.05, (
            "guard stack costs >= 5% of training throughput", r)
        assert float(r["round_ms"]) > 0, r
        assert float(r["guard_ms_per_check"]) > 0, r
    rec = [r for r in rows if r.get("section") == "recovery"]
    assert {r["topology"] for r in rec} == \
        {"fused", "actor-learner", "async"}, rec
    for r in rec:
        assert r["status"] == "ok", ("supervised run did not recover", r)
        assert int(r["fired"]) == int(r["injected"]), (
            "an injected fault never fired", r)
        assert int(r["recovered"]) == int(r["injected"]), (
            "recovery count != injected count", r)
        assert int(r["not_applicable"]) == 0, r
    shed = [r for r in rows if r.get("section") == "serve_shedding"]
    assert shed, "serve_shedding section missing from " + path
    for r in shed:
        assert int(r["rejected"]) > 0, (
            "2x-capacity overload produced no typed rejections", r)
        assert int(r["served"]) == int(r["accepted"]), (
            "an accepted request went unanswered", r)
        assert int(r["accepted"]) + int(r["rejected"]) \
            == int(r["requests"]), r
    print(f"BENCH_resilience.json schema OK ({len(rec)} recovery rows, "
          f"guard overhead {float(guard[0]['overhead_frac']) * 100:.2f}%, "
          f"{shed[0]['rejected']} requests shed)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="reduced matrix (same as REPRO_BENCH_FAST=1)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: reduced matrix on tiny budgets")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ.setdefault("REPRO_BENCH_SCALE", "0.25")
    fast = (args.fast or args.smoke
            or os.environ.get("REPRO_BENCH_FAST", "0") == "1")

    t0 = time.time()
    print("benchmark,us_per_call,derived")
    jobs = []

    from benchmarks import (actor_learner, actor_throughput, deployment,
                            exploration, mixed_precision, ptq_rewards,
                            qat_bitwidth, resilience, roofline,
                            serve_load, transformer_actor,
                            weight_distribution)

    if fast:
        jobs = [
            ("table2_ptq", lambda: ptq_rewards.run(
                matrix=[("ppo", "cartpole", 120), ("ppo", "airnav", 100),
                        ("a2c", "cartpole", 600), ("dqn", "cartpole", 500),
                        ("ddpg", "pendulum", 200),
                        ("ddpg", "mountaincar_continuous", 150)])),
            ("fig2_qat_bitwidth", lambda: qat_bitwidth.run(
                "ppo", "cartpole", iterations=120)),
            ("table3_weight_distribution", lambda: weight_distribution.run(
                cases=[("dqn", "cartpole", 500), ("dqn", "catch", 60),
                       ("ppo", "cartpole", 120), ("a2c", "cartpole", 600)])),
            ("fig1_exploration", lambda: exploration.run(
                "a2c", "cartpole", iterations=400)),
            ("table4_mixed_precision", lambda: mixed_precision.run()),
            ("fig5_mp_convergence",
             lambda: mixed_precision.convergence_check(steps=60)),
            ("table5_deployment", lambda: deployment.run(iterations=100)),
            ("actorq_throughput",
             lambda: (actor_throughput.run(train_iterations=30),
                      _check_actor_throughput_schema())),
            ("actor_learner_topology",
             lambda: (actor_learner.run(iters=10),
                      _check_actor_learner_schema())),
            ("serving_load",
             lambda: (serve_load.run(),
                      _check_serving_schema())),
            ("transformer_actor",
             lambda: (transformer_actor.run(batch=64, contexts=(4, 8)),
                      _check_transformer_actor_schema())),
            ("resilience",
             # guard_iters stays at the full default: the overhead
             # measurement is fixed-cost dominated, so shrinking the run
             # only raises the noise floor against the 5% gate
             lambda: (resilience.run(requests=512),
                      _check_resilience_schema())),
        ]
    else:
        jobs = [
            ("table2_ptq", ptq_rewards.run),
            ("fig2_qat_bitwidth", qat_bitwidth.run),
            ("table3_weight_distribution", weight_distribution.run),
            ("fig1_exploration", exploration.run),
            ("table4_mixed_precision", mixed_precision.run),
            ("fig5_mp_convergence", mixed_precision.convergence_check),
            ("table5_deployment", deployment.run),
            ("actorq_throughput",
             lambda: (actor_throughput.run(),
                      _check_actor_throughput_schema())),
            ("actor_learner_topology",
             lambda: (actor_learner.run(),
                      _check_actor_learner_schema())),
            ("serving_load",
             lambda: (serve_load.run(),
                      _check_serving_schema())),
            ("transformer_actor",
             lambda: (transformer_actor.run(),
                      _check_transformer_actor_schema())),
            ("resilience",
             lambda: (resilience.run(),
                      _check_resilience_schema())),
        ]
    jobs.append(("roofline", roofline.main))

    failures = 0
    for name, fn in jobs:
        print(f"\n### {name}")
        t = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        print(f"### {name} done in {time.time() - t:.0f}s")
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s, "
          f"{failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
