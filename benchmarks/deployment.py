"""Paper Table 5 + Figure 6: quantized policy deployment.

The paper deploys navigation policies (3-layer MLPs: 64 / 256 /
4096-512-1024) on a RasPi-3b and reports int8 speedup (up to 18.8x — mostly
from fitting in RAM) and 4x memory reduction.

TPU/offline adaptation: we train the same three policies on AirNav (the
Air-Learning-style env, paper Appendix D), then measure:
  * success rate fp32 vs int8 (paper's accuracy columns),
  * parameter-memory footprint fp32 vs int8-packed (exact 4x-ish),
  * inference latency fp32 vs the int8 path (weights packed int8,
    int8 GEMM with int32 accumulation — kernels/int8_matmul; on this CPU
    host the reported number is the XLA-CPU latency; the Pallas kernel is
    the TPU hot path).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common as C

POLICIES = {          # paper Table 5
    "policy_i": (64, 64, 64),
    "policy_ii": (256, 256, 256),
    "policy_iii": (4096, 512, 1024),
}


def _int8_infer_fn(packed_params):
    """MLP forward where every dense is the int8 GEMM path (rl.actorq)."""
    from repro.rl import actorq

    @jax.jit
    def infer(obs):
        return jnp.argmax(actorq.quantized_apply(packed_params, obs), -1)

    return infer


def run(iterations: int = 250) -> List[Dict]:
    from repro.core import ptq
    from repro.core.qconfig import QuantConfig
    from repro.rl import loops

    rows = []
    for name, widths in POLICIES.items():
        res = loops.train("ppo", "airnav", iterations=C.scaled(iterations),
                          net_kwargs={"hidden": widths}, seed=0)
        key = jax.random.PRNGKey(123)
        fp32_r = loops.eval_policy(res, QuantConfig.none(), key, episodes=16)
        int8_r = loops.eval_policy(res, QuantConfig.ptq_int(8), key,
                                   episodes=16)

        # memory footprint (paper Fig 6: 4x)
        fp32_bytes = ptq.tree_nbytes(res.state.params)
        packed = ptq.ptq_pack(res.state.params, QuantConfig.ptq_int(8))
        int8_bytes = ptq.tree_nbytes(packed)

        # latency: single-observation inference (deployment regime)
        obs = jnp.zeros((1, 9))
        from repro.core.fake_quant import NullQATContext
        ctx = NullQATContext()

        @jax.jit
        def fp32_infer(obs, params=res.state.params):
            return jnp.argmax(res.net.apply(ctx, params, obs), -1)

        int8_infer = _int8_infer_fn(packed)
        t_fp32 = C.time_fn(fp32_infer, obs, warmup=2, iters=10)
        t_int8 = C.time_fn(int8_infer, obs, warmup=2, iters=10)

        row = {"policy": name, "widths": widths,
               "fp32_reward": fp32_r, "int8_reward": int8_r,
               "fp32_mbytes": fp32_bytes / 1e6,
               "int8_mbytes": int8_bytes / 1e6,
               "mem_reduction": fp32_bytes / int8_bytes,
               "t_fp32_us": t_fp32 * 1e6, "t_int8_us": t_int8 * 1e6,
               "speedup": t_fp32 / t_int8}
        rows.append(row)
        C.emit(f"deploy/{name}/fp32", t_fp32 * 1e6,
               f"reward={fp32_r:.0f};mem={fp32_bytes / 1e6:.2f}MB")
        C.emit(f"deploy/{name}/int8", t_int8 * 1e6,
               f"reward={int8_r:.0f};mem={int8_bytes / 1e6:.2f}MB"
               f";mem_reduction={row['mem_reduction']:.2f}x"
               f";speedup={row['speedup']:.2f}x")
    C.save_rows("deployment", rows)
    return rows


if __name__ == "__main__":
    run()
