"""Actor–learner topology benchmark: the paper's distributed ActorQ claim.

Measures end-to-end training throughput of ``rl.actor_learner`` (DQN on
cartpole) across the topology matrix

    num_actors x {1, 2, 4}  ×  actor_backend x {fp32, int8}
                            ×  sync_every   x {1, 4}

plus a **uniform-vs-prioritized replay column** (ISSUE 3): the same
throughput cell with ``replay="prioritized"`` for a reduced sub-matrix —
the learner-samples/sec *cost* of the sum-tree (sampling descent + the
per-update priority push) — and a convergence section measuring the
*time-to-reward-threshold gain* of prioritized sampling on the fused DQN
driver (learner updates until the periodic eval first clears the
threshold).

Plus the **async overlap section** (ISSUE 4): the same cells driven
through ``topology="async"`` (``rl.actor_learner.make_async_actor_learner``
— actor rollout chunks and learner update chunks as two independent jit
programs over a double-buffered replay, dispatched with no
``block_until_ready`` between them).  Each async row measures
``env_steps_per_sec`` **and** ``learner_updates_per_sec`` over one shared
wall-clock window — i.e. concurrently, not sequentially — and reports the
env-steps speedup over the *fastest* bulk-synchronous cell with the same
``num_actors``/backend across sync cadences (so cheaper sync cadence
alone cannot explain the gap).  The total learner work per env step is
identical in both modes (``updates_per_iter`` updates per rollout),
leaving overlap + dispatch amortization as the remaining difference.

Two numbers per throughput cell, both measured after compile on the jitted
iteration(s):

* ``env_steps_per_sec``    — environment transitions collected per second
  (``num_actors * n_envs * rollout_steps`` per iteration): the actor-side
  throughput the paper scales by adding quantized actors,
* ``learner_samples_per_sec`` / ``learner_updates_per_sec`` — replay
  transitions (resp. gradient updates) consumed by the fp32 learner per
  second over the same window.

The acceptance rows: a >= 2-actor int8 configuration must beat the
1-actor fp32 baseline on env-steps/sec (ISSUE 2), and the 2-actor int8
*async* cell must beat the 2-actor int8 synchronous cell on env-steps/sec
(ISSUE 4).  On this CPU host the int8 path runs the ``ref`` oracle (the
Pallas kernel needs a TPU), so the speedups come from fan-out + overlap;
on TPU the W8A8 kernel compounds them.

Emits ``BENCH_actor_learner.json`` via ``benchmarks/common.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from benchmarks import common as C

ACTORS = (1, 2, 4)
BACKENDS = ("fp32", "int8")
SYNCS = (1, 4)
# prioritized rides a reduced sub-matrix (the replay discipline is
# orthogonal to fan-out/staleness; two cells bound the tree overhead)
PER_CELLS = ((1, "int8", 1), (2, "int8", 1))
ENV = "cartpole"


def _time_topology(num_actors: int, backend: str, sync_every: int,
                   iters: int, replay: str = "uniform") -> Dict:
    from repro.rl import actor_learner, dqn
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env(ENV)
    cfg = dqn.DQNConfig(n_envs=16, rollout_steps=8, updates_per_iter=4,
                        buffer_size=4096, batch_size=64, warmup=64,
                        actor_backend=backend, replay=replay)
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                          sync_every=sync_every)
    state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                               cfg, al)
    iteration, _, benv = actor_learner.make_actor_learner(
        "dqn", env, net, cfg, al)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    key, k = jax.random.split(key)
    state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)          # compile + warm

    t0 = time.perf_counter()
    for _ in range(iters):
        key, k = jax.random.split(key)
        state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)
    dt = time.perf_counter() - t0

    env_steps = iters * num_actors * cfg.n_envs * cfg.rollout_steps
    learner_updates = iters * cfg.updates_per_iter
    return {
        "section": "actor_learner",
        "mode": "sync",
        "num_actors": num_actors,
        "actor_backend": backend,
        "sync_every": sync_every,
        "replay": replay,
        "iters": iters,
        "wall_s": dt,
        "us_per_iter": dt / iters * 1e6,
        "env_steps_per_sec": env_steps / dt,
        "learner_samples_per_sec": learner_updates * cfg.batch_size / dt,
        "learner_updates_per_sec": learner_updates / dt,
        "divergence_last": [float(d) for d in state.divergence],
    }


# the async overlap cells ride the same env/config as the sync matrix;
# chunk = rollouts per actor-program dispatch (the steps_per_call analogue)
ASYNC_CELLS = ((2, "fp32"), (2, "int8"), (4, "int8"))
ASYNC_CHUNK = 8


def _time_async(num_actors: int, backend: str, iters: int,
                chunk: int = ASYNC_CHUNK, checkpointer=None) -> Dict:
    """One ``topology="async"`` throughput cell.

    Drives the two async programs exactly like ``loops._train_async``:
    per round one actor chunk (``chunk`` rollouts -> write slot) and one
    learner chunk (``chunk * updates_per_iter`` updates <- read slot) are
    dispatched back-to-back with **no** host barrier; slots swap and the
    snapshot refreshes at every round (sync_every = one round of learner
    updates).  Both throughputs come from the same wall-clock window —
    the overlap is measured, not inferred.

    ``checkpointer`` (an ``repro.checkpoint.AsyncCheckpointer``) saves
    the full round state after EVERY timed round — the worst-case
    checkpoint cadence, driven exactly like ``loops._train_async``'s
    save path (host copy on this thread, commit on the writer thread).
    The timed window covers the ``save_async`` submissions but not the
    final queue drain (a trailing flush is not a per-step cost).
    """
    from repro.rl import actor_learner, dqn
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env(ENV)
    cfg = dqn.DQNConfig(n_envs=16, rollout_steps=8, updates_per_iter=4,
                        buffer_size=4096, batch_size=64, warmup=64,
                        actor_backend=backend)
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    updates_per_round = chunk * cfg.updates_per_iter
    al = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                          sync_every=updates_per_round)
    progs = actor_learner.make_async_actor_learner("dqn", env, net, cfg,
                                                   al)
    learner, wbuf = actor_learner.init_async(jax.random.PRNGKey(0), env,
                                             net, "dqn", cfg, al)
    snap = progs.make_snapshot(learner)
    env_state, obs = progs.benv_global.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    def one_round(learner, wbuf, snap, env_state, obs, key):
        key, k_it = jax.random.split(key)
        k_roll, k_up = jax.random.split(k_it)
        env_state, obs, wbuf, _ = progs.actor_chunk(
            snap, env_state, obs, wbuf, k_roll, n_chunks=chunk)
        learner, _ = progs.learner_chunk(learner, k_up,
                                         n_updates=updates_per_round)
        learner, wbuf = actor_learner.swap_read_slot(learner, wbuf)
        snap = progs.make_snapshot(learner)
        return learner, wbuf, snap, env_state, obs, key

    carry = one_round(learner, wbuf, snap, env_state, obs, key)
    jax.block_until_ready((carry[0].params, carry[4]))   # compile + warm

    rounds = max(iters // chunk, 2)
    t0 = time.perf_counter()
    for rnd in range(rounds):
        carry = one_round(*carry)
        if checkpointer is not None:
            learner_c, wbuf_c, snap_c, env_state_c, obs_c, key_c = carry
            checkpointer.save_async(
                rnd + 1,
                {"learner": learner_c, "wbuf": wbuf_c, "snap": snap_c,
                 "env_state": env_state_c, "obs": obs_c, "key": key_c})
    jax.block_until_ready((carry[0].params, carry[4]))
    dt = time.perf_counter() - t0

    env_steps = rounds * chunk * num_actors * cfg.n_envs * cfg.rollout_steps
    learner_updates = rounds * updates_per_round
    return {
        "section": "actor_learner_async",
        "mode": "async",
        "num_actors": num_actors,
        "actor_backend": backend,
        "sync_every_updates": updates_per_round,
        "chunk": chunk,
        "rounds": rounds,
        "wall_s": dt,
        "us_per_round": dt / rounds * 1e6,
        "env_steps_per_sec": env_steps / dt,
        "learner_updates_per_sec": learner_updates / dt,
        "learner_samples_per_sec": learner_updates * cfg.batch_size / dt,
    }


CKPT_CELL = (2, "int8")     # the async acceptance cell carries the measure


def _time_checkpoint_overhead(iters: int, baseline: Dict) -> Dict:
    """ISSUE 8 acceptance row: the ``CKPT_CELL`` async cell re-timed with
    an ``AsyncCheckpointer`` saving the FULL round state (learner +
    optimizer + double-buffered replay + packed snapshot + env + key)
    after every round — the worst-case cadence.  ``overhead_frac``
    against the un-checkpointed ``baseline`` row must sit within noise:
    the driver thread only pays the device->host copy, while encode +
    fsync + rename run on the background writer.
    """
    import os
    import shutil
    import tempfile

    from repro.checkpoint import AsyncCheckpointer

    d = tempfile.mkdtemp(prefix="bench-ckpt-")
    try:
        ac = AsyncCheckpointer(d, keep=2)
        row = _time_async(*CKPT_CELL, iters, checkpointer=ac)
        t0 = time.perf_counter()
        last = ac.wait()
        drain_s = time.perf_counter() - t0
        step_dir = ac.manager.step_path(last)
        bytes_per_save = sum(
            os.path.getsize(os.path.join(step_dir, f))
            for f in os.listdir(step_dir))
        ac.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
    rate, base_rate = (row["env_steps_per_sec"],
                       baseline["env_steps_per_sec"])
    return {
        "section": "checkpoint_overhead",
        "mode": "async",
        "num_actors": CKPT_CELL[0],
        "actor_backend": CKPT_CELL[1],
        "checkpoint_every_rounds": 1,
        "saves": row["rounds"],
        "us_per_round": row["us_per_round"],
        "env_steps_per_sec": rate,
        "learner_updates_per_sec": row["learner_updates_per_sec"],
        "baseline_env_steps_per_sec": base_rate,
        "overhead_frac": 1.0 - rate / base_rate,
        "drain_wall_s": drain_s,
        "bytes_per_save": bytes_per_save,
    }


THRESHOLD = 2.0         # catch eval return over [-5, 5]; random play ~ -5
CONV_ENV = "catch"      # sparse-reward pixel env — where PER buys the most


def _time_to_threshold(replay: str, iterations: int) -> Dict:
    """Fused-DQN convergence on sparse-reward Catch: learner updates +
    wall time until the periodic eval first clears THRESHOLD (-1 = never;
    under ``--smoke`` budgets neither discipline gets there — the gain
    shows at full scale, mirroring the slow-marked test in
    ``tests/test_prioritized_replay.py``)."""
    from repro.rl import loops

    record_every = 50
    cfg = dict(n_envs=8, rollout_steps=8, updates_per_iter=4,
               buffer_size=8192, batch_size=32, warmup=256,
               eps_decay_updates=800, target_update_every=100)
    t0 = time.perf_counter()
    res = loops.train("dqn", CONV_ENV, iterations=iterations,
                      record_every=record_every, eval_episodes=16, seed=0,
                      steps_per_call=25, replay=replay,
                      net_kwargs=dict(conv_filters=(8, 8), fc_width=32),
                      algo_overrides=cfg)
    wall = time.perf_counter() - t0
    # loops.train records at record_every multiples AND at the final
    # (possibly partial) iteration — mirror that to map the first
    # threshold crossing back to an exact learner-update count
    positions = list(range(record_every, iterations + 1, record_every))
    if not positions or positions[-1] != iterations:
        positions.append(iterations)
    hit = next((i for i, r in enumerate(res.rewards) if r >= THRESHOLD),
               None)
    updates = -1 if hit is None \
        else positions[hit] * cfg["updates_per_iter"]
    return {
        "section": "replay_convergence",
        "env": CONV_ENV,
        "replay": replay,
        "iterations": iterations,
        "reward_threshold": THRESHOLD,
        "rewards": [float(r) for r in res.rewards],
        "learner_updates_to_threshold": updates,
        "wall_s": wall,
    }


def run(iters: int = 30) -> List[Dict]:
    iters = C.scaled(iters)
    rows = []
    base = None
    matrix = [(a, b, s, "uniform")
              for a in ACTORS for b in BACKENDS for s in SYNCS]
    matrix += [(a, b, s, "prioritized") for a, b, s in PER_CELLS]
    for num_actors, backend, sync_every, replay in matrix:
        row = _time_topology(num_actors, backend, sync_every, iters,
                             replay=replay)
        if (num_actors, backend, sync_every, replay) == \
                (1, "fp32", 1, "uniform"):
            base = row
        row["speedup_env_steps_vs_1actor_fp32"] = (
            row["env_steps_per_sec"] / base["env_steps_per_sec"]
            if base else 1.0)
        rows.append(row)
        C.emit(
            f"actor_learner/{backend}/a{num_actors}/s{sync_every}"
            f"/{replay}",
            row["us_per_iter"],
            f"env_steps_per_sec={row['env_steps_per_sec']:.0f}"
            f";learner_sps={row['learner_samples_per_sec']:.0f}"
            f";speedup="
            f"{row['speedup_env_steps_vs_1actor_fp32']:.2f}x")

    # async overlap cells (ISSUE 4): same work ratio, two overlapped
    # programs.  The baseline is the FASTEST synchronous cell with
    # matching actors/backend across all sync cadences — the async rounds
    # repack/push only once per sync period, so comparing against
    # sync_every=1 alone would conflate reduced sync cadence with the
    # overlap; taking the best sync cell keeps the reported speedup
    # attributable to overlap + dispatch amortization
    sync_rows: Dict = {}
    for r in rows:
        if r.get("section") != "actor_learner" or r["replay"] != "uniform":
            continue
        cell = (r["num_actors"], r["actor_backend"])
        if (cell not in sync_rows or r["env_steps_per_sec"]
                > sync_rows[cell]["env_steps_per_sec"]):
            sync_rows[cell] = r
    for num_actors, backend in ASYNC_CELLS:
        row = _time_async(num_actors, backend, iters)
        ref = sync_rows.get((num_actors, backend))
        if ref is None:
            # a fabricated neutral speedup would read as a measurement —
            # a missing baseline must fail the run instead
            raise RuntimeError(
                f"no sync baseline cell for async cell "
                f"({num_actors}, {backend!r})")
        row["speedup_env_steps_vs_sync"] = (
            row["env_steps_per_sec"] / ref["env_steps_per_sec"])
        row["sync_baseline_sync_every"] = ref["sync_every"]
        rows.append(row)
        C.emit(
            f"actor_learner/async/{backend}/a{num_actors}"
            f"/c{row['chunk']}",
            row["us_per_round"],
            f"env_steps_per_sec={row['env_steps_per_sec']:.0f}"
            f";learner_ups={row['learner_updates_per_sec']:.1f}"
            f";speedup_vs_sync="
            f"{row['speedup_env_steps_vs_sync']:.2f}x")

    # async checkpointing overhead (ISSUE 8): per-round saves must sit
    # within noise of the matching un-checkpointed async cell
    async_base = next(
        r for r in rows if r.get("section") == "actor_learner_async"
        and (r["num_actors"], r["actor_backend"]) == CKPT_CELL)
    row = _time_checkpoint_overhead(iters, async_base)
    rows.append(row)
    C.emit(
        f"actor_learner/ckpt_overhead/{CKPT_CELL[1]}/a{CKPT_CELL[0]}",
        row["us_per_round"],
        f"env_steps_per_sec={row['env_steps_per_sec']:.0f}"
        f";baseline={row['baseline_env_steps_per_sec']:.0f}"
        f";overhead={row['overhead_frac'] * 100:.1f}%"
        f";bytes_per_save={row['bytes_per_save']}")

    # uniform-vs-prioritized convergence (time-to-reward-threshold gain)
    conv_iters = C.scaled(800)
    conv = {r: _time_to_threshold(r, conv_iters)
            for r in ("uniform", "prioritized")}
    for replay, row in conv.items():
        rows.append(row)
        C.emit(f"actor_learner/convergence/{replay}",
               row["wall_s"] * 1e6,
               f"updates_to_{THRESHOLD:.0f}="
               f"{row['learner_updates_to_threshold']}")
    u, p = (conv[r]["learner_updates_to_threshold"]
            for r in ("uniform", "prioritized"))
    if p > 0 and (u < 0 or p < u):
        print(f"prioritized reached reward {THRESHOLD:.0f} in {p} learner "
              f"updates vs uniform {'never' if u < 0 else u}")

    path = C.save_rows("BENCH_actor_learner", rows)
    print(f"wrote {path}")
    accept = [r for r in rows
              if r.get("section") == "actor_learner"
              and r["num_actors"] >= 2 and r["actor_backend"] == "int8"
              and r["speedup_env_steps_vs_1actor_fp32"] > 1.0]
    print(f"acceptance: {len(accept)} int8 multi-actor configs beat the "
          f"1-actor fp32 baseline on env-steps/sec")
    overlap = [r for r in rows
               if r.get("section") == "actor_learner_async"
               and r["num_actors"] >= 2 and r["actor_backend"] == "int8"
               and r["speedup_env_steps_vs_sync"] > 1.0]
    print(f"acceptance: {len(overlap)} int8 multi-actor async cells beat "
          f"their synchronous counterpart on env-steps/sec (learner "
          f"updates measured concurrently)")
    return rows


if __name__ == "__main__":
    run()
