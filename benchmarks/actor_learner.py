"""Actor–learner topology benchmark: the paper's distributed ActorQ claim.

Measures end-to-end training throughput of ``rl.actor_learner`` (DQN on
cartpole) across the topology matrix

    num_actors x {1, 2, 4}  ×  actor_backend x {fp32, int8}
                            ×  sync_every   x {1, 4}

plus a **uniform-vs-prioritized replay column** (ISSUE 3): the same
throughput cell with ``replay="prioritized"`` for a reduced sub-matrix —
the learner-samples/sec *cost* of the sum-tree (sampling descent + the
per-update priority push) — and a convergence section measuring the
*time-to-reward-threshold gain* of prioritized sampling on the fused DQN
driver (learner updates until the periodic eval first clears the
threshold).

Two numbers per throughput cell, both measured after compile on the jitted
iteration:

* ``env_steps_per_sec``    — environment transitions collected per second
  (``num_actors * n_envs * rollout_steps`` per iteration): the actor-side
  throughput the paper scales by adding quantized actors,
* ``learner_samples_per_sec`` — replay transitions consumed by the fp32
  learner per second (``updates_per_iter * batch_size`` per iteration).

The acceptance row (ISSUE 2): a >= 2-actor int8 configuration must beat the
1-actor fp32 baseline on env-steps/sec.  On this CPU host the int8 path
runs the ``ref`` oracle (the Pallas kernel needs a TPU), so the speedup
comes from the actor fan-out; on TPU the W8A8 kernel compounds it.

Emits ``BENCH_actor_learner.json`` via ``benchmarks/common.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from benchmarks import common as C

ACTORS = (1, 2, 4)
BACKENDS = ("fp32", "int8")
SYNCS = (1, 4)
# prioritized rides a reduced sub-matrix (the replay discipline is
# orthogonal to fan-out/staleness; two cells bound the tree overhead)
PER_CELLS = ((1, "int8", 1), (2, "int8", 1))
ENV = "cartpole"


def _time_topology(num_actors: int, backend: str, sync_every: int,
                   iters: int, replay: str = "uniform") -> Dict:
    from repro.rl import actor_learner, dqn
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env(ENV)
    cfg = dqn.DQNConfig(n_envs=16, rollout_steps=8, updates_per_iter=4,
                        buffer_size=4096, batch_size=64, warmup=64,
                        actor_backend=backend, replay=replay)
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                          sync_every=sync_every)
    state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                               cfg, al)
    iteration, _, benv = actor_learner.make_actor_learner(
        "dqn", env, net, cfg, al)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    key, k = jax.random.split(key)
    state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)          # compile + warm

    t0 = time.perf_counter()
    for _ in range(iters):
        key, k = jax.random.split(key)
        state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)
    dt = time.perf_counter() - t0

    env_steps = iters * num_actors * cfg.n_envs * cfg.rollout_steps
    learner_samples = iters * cfg.updates_per_iter * cfg.batch_size
    return {
        "section": "actor_learner",
        "num_actors": num_actors,
        "actor_backend": backend,
        "sync_every": sync_every,
        "replay": replay,
        "iters": iters,
        "wall_s": dt,
        "us_per_iter": dt / iters * 1e6,
        "env_steps_per_sec": env_steps / dt,
        "learner_samples_per_sec": learner_samples / dt,
        "divergence_last": [float(d) for d in state.divergence],
    }


THRESHOLD = 2.0         # catch eval return over [-5, 5]; random play ~ -5
CONV_ENV = "catch"      # sparse-reward pixel env — where PER buys the most


def _time_to_threshold(replay: str, iterations: int) -> Dict:
    """Fused-DQN convergence on sparse-reward Catch: learner updates +
    wall time until the periodic eval first clears THRESHOLD (-1 = never;
    under ``--smoke`` budgets neither discipline gets there — the gain
    shows at full scale, mirroring the slow-marked test in
    ``tests/test_prioritized_replay.py``)."""
    from repro.rl import loops

    record_every = 50
    cfg = dict(n_envs=8, rollout_steps=8, updates_per_iter=4,
               buffer_size=8192, batch_size=32, warmup=256,
               eps_decay_updates=800, target_update_every=100)
    t0 = time.perf_counter()
    res = loops.train("dqn", CONV_ENV, iterations=iterations,
                      record_every=record_every, eval_episodes=16, seed=0,
                      steps_per_call=25, replay=replay,
                      net_kwargs=dict(conv_filters=(8, 8), fc_width=32),
                      algo_overrides=cfg)
    wall = time.perf_counter() - t0
    # loops.train records at record_every multiples AND at the final
    # (possibly partial) iteration — mirror that to map the first
    # threshold crossing back to an exact learner-update count
    positions = list(range(record_every, iterations + 1, record_every))
    if not positions or positions[-1] != iterations:
        positions.append(iterations)
    hit = next((i for i, r in enumerate(res.rewards) if r >= THRESHOLD),
               None)
    updates = -1 if hit is None \
        else positions[hit] * cfg["updates_per_iter"]
    return {
        "section": "replay_convergence",
        "env": CONV_ENV,
        "replay": replay,
        "iterations": iterations,
        "reward_threshold": THRESHOLD,
        "rewards": [float(r) for r in res.rewards],
        "learner_updates_to_threshold": updates,
        "wall_s": wall,
    }


def run(iters: int = 30) -> List[Dict]:
    iters = C.scaled(iters)
    rows = []
    base = None
    matrix = [(a, b, s, "uniform")
              for a in ACTORS for b in BACKENDS for s in SYNCS]
    matrix += [(a, b, s, "prioritized") for a, b, s in PER_CELLS]
    for num_actors, backend, sync_every, replay in matrix:
        row = _time_topology(num_actors, backend, sync_every, iters,
                             replay=replay)
        if (num_actors, backend, sync_every, replay) == \
                (1, "fp32", 1, "uniform"):
            base = row
        row["speedup_env_steps_vs_1actor_fp32"] = (
            row["env_steps_per_sec"] / base["env_steps_per_sec"]
            if base else 1.0)
        rows.append(row)
        C.emit(
            f"actor_learner/{backend}/a{num_actors}/s{sync_every}"
            f"/{replay}",
            row["us_per_iter"],
            f"env_steps_per_sec={row['env_steps_per_sec']:.0f}"
            f";learner_sps={row['learner_samples_per_sec']:.0f}"
            f";speedup="
            f"{row['speedup_env_steps_vs_1actor_fp32']:.2f}x")

    # uniform-vs-prioritized convergence (time-to-reward-threshold gain)
    conv_iters = C.scaled(800)
    conv = {r: _time_to_threshold(r, conv_iters)
            for r in ("uniform", "prioritized")}
    for replay, row in conv.items():
        rows.append(row)
        C.emit(f"actor_learner/convergence/{replay}",
               row["wall_s"] * 1e6,
               f"updates_to_{THRESHOLD:.0f}="
               f"{row['learner_updates_to_threshold']}")
    u, p = (conv[r]["learner_updates_to_threshold"]
            for r in ("uniform", "prioritized"))
    if p > 0 and (u < 0 or p < u):
        print(f"prioritized reached reward {THRESHOLD:.0f} in {p} learner "
              f"updates vs uniform {'never' if u < 0 else u}")

    path = C.save_rows("BENCH_actor_learner", rows)
    print(f"wrote {path}")
    accept = [r for r in rows
              if r.get("section") == "actor_learner"
              and r["num_actors"] >= 2 and r["actor_backend"] == "int8"
              and r["speedup_env_steps_vs_1actor_fp32"] > 1.0]
    print(f"acceptance: {len(accept)} int8 multi-actor configs beat the "
          f"1-actor fp32 baseline on env-steps/sec")
    return rows


if __name__ == "__main__":
    run()
