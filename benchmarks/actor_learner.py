"""Actor–learner topology benchmark: the paper's distributed ActorQ claim.

Measures end-to-end training throughput of ``rl.actor_learner`` (DQN on
cartpole) across the topology matrix

    num_actors x {1, 2, 4}  ×  actor_backend x {fp32, int8}
                            ×  sync_every   x {1, 4}

Two numbers per cell, both measured after compile on the jitted iteration:

* ``env_steps_per_sec``    — environment transitions collected per second
  (``num_actors * n_envs * rollout_steps`` per iteration): the actor-side
  throughput the paper scales by adding quantized actors,
* ``learner_samples_per_sec`` — replay transitions consumed by the fp32
  learner per second (``updates_per_iter * batch_size`` per iteration).

The acceptance row (ISSUE 2): a >= 2-actor int8 configuration must beat the
1-actor fp32 baseline on env-steps/sec.  On this CPU host the int8 path
runs the ``ref`` oracle (the Pallas kernel needs a TPU), so the speedup
comes from the actor fan-out; on TPU the W8A8 kernel compounds it.

Emits ``BENCH_actor_learner.json`` via ``benchmarks/common.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from benchmarks import common as C

ACTORS = (1, 2, 4)
BACKENDS = ("fp32", "int8")
SYNCS = (1, 4)
ENV = "cartpole"


def _time_topology(num_actors: int, backend: str, sync_every: int,
                   iters: int) -> Dict:
    from repro.rl import actor_learner, dqn
    from repro.rl.envs import make as make_env
    from repro.rl.networks import make_network

    env = make_env(ENV)
    cfg = dqn.DQNConfig(n_envs=16, rollout_steps=8, updates_per_iter=4,
                        buffer_size=4096, batch_size=64, warmup=64,
                        actor_backend=backend)
    net = make_network(env.spec.obs_shape, env.spec.n_actions)
    al = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                          sync_every=sync_every)
    state = actor_learner.init(jax.random.PRNGKey(0), env, net, "dqn",
                               cfg, al)
    iteration, _, benv = actor_learner.make_actor_learner(
        "dqn", env, net, cfg, al)
    env_state, obs = benv.reset(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    key, k = jax.random.split(key)
    state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)          # compile + warm

    t0 = time.perf_counter()
    for _ in range(iters):
        key, k = jax.random.split(key)
        state, env_state, obs, m = iteration(state, env_state, obs, k)
    jax.block_until_ready(state.learner.params)
    dt = time.perf_counter() - t0

    env_steps = iters * num_actors * cfg.n_envs * cfg.rollout_steps
    learner_samples = iters * cfg.updates_per_iter * cfg.batch_size
    return {
        "section": "actor_learner",
        "num_actors": num_actors,
        "actor_backend": backend,
        "sync_every": sync_every,
        "iters": iters,
        "wall_s": dt,
        "us_per_iter": dt / iters * 1e6,
        "env_steps_per_sec": env_steps / dt,
        "learner_samples_per_sec": learner_samples / dt,
        "divergence_last": [float(d) for d in state.divergence],
    }


def run(iters: int = 30) -> List[Dict]:
    iters = C.scaled(iters)
    rows = []
    base = None
    for num_actors in ACTORS:
        for backend in BACKENDS:
            for sync_every in SYNCS:
                row = _time_topology(num_actors, backend, sync_every, iters)
                if (num_actors, backend, sync_every) == (1, "fp32", 1):
                    base = row
                row["speedup_env_steps_vs_1actor_fp32"] = (
                    row["env_steps_per_sec"] / base["env_steps_per_sec"]
                    if base else 1.0)
                rows.append(row)
                C.emit(
                    f"actor_learner/{backend}/a{num_actors}/s{sync_every}",
                    row["us_per_iter"],
                    f"env_steps_per_sec={row['env_steps_per_sec']:.0f}"
                    f";learner_sps={row['learner_samples_per_sec']:.0f}"
                    f";speedup="
                    f"{row['speedup_env_steps_vs_1actor_fp32']:.2f}x")

    path = C.save_rows("BENCH_actor_learner", rows)
    print(f"wrote {path}")
    accept = [r for r in rows
              if r["num_actors"] >= 2 and r["actor_backend"] == "int8"
              and r["speedup_env_steps_vs_1actor_fp32"] > 1.0]
    print(f"acceptance: {len(accept)} int8 multi-actor configs beat the "
          f"1-actor fp32 baseline on env-steps/sec")
    return rows


if __name__ == "__main__":
    run()
