"""Sequence-policy actor benchmark: env-steps/sec vs context length.

The ISSUE 9 systems claim: the int8 KV-cache decode path
(``rl.actorq.quantized_seq_step``) turns the transformer actor's per-step
cost from O(context) re-encoding into O(1) incremental decode, and the
int8-coded cache is a fraction of an fp32 cache's bytes.  Three execution
modes per context length, all selecting actions for the same env batch:

* ``fp32_windowed``   — full fp32 forward over the (context, feat) frame
  stack every step (what the learner/eval path runs),
* ``int8_windowed``   — the packed windowed mirror
  (``actorq.quantized_seq_apply``), same token set, int8 GEMMs,
* ``int8_kv_cache``   — the deployment hot path: one frame row in, int8
  KV-cache write + masked decode via ``ops.int8_cache_attention``.

Plus the footprint row: per-env packed int8 cache bytes (codes + scales)
vs the equivalent fp32 K/V cache.  Emits ``BENCH_transformer_actor.json``
via ``benchmarks/common.py``; ``run.py`` schema-gates it.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks import common as C

CONTEXTS = (4, 8, 16)
BATCH = 256
NET = {"d_model": 32, "n_layers": 2, "d_ff": 64}


def _build(context: int):
    from repro.rl import actorq
    from repro.rl.envs import make
    from repro.rl.networks import make_network

    env = make("catch_seq", context=context)
    net = make_network(env.spec.obs_shape, env.spec.n_actions,
                       transformer=dict(NET))
    params = net.init(jax.random.PRNGKey(0))
    qparams = actorq.pack_actor_params(params, 8)
    return env, net, params, qparams


def run(batch: int = BATCH, contexts=CONTEXTS) -> List[Dict]:
    from repro.core.qconfig import QuantConfig
    from repro.rl import actorq
    from repro.rl import common as rl_common

    batch = C.scaled(batch, lo=8)
    rows: List[Dict] = []
    for context in contexts:
        env, net, params, qparams = _build(context)
        cfg = net.seq_cfg
        obs = jax.random.normal(jax.random.PRNGKey(1),
                                (batch,) + env.spec.obs_shape)
        obs = obs.at[..., -1].set(1.0)
        feat = obs[:, -1, :]
        pstate = actorq.seq_cache_zeros(cfg, batch,
                                        env.spec.max_steps + 1)

        @jax.jit
        def fp32_act(obs):
            ctx = rl_common.make_ctx(QuantConfig.none(), {},
                                     jnp.zeros((), jnp.int32))
            return jnp.argmax(net.apply(ctx, params, obs), axis=-1)

        @jax.jit
        def int8_windowed_act(obs):
            return jnp.argmax(
                actorq.quantized_seq_apply(qparams, obs), axis=-1)

        @jax.jit
        def int8_cached_act(feat, pstate):
            q, pstate = actorq.quantized_seq_step(
                qparams, feat, pstate, context=cfg.context)
            return jnp.argmax(q, axis=-1), pstate

        for mode, fn, args in (
                ("fp32_windowed", fp32_act, (obs,)),
                ("int8_windowed", int8_windowed_act, (obs,)),
                ("int8_kv_cache", int8_cached_act, (feat, pstate))):
            secs = C.time_fn(fn, *args, warmup=2, iters=10)
            rate = batch / secs
            rows.append({"section": "transformer_actor",
                         "context": context, "mode": mode,
                         "batch": batch,
                         "us_per_call": secs * 1e6,
                         "env_steps_per_sec": rate})
            C.emit(f"transformer_actor/ctx{context}/{mode}", secs * 1e6,
                   f"{rate:.0f} env-steps/s")

    # footprint: per-env packed int8 cache vs an fp32 K/V cache of the
    # same layout (codes at 4 bytes, no scales)
    env, net, _, _ = _build(contexts[-1])
    cfg = net.seq_cfg
    size = env.spec.max_steps + 1
    from repro.rl import actorq as aq
    ps1 = aq.seq_cache_zeros(cfg, 1, size)
    int8_nbytes = aq.seq_cache_nbytes(ps1)
    fp32_nbytes = cfg.n_layers * 2 * size * cfg.d_model * 4 + 4
    frac = int8_nbytes / fp32_nbytes
    rows.append({"section": "transformer_actor_footprint",
                 "cache_slots": size,
                 "int8_cache_nbytes": int8_nbytes,
                 "fp32_cache_nbytes": fp32_nbytes,
                 "int8_frac": frac})
    C.emit("transformer_actor/footprint", 0.0,
           f"int8 {int8_nbytes}B vs fp32 {fp32_nbytes}B "
           f"({frac:.3f}x) per env")

    path = C.save_rows("BENCH_transformer_actor", rows)
    print(f"rows -> {path}")
    return rows
