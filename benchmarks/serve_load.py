"""Serving load benchmark: sustained req/s + p50/p99 step latency.

Drives ``repro.serving.PolicyServer`` with a heavy synthetic **open-loop**
load — arrival times are drawn from a fixed schedule independent of
completions, so queueing delay shows up in the latency numbers instead of
being absorbed by a closed feedback loop — across the fp32 / int8 / int4
actor backends at >= 512 concurrent sessions.

Per backend, two phases:

1. **capacity probes**: (a) device side — full max-bucket batches through
   ``serve_batch`` directly, the ceiling the batcher can amortize toward;
   (b) request path — a closed-loop burst through submit + worker, the
   rate the host-side dispatch machinery itself sustains.
2. **open-loop load**: one driver thread submits per the arrival schedule
   (offered rate = ``LOAD_FRACTION`` x the request-path capacity, so the
   reported percentiles measure a *stable* queue, not unbounded backlog
   growth), worker thread batches + serves; per-request latency =
   enqueue -> completion.

Emits ``artifacts/bench/BENCH_serving.json`` (sections ``serve_capacity``,
``serve_load``) — schema-gated by ``run.py --smoke``.  The capacity-
planning worked example in ``docs/serving.md`` reads straight off this
artifact.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common

BACKENDS = ("fp32", "int8", "int4")
LOAD_FRACTION = 0.6      # offered open-loop rate as a fraction of capacity
BUCKETS = (8, 32, 128, 512)
MAX_WAIT_US = 2000
CALIB_BATCH = 64


def _make_server(actor_backend: str):
    import jax

    from repro.rl.env import EnvSpec
    from repro.rl.networks import make_network
    from repro.serving import PolicyServer

    spec = EnvSpec(name="bench-serve", obs_shape=(4,), n_actions=2)
    params = make_network(spec.obs_shape, 2, hidden=(64, 64)).init(
        jax.random.PRNGKey(0))
    srv = PolicyServer(spec, actor_backend=actor_backend,
                       kernel_backend="auto", buckets=BUCKETS,
                       max_wait_us=MAX_WAIT_US, calib_batch=CALIB_BATCH)
    obs = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                       (CALIB_BATCH, 4)), np.float32)
    srv.push_params(params, calib_obs=obs)
    srv.warmup()
    return srv, spec


def _probe_capacity(srv, n_batches: int) -> float:
    """Closed-loop ceiling: full max-bucket dispatches, actions/sec."""
    from repro.serving.batcher import Request

    bucket = srv.buckets[-1]
    sids = [srv.open_session() for _ in range(bucket)]
    rng = np.random.default_rng(0)
    obs = rng.standard_normal((bucket, 4)).astype(np.float32)
    t0 = time.perf_counter()
    for _ in range(n_batches):
        srv.serve_batch([Request(sid, obs[i])
                         for i, sid in enumerate(sids)])
    dt = time.perf_counter() - t0
    for sid in sids:
        srv.close_session(sid)
    return n_batches * bucket / dt


def _open_loop(srv, sessions: int, requests: int, offered_rps: float):
    """Submit ``requests`` on a fixed arrival schedule; return latencies
    (seconds, in completion order) and the sustained service rate."""
    sids = [srv.open_session() for _ in range(sessions)]
    rng = np.random.default_rng(1)
    obs = rng.standard_normal((sessions, 4)).astype(np.float32)
    # deterministic uniform arrival schedule at the offered rate
    schedule = np.arange(requests) / offered_rps
    reqs = []
    with srv:
        t0 = time.perf_counter()
        for i in range(requests):
            now = time.perf_counter() - t0
            wait = schedule[i] - now
            if wait > 0:
                time.sleep(wait)
            s = i % sessions
            reqs.append(srv.submit(sids[s], obs[s]))
        lats = [r.result(timeout=120).latency_s for r in reqs]
        dt = time.perf_counter() - t0
    for sid in sids:
        srv.close_session(sid)
    return np.asarray(lats), requests / dt


def run(sessions: int = 512, requests: int = 4096,
        probe_batches: int = 20) -> list:
    """Benchmark every actor backend; emit + save BENCH_serving.json."""
    requests = common.scaled(requests, lo=256)
    probe_batches = common.scaled(probe_batches, lo=3)
    rows = []
    for backend in BACKENDS:
        srv, spec = _make_server(backend)
        cap = _probe_capacity(srv, probe_batches)
        # request-path ceiling: a short saturating burst through the real
        # submit -> batcher -> worker path (offering a fraction of the
        # device ceiling would overload the host-side dispatch machinery
        # and measure backlog growth instead of steady-state latency)
        _, path_rps = _open_loop(srv, min(sessions, 128),
                                 max(requests // 4, 64), offered_rps=1e9)
        nbytes = srv.current.nbytes
        rows.append(dict(section="serve_capacity", backend=backend,
                         bucket=srv.buckets[-1], actions_per_sec=cap,
                         request_path_rps=float(path_rps),
                         cache_nbytes=nbytes))
        common.emit(f"serve_capacity_{backend}", 1e6 / cap,
                    f"{cap:.0f}_actions_per_sec")
        offered = max(min(cap, path_rps) * LOAD_FRACTION, 1.0)
        before = srv.stats()       # probe counters must not pollute load
        lats, sustained = _open_loop(srv, sessions, requests, offered)
        after = srv.stats()
        dispatches = after["dispatches"] - before["dispatches"]
        served = after["served"] - before["served"]
        padding = after["padding_rows"] - before["padding_rows"]
        p50, p99 = (float(np.percentile(lats, q) * 1e3) for q in (50, 99))
        rows.append(dict(
            section="serve_load", backend=backend, sessions=sessions,
            requests=requests, offered_rps=float(offered),
            sustained_rps=float(sustained), p50_ms=p50, p99_ms=p99,
            mean_ms=float(lats.mean() * 1e3),
            dispatches=dispatches,
            mean_batch=served / max(dispatches, 1),
            padding_frac=padding / max(padding + served, 1),
            cache_nbytes=nbytes, buckets=list(srv.buckets),
            max_wait_us=MAX_WAIT_US, calib_batch=CALIB_BATCH))
        common.emit(f"serve_load_{backend}", p50 * 1e3,
                    f"{sustained:.0f}_rps_p99_{p99:.2f}ms")
        print(f"  {backend}: capacity {cap:.0f} act/s, offered "
              f"{offered:.0f} rps -> sustained {sustained:.0f} rps, "
              f"p50 {p50:.2f}ms p99 {p99:.2f}ms, "
              f"mean batch {rows[-1]['mean_batch']:.1f}, "
              f"cache {nbytes / 1e3:.1f}KB")
    common.save_rows("BENCH_serving", rows)
    return rows


if __name__ == "__main__":
    run()
