"""Resilience benchmark: guard overhead, recovery matrix, load shedding.

Three sections, one claim each (the self-healing runtime must be cheap
when nothing fails, effective when everything does, and bounded under
overload):

1. ``guard_overhead`` — the host-side guard primitives (finite check,
   packed-cache CRC, reference re-mint) microbenched on real
   params/cache and amortized over the ``CHECK_EVERY`` cadence, divided
   by the *marginal* per-round cost of a realistically sized
   actor-learner int8 run (iteration differencing cancels the per-call
   fixed compile/setup cost).  Claim: ``overhead_frac < 0.05``
   (schema-gated).
2. ``recovery`` — one supervised run per topology (fused /
   actor-learner / async) under a topology-appropriate deterministic
   ``FaultPlan`` covering all six fault kinds between them.  Claim: every
   injected fault fires and the run still converges to ``status == "ok"``
   — ``recovered == fired == injected`` per row (schema-gated).
3. ``serve_shedding`` — a ``PolicyServer`` with a bounded admission queue
   offered a closed-loop burst at ~2x its measured device capacity.
   Claim: the server sheds with typed ``QueueFullError`` rejections
   (``rejected > 0``) while every *accepted* request is still answered
   (``served == accepted``), instead of queueing without bound.

Emits ``artifacts/bench/BENCH_resilience.json`` — schema-gated by
``run.py`` (``_check_resilience_schema``).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks import common

CHECK_EVERY = 10       # guard cadence in the overhead run: ~5 ms/check
                       # amortized against ~25 ms rounds (docs/resilience.md
                       # says to pick the cadence against the round cost)
GUARD_ITERS = 40       # lo leg of the marginal-cost differencing (hi = 4x)
MAX_QUEUE = 16         # admission bound for the shedding run

# (topology, actor_backend, fault plan) — all six kinds across the matrix;
# dropped_sync only exists as a host-controlled push in the async driver
# (the sync topologies exchange params inside the jitted round).
RECOVERY_MATRIX = (
    ("fused", "fp32", "5:actor_crash@2,nan_grad@4"),
    ("actor-learner", "int8", "7:bitflip_push@4,nan_grad@6:mode=inf"),
    ("async", "int8",
     "9:dropped_sync@2,bitflip_push@4,straggler@5:delay_s=0.02,"
     "crash_commit@6"),
)


def _train_kwargs(topology: str, backend: str, iterations: int,
                  ckpt_dir=None):
    kw = dict(algo="dqn", env_name="cartpole", iterations=iterations,
              seed=3, record_every=max(iterations // 2, 1),
              eval_episodes=2)
    if topology != "fused":
        kw.update(topology=topology, num_actors=2, sync_every=2,
                  actor_backend=backend)
    if ckpt_dir is not None:
        kw.update(checkpoint_dir=ckpt_dir, checkpoint_every=2)
    return kw


def guard_overhead(iters: int = GUARD_ITERS) -> dict:
    """Amortized guard cost as a fraction of per-round training cost.

    An end-to-end guarded-vs-unguarded A/B cannot resolve a ~1 ms/round
    host-side hook here: each ``loops.train`` call carries a multi-second
    fixed cost (compile + setup) with hundreds of ms of host jitter, so
    the ratio is assembled from two *separately precise* measurements:

    * numerator — the primitives the guard hooks actually run per check
      (finite reduction over the learner params, packed-cache CRC, and
      the deterministic re-mint that produces the reference CRC),
      microbenched on the run's real params/cache (median of 50 reps of
      pure host work), amortized over the ``CHECK_EVERY`` cadence;
    * denominator — the marginal per-round cost of the same training
      configuration by iteration differencing
      (``(t(4*iters) - t(iters)) / (3*iters)``, median of 3 interleaved
      pairs), which cancels the per-call fixed cost exactly.

    The configuration is deliberately not a toy: a (256, 256)-hidden
    policy with batch-256 8-update learner rounds — the regime the
    <5% claim is about.  On a 4-unit cartpole net the ~3 ms re-mint
    rivals the whole round and no check cadence makes guards cheap;
    ``check_every`` exists precisely to amortize the re-mint against
    real round costs.
    """
    import jax

    from repro.rl import actorq, loops
    from repro.rl.networks import make_network
    from repro.resilience import guards

    net_kwargs = dict(hidden=(256, 256))
    overrides = dict(batch_size=256)       # default 8 updates/iter stays

    def med(fn, n=50):
        fn()                               # warm
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[n // 2]

    net = make_network((4,), 2, **net_kwargs)
    params = net.init(jax.random.PRNGKey(0))
    cache = actorq.make_actor_cache(params, "int8")
    finite_ms = med(lambda: guards.check_finite(params, what="p")) * 1e3
    crc_ms = med(lambda: guards.tree_crc32(cache)) * 1e3
    remint_ms = med(lambda: actorq.make_actor_cache(params, "int8")) * 1e3
    per_check_ms = finite_ms + remint_ms + 2.0 * crc_ms

    def run(n):
        kw = _train_kwargs("actor-learner", "int8", n)
        kw.update(net_kwargs=net_kwargs, algo_overrides=dict(overrides),
                  record_every=n)     # one eval per leg: cancels in diff
        return loops.train(**kw).wall_time_s

    run(iters), run(4 * iters)             # jit warmup for both legs
    margs = []
    for _ in range(3):                     # interleaved: drift cancels
        lo = run(iters)
        hi = run(4 * iters)
        margs.append((hi - lo) / (3 * iters))
    round_ms = sorted(margs)[1] * 1e3
    frac = (per_check_ms / CHECK_EVERY) / round_ms
    row = dict(section="guard_overhead", topology="actor-learner",
               backend="int8", iterations=iters,
               check_every=CHECK_EVERY, finite_ms=float(finite_ms),
               crc_ms=float(crc_ms), remint_ms=float(remint_ms),
               guard_ms_per_check=float(per_check_ms),
               round_ms=float(round_ms), overhead_frac=float(frac))
    common.emit("resilience_guard_overhead", round_ms * 1e3,
                f"overhead_{frac * 100:.2f}pct")
    print(f"  guards: {per_check_ms:.2f} ms/check every {CHECK_EVERY} "
          f"rounds over {round_ms:.2f} ms/round -> "
          f"{frac * 100:+.2f}% overhead")
    return row


def recovery_matrix(iterations: int = 8) -> list:
    """Supervised fault-plan runs: every injected fault must recover."""
    from repro import resilience as rz

    rows = []
    for topology, backend, spec in RECOVERY_MATRIX:
        plan = rz.FaultPlan.parse(spec)
        with tempfile.TemporaryDirectory() as d:
            kw = _train_kwargs(topology, backend, iterations, ckpt_dir=d)
            t0 = time.perf_counter()
            try:
                _, rep = rz.supervise(kw, plan=plan)
                status = rep.status
            except rz.SupervisorAbort as e:   # recorded, fails the gate
                rep, status = e.report, "abort"
            dt = time.perf_counter() - t0
        fired = len(rep.faults_fired)
        na = len(rep.faults_not_applicable)
        recovered = fired if status == "ok" else 0
        rows.append(dict(
            section="recovery", topology=topology, backend=backend,
            plan=spec, status=status, injected=len(plan.faults),
            fired=fired, not_applicable=na, recovered=recovered,
            retries=rep.retries, rollbacks=rep.rollbacks,
            attempts=rep.attempts, wall_s=float(dt)))
        common.emit(f"resilience_recovery_{topology}", dt * 1e6,
                    f"{recovered}of{len(plan.faults)}_recovered_"
                    f"{rep.retries}retries")
        print(f"  {topology}: {rep.summary().splitlines()[0]} "
              f"({fired} fault(s) fired)")
    return rows


def serve_shedding(requests: int = 1024) -> dict:
    """Bounded-queue overload: typed shedding at ~2x device capacity."""
    import jax

    from repro.rl.env import EnvSpec
    from repro.rl.networks import make_network
    from repro.serving import PolicyServer, QueueFullError
    from repro.serving.batcher import Request

    spec = EnvSpec(name="bench-resilience", obs_shape=(4,), n_actions=2)
    params = make_network(spec.obs_shape, 2, hidden=(64, 64)).init(
        jax.random.PRNGKey(0))
    srv = PolicyServer(spec, actor_backend="int8", buckets=(8, 32),
                       max_wait_us=500, max_queue=MAX_QUEUE)
    srv.push_params(params)
    srv.warmup()
    rng = np.random.default_rng(0)
    obs = rng.standard_normal((32, 4)).astype(np.float32)

    # device-capacity probe (no queue involved), then offer 2x that rate
    sids = [srv.open_session() for _ in range(32)]
    t0 = time.perf_counter()
    for _ in range(10):
        srv.serve_batch([Request(s, obs[i]) for i, s in enumerate(sids)])
    cap = 10 * 32 / (time.perf_counter() - t0)
    offered_rps = 2.0 * cap

    accepted, rejected = [], 0
    schedule = np.arange(requests) / offered_rps
    with srv:
        t0 = time.perf_counter()
        for i in range(requests):
            wait = schedule[i] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
            try:
                accepted.append(srv.submit(sids[i % 32], obs[i % 32]))
            except QueueFullError:
                rejected += 1
        served = sum(1 for r in accepted if r.result(timeout=120))
    for s in sids:
        srv.close_session(s)
    stats = srv.stats()
    assert stats["rejected"] == rejected, (stats["rejected"], rejected)
    row = dict(section="serve_shedding", backend="int8",
               max_queue=MAX_QUEUE, requests=requests,
               capacity_rps=float(cap), offered_rps=float(offered_rps),
               accepted=len(accepted), rejected=rejected, served=served,
               worker_crashes=stats["worker"]["crashes"])
    common.emit("resilience_serve_shedding", 1e6 / max(offered_rps, 1),
                f"{rejected}rejected_of_{requests}")
    print(f"  shedding: {cap:.0f} rps capacity, offered "
          f"{offered_rps:.0f} rps -> {served} served, "
          f"{rejected} shed (queue bound {MAX_QUEUE})")
    return row


def run(iterations: int = 8, guard_iters: int = GUARD_ITERS,
        requests: int = 1024) -> list:
    """All three sections; emit + save BENCH_resilience.json."""
    iterations = common.scaled(iterations, lo=6)
    guard_iters = common.scaled(guard_iters, lo=16)
    requests = common.scaled(requests, lo=256)
    rows = [guard_overhead(guard_iters)]
    rows.extend(recovery_matrix(iterations))
    rows.append(serve_shedding(requests))
    common.save_rows("BENCH_resilience", rows)
    return rows


if __name__ == "__main__":
    run()
