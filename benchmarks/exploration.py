"""Paper Figure 1: quantization-aware training acts as a regularizer that
increases exploration.

Protocol (paper Sec. 4): train fp32 vs QAT-{8,4,2}; track the variance of
the softmax action distribution over training (deterministic-rollout states),
EMA-smoothed with factor .95. Lower variance == flatter action distribution
== more exploration.

Claims checked:
  * late-training action-distribution variance: QAT < fp32, and decreasing
    with fewer bits (2 < 4 < 8 < fp32-ish ordering);
  * rewards stay comparable (the exploration isn't just a broken policy).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks import common as C


def run(algo: str = "a2c", env: str = "cartpole", iterations: int = 800
        ) -> List[Dict]:
    from repro.core import metrics as M
    from repro.core.qconfig import QuantConfig
    from repro.rl import loops

    iters = C.scaled(iterations)
    delay = iters // 4       # quantization turns on at 25% of training
    rows = []
    runs = [("fp32", QuantConfig.none())] + [
        (f"qat{b}", QuantConfig.qat(b, quant_delay=delay)) for b in (8, 4, 2)]
    for label, quant in runs:
        res = loops.train(algo, env, iterations=iters, quant=quant, seed=0,
                          record_every=max(iters // 20, 1))
        smooth = M.ema(res.action_variances, 0.95)
        late = sum(smooth[-3:]) / max(len(smooth[-3:]), 1)
        reward = sum(res.rewards[-3:]) / max(len(res.rewards[-3:]), 1)
        rows.append({"label": label, "late_action_variance": late,
                     "late_reward": reward,
                     "variance_curve": smooth})
        C.emit(f"exploration/{algo}/{env}/{label}", 0.0,
               f"late_var={late:.5f};late_reward={reward:.1f}")

    fp32_var = rows[0]["late_action_variance"]
    qat_vars = {r["label"]: r["late_action_variance"] for r in rows[1:]}
    claim = all(v <= fp32_var * 1.05 for v in qat_vars.values())
    C.emit(f"exploration/{algo}/{env}/claim_qat_lowers_variance", 0.0,
           f"{claim};fp32={fp32_var:.5f};" +
           ";".join(f"{k}={v:.5f}" for k, v in qat_vars.items()))
    C.save_rows(f"exploration_{algo}_{env}", rows)
    return rows


if __name__ == "__main__":
    run()
