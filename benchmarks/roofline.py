"""Roofline analysis per (arch × input shape × mesh) — deliverable (g).

Reads the dry-run artifacts (artifacts/dryrun/*.json) and derives the three
roofline terms per the spec (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

  compute term    = FLOPs / (chips x peak)        [FLOPs: analytic model —
                    XLA cost_analysis counts while bodies once; raw HLO
                    numbers are reported alongside for reference]
  memory term     = HBM bytes / (chips x HBM bw)  [analytic traffic model]
  collective term = collective bytes / link bw    [trip-count-weighted parse
                    of the post-SPMD HLO, per-device]

plus MODEL_FLOPS = 6·N(_active)·D, the useful-compute ratio, the dominant
term, and a one-line "what would move it" note. Emits the markdown table for
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.configs import base as cfgs                      # noqa: E402
from repro.launch import analytic, mesh as mesh_lib        # noqa: E402
from repro.launch.steps import resolve_arch_for_shape      # noqa: E402

ART = os.path.join(REPO, "artifacts", "dryrun")


def load_records(pattern: str = "*.json") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def derive_terms(rec: Dict) -> Dict:
    cfg = cfgs.get(rec["arch"])
    shape = cfgs.INPUT_SHAPES[rec["shape"]]
    cfg, _ = resolve_arch_for_shape(cfg, shape)
    chips = rec["devices"]

    flops = analytic.step_flops(cfg, shape)
    mflops = analytic.model_flops(cfg, shape)
    hbm = analytic.hbm_bytes_per_device(cfg, shape, chips,
                                        eightbit_opt=cfg.optimizer_8bit)
    coll = rec["collective_bytes"]

    compute_s = flops / (chips * mesh_lib.PEAK_FLOPS_BF16)
    memory_s = hbm / mesh_lib.HBM_BW
    collective_s = coll / mesh_lib.ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = dominant.replace("_s", "")
    total = max(terms.values())
    frac = {k: v / total for k, v in terms.items()}

    notes = {
        "compute": "raise arithmetic efficiency (larger microbatch, fused "
                   "kernels, int8 matmuls)",
        "memory": "cut resident/streamed bytes (int8 weights/cache, remat "
                  "policy, bigger per-step batch)",
        "collective": "reshard to cut all-gather/all-reduce volume (layer-"
                      "local TP, overlap collectives with compute)",
    }
    return {
        **rec,
        "analytic_flops": flops,
        "model_flops": mflops,
        "useful_ratio": mflops / flops if flops else 0.0,
        "analytic_hbm_bytes_dev": hbm,
        **terms,
        "dominant": bound,
        "note": notes[bound],
        "fractions": frac,
    }


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.0f}us"


def markdown_table(records: List[Dict], multi_pod: bool = False) -> str:
    rows = [r for r in records if r["multi_pod"] == multi_pod]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        "| arch | shape | kind | compute | memory | collective | bound | "
        "6ND/analytic | fits HBM? |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mem = r["memory"].get("total_nonalias_bytes", 0) / 1e9
        fits = "yes" if mem <= 16 else f"~{mem:.0f}GB (see notes)"
        lines.append(
            f"| {r['arch']} | {r['shape']}"
            f"{' (variant)' if r['variant'] != 'native' else ''} | "
            f"{r['kind']} | {fmt_seconds(r['compute_s'])} | "
            f"{fmt_seconds(r['memory_s'])} | "
            f"{fmt_seconds(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {fits} |")
    return "\n".join(lines)


def interesting_pairs(records: List[Dict]) -> Dict[str, Dict]:
    """The three hillclimb pairs per the assignment."""
    one_pod = [r for r in records if not r["multi_pod"]]
    worst_roofline = max(
        one_pod, key=lambda r: (1.0 / max(r["useful_ratio"], 1e-9))
        * (1 if r["kind"] == "train" else 0.5))
    most_collective = max(one_pod, key=lambda r: r["collective_s"]
                          / max(r["compute_s"] + r["memory_s"], 1e-12))
    # most representative of the paper: the quantization-relevant decode
    # (int8 KV-cache serving) on the biggest dense model
    rep = [r for r in one_pod
           if r["kind"] == "decode" and r["arch"] == "gemma2-9b"
           and r["shape"] == "decode_32k"]
    representative = rep[0] if rep else one_pod[0]
    return {"worst_useful_ratio": worst_roofline,
            "most_collective_bound": most_collective,
            "paper_representative": representative}


def baseline_comparison(records) -> str:
    """Optimized vs pre-§Perf baseline (artifacts/dryrun_baseline)."""
    base_dir = os.path.join(REPO, "artifacts", "dryrun_baseline")
    if not os.path.isdir(base_dir):
        return ""
    base = {}
    for path in glob.glob(os.path.join(base_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        base[(r["arch"], r["shape"], r["multi_pod"])] = r
    lines = ["\n## optimized vs baseline (per-device collective bytes / "
             "temp bytes)\n",
             "| arch x shape | baseline coll | optimized coll | baseline "
             "temp | optimized temp |", "|---|---|---|---|---|"]
    for r in records:
        if r["multi_pod"]:
            continue
        b = base.get((r["arch"], r["shape"], False))
        if not b:
            continue
        bt = b["memory"].get("temp_size_in_bytes", 0) / 1e9
        ot = r["memory"].get("temp_size_in_bytes", 0) / 1e9
        bc = b["collective_bytes"] / 1e9
        oc = r["collective_bytes"] / 1e9
        if bc < 0.5 and abs(bt - ot) < 1:
            continue  # only rows that moved
        lines.append(f"| {r['arch']} x {r['shape']} | {bc:.1f} GB | "
                     f"{oc:.1f} GB | {bt:.1f} GB | {ot:.1f} GB |")
    return "\n".join(lines)


def main() -> None:
    records = [derive_terms(r) for r in load_records()
               if not os.path.basename(r.get("arch", "")).startswith("_")]
    if not records:
        print("no dryrun artifacts found — run repro.launch.dryrun first")
        return
    print(f"# Roofline ({len(records)} records)\n")
    print("## single-pod (16x16)\n")
    print(markdown_table(records, multi_pod=False))
    print("\n## multi-pod (2x16x16)\n")
    print(markdown_table(records, multi_pod=True))
    picks = interesting_pairs(records)
    print("\n## hillclimb picks\n")
    for why, r in picks.items():
        print(f"- **{why}**: {r['arch']} x {r['shape']} "
              f"(dominant: {r['dominant']}, useful ratio "
              f"{r['useful_ratio']:.2f})")
    cmp_table = baseline_comparison(records)
    if cmp_table:
        print(cmp_table)
    out = os.path.join(REPO, "artifacts", "roofline.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
