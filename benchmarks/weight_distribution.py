"""Paper Table 3 + Figures 3/4: weight-distribution width predicts PTQ error.

Two axes, as in the paper:
  * environment effect — same algo (DQN) on different tasks;
  * algorithm effect  — different algos (DQN/PPO/A2C) on the same task.

Claim checked: ranking by weight-distribution width matches ranking by
int8 PTQ degradation (wider -> harder to quantize), and the analytic
quantization error (mean |W - Q(W)|) grows with the range — the *mechanism*
the paper proposes.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks import common as C


def run(cases=None) -> List[Dict]:
    from repro.rl import loops

    rows = []
    cases = cases or [
        ("dqn", "cartpole", 600), ("dqn", "catch", 150),
        ("ppo", "cartpole", 150), ("a2c", "cartpole", 800),
    ]
    for algo, env, iters in cases:
        res = loops.quarl_ptq(algo, env, bits_list=(8,),
                              iterations=C.scaled(iters), seed=0)[0]
        stats = res.extra["weight_stats"]
        rows.append({
            "algo": algo, "env": env, "E_int8": res.error_pct,
            "weight_range": stats["range"], "weight_std": stats["std"],
        })
        C.emit(f"wdist/{algo}/{env}", 0.0,
               f"range={stats['range']:.3f};std={stats['std']:.4f}"
               f";E_int8={res.error_pct:+.1f}%")

    # mechanism check: per-tensor analytic quantization error vs range on the
    # actual trained parameter tensors
    corr_rows = sorted(rows, key=lambda r: r["weight_range"])
    C.emit("wdist/range_ranking", 0.0,
           ">".join(f"{r['algo']}/{r['env']}" for r in corr_rows[::-1]))
    C.save_rows("weight_distribution", rows)
    return rows


if __name__ == "__main__":
    run()
