"""Docs link checker: every relative link in docs/*.md and README resolves.

Scans markdown files for inline links/images, resolves relative targets
against the linking file, and fails if a target file (or, for ``.md``
targets, a ``#fragment`` heading anchor) does not exist.  Skips external
schemes (http/https/mailto) and GitHub "virtual" paths that escape the
repository root (e.g. the ``../../actions/...`` CI badge idiom).

Run directly (CI lint job) or via ``tests/test_docs.py``:

    python tools/check_doc_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

ROOT = Path(__file__).resolve().parent.parent

# inline links and images: [text](target) / ![alt](target)
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^(```|~~~).*?^\1\s*$", re.MULTILINE | re.DOTALL)
_CODE_RE = re.compile(r"`[^`]*`")


def doc_files(root: Path = ROOT) -> List[Path]:
    """The markdown set under the link gate: ``docs/*.md`` + README."""
    files = sorted((root / "docs").glob("*.md"))
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def heading_slugs(md_path: Path) -> set:
    """GitHub-style anchor slugs for every heading in ``md_path``."""
    slugs = set()
    text = _FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for line in text.splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if not m:
            continue
        h = m.group(1).strip().lower()
        h = re.sub(r"[^\w\- ]", "", h)   # drop punctuation, keep -/_/space
        slugs.add(h.replace(" ", "-"))
    return slugs


def check_file(md_path: Path, root: Path = ROOT) -> List[str]:
    """Return human-readable errors for broken links in one file."""
    errors = []
    text = md_path.read_text(encoding="utf-8")
    text = _CODE_RE.sub("", _FENCE_RE.sub("", text))
    rel = md_path.relative_to(root)
    for target in _LINK_RE.findall(text):
        if re.match(r"[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        path_part, _, fragment = target.partition("#")
        dest = md_path if not path_part else (
            md_path.parent / path_part).resolve()
        if not str(dest).startswith(str(root)):
            continue                                   # GitHub virtual path
        if not dest.exists():
            errors.append(f"{rel}: broken link -> {target}")
            continue
        if fragment and dest.suffix == ".md":
            if fragment not in heading_slugs(dest):
                errors.append(f"{rel}: missing anchor -> {target}")
    return errors


def check(root: Path = ROOT) -> List[str]:
    """Check every gated file; return the combined error list."""
    errors = []
    for f in doc_files(root):
        errors.extend(check_file(f, root))
    return errors


def main() -> int:
    """CLI entry: print errors, exit 1 if any link is broken."""
    files = doc_files()
    errors = check()
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_doc_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
