"""Policy server: packed-actor cache registry, hot-swap, dispatch loop.

``PolicyServer`` multiplexes any number of open sessions onto shape-
bucketed padded batches answered by ONE immutable actor-cache snapshot per
dispatch:

* **Cache registry / hot-swap.**  ``push_params`` packs the learner's fp32
  params into the backend's serving form (``rl.actorq`` int8/int4 packing,
  optionally calibrated so MLP actors run the single-pass fused kernel;
  fp32 stores the pytree as-is) and publishes it as a frozen ``CacheEntry``
  under a single reference assignment.  Dispatches read that reference
  exactly once, so an in-flight batch keeps computing against the cache it
  started with — a swap can never tear a batch across two versions (the
  ``test_hot_swap_*`` suite).  Zero-copy: no tree copy on either side of
  the swap; old caches are garbage once the last in-flight batch drops
  them.
* **Dispatch loop.**  A single worker thread drains the ``Batcher``
  admission queue and calls ``serve_batch``; per-step compute is the same
  jitted act function for every bucket (jax retraces per bucket shape,
  ``warmup()`` pre-compiles them all).
* **Backends.**  ``actor_backend`` fp32 | int8 | int4 exactly as in
  training (``rl.actorq``); ``kernel_backend`` selects the quantized GEMM
  path (pallas/interpret/ref/xla/auto) as everywhere else.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptq
from repro.rl import actorq
from repro.serving.batcher import (Batcher, Request, pad_rows,
                                   remove_padding, select_bucket)
from repro.serving.session import SessionTable, StepCounter

DEFAULT_BUCKETS = (8, 32, 128, 512)


def make_fp32_act_fn(env_spec) -> Callable:
    """Deterministic fp32 policy ``act(params, obs)`` mirroring the
    quantized ``actorq.make_act_fn`` head contract.

    ``params`` is the plain fp32 pytree (``rl.networks`` naming: ``fc*``/
    ``out`` MLP or ``conv*``/``fc``/``out`` CNN); ``obs`` is f32 with any
    leading batch dims.  Discrete specs argmax the first ``n_actions``
    head outputs (int32 actions); continuous specs apply the DDPG
    ``tanh * action_scale`` head (f32 actions).
    """
    from repro.core.fake_quant import NullQATContext
    from repro.rl import networks

    ctx = NullQATContext()

    def apply(params, obs):
        """Head outputs, dispatching MLP vs CNN on the param naming."""
        names = set(params)
        n_convs = sum(1 for n in names if n.startswith("conv"))
        if n_convs:
            return networks.cnn_apply(ctx, params, obs, n_convs)
        n_hidden = sum(1 for n in names if n.startswith("fc"))
        return networks.mlp_apply(ctx, params, obs, n_hidden)

    if env_spec.continuous:
        def act(params, obs):
            """Continuous head: tanh * action_scale, f32 actions."""
            return jnp.tanh(apply(params, obs)) * env_spec.action_scale
    else:
        n_act = env_spec.n_actions

        def act(params, obs):
            """Discrete head: argmax over n_actions logits, int32."""
            out = apply(params, obs)
            return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)
    return act


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One immutable published actor cache.

    ``cache`` is the serving pytree (packed ``QuantizedParams`` for int8/
    int4 — calibrated when the server has ``calib_batch > 0`` and the
    policy is an MLP — or the fp32 params), ``version`` the monotone push
    counter, ``nbytes`` its parameter-memory footprint, ``pushed_at`` a
    ``perf_counter`` stamp.  Frozen: hot-swap publishes a new entry rather
    than mutating, so concurrent dispatches can never observe a
    half-updated cache.
    """

    cache: Any
    version: int
    actor_backend: str
    nbytes: int
    pushed_at: float


def greedy_calib_obs(env, qparams, calib_batch: int, seed: int = 0, *,
                     kernel_backend: str = "auto") -> jnp.ndarray:
    """Collect ``calib_batch`` observations for deploy-time calibration.

    Rolls the *served* greedy policy (over the freshly packed ``qparams``)
    a few auto-reset steps from reset — reset draws alone sit near the
    origin for the classic-control envs and would saturate the static
    scales once the policy drifts.  Returns (calib_batch, \\*obs_shape) f32.
    """
    from repro.rl.env import batched_env

    roll_steps = 8
    benv = batched_env(env, max(-(-calib_batch // roll_steps), 1))
    key = jax.random.PRNGKey(seed)
    act = actorq.make_act_fn(env.spec, backend=kernel_backend)
    e_state, obs = benv.reset(key)
    seen = [obs]
    for t in range(roll_steps - 1):
        a = act(qparams, obs)
        e_state, obs, _, _ = benv.step(e_state, a, jax.random.fold_in(key, t))
        seen.append(obs)
    return jnp.concatenate(seen)[:calib_batch]


class PolicyServer:
    """Continuous-batching policy server over one actor cache.

    Construction wires the policy (from ``env_spec``), the cache backend,
    and the batching policy; ``push_params`` publishes the first cache;
    ``start``/``stop`` run the background dispatch loop (or call
    ``serve_batch``/``serve`` directly for synchronous use — the tests and
    the bitwise-parity contract run that way).

    Args:
        env_spec: frozen ``rl.env.EnvSpec`` — defines obs shape and the
            deterministic action head.
        actor_backend: ``"fp32" | "int8" | "int4"`` serving cache format.
        kernel_backend: quantized-GEMM backend knob
            (``pallas/interpret/ref/xla/auto``), ignored for fp32.
        buckets: ascending padded batch shapes; the largest is the
            admission ``max_batch``.
        max_wait_us: admission straggler wait (see ``batcher.Batcher``).
        calib_batch: > 0 calibrates static activation scales at every
            push from the observations handed to ``push_params`` (MLP
            caches then serve through the single-pass fused kernel);
            0 keeps the dynamic per-layer path.
    """

    def __init__(self, env_spec, *, actor_backend: str = "int8",
                 kernel_backend: str = "auto",
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_us: int = 2000, calib_batch: int = 0):
        """See class docstring."""
        actorq.validate_actor_backend(actor_backend)
        if not buckets or list(buckets) != sorted(set(int(b) for b in
                                                      buckets)):
            raise ValueError(f"buckets must be ascending and unique, "
                             f"got {buckets!r}")
        self.env_spec = env_spec
        self.actor_backend = actor_backend
        self.kernel_backend = kernel_backend
        self.buckets = tuple(int(b) for b in buckets)
        self.max_wait_us = int(max_wait_us)
        self.calib_batch = int(calib_batch)
        if actorq.is_quantized(actor_backend):
            act = actorq.make_act_fn(env_spec, backend=kernel_backend)
        else:
            act = make_fp32_act_fn(env_spec)
        self._step_fn = jax.jit(act)
        self._entry: Optional[CacheEntry] = None
        self._calib_obs = None              # last calibration batch seen
        self._push_mu = threading.Lock()
        self._versions = StepCounter()
        self.batcher = Batcher(max_batch=self.buckets[-1],
                               max_wait_us=max_wait_us)
        self.sessions = SessionTable()
        self.steps = StepCounter()          # dispatch (batch) tickets
        self._served = 0                    # requests answered
        self._padded = 0                    # padding rows dispatched
        self._bucket_counts: Dict[int, int] = {b: 0 for b in self.buckets}
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- cache registry / hot-swap -----------------------------------------

    def push_params(self, params, calib_obs=None) -> CacheEntry:
        """Pack + publish a new actor cache; returns the new entry.

        ``params`` is the learner's fp32 pytree.  Quantized backends pack
        it via ``actorq.make_actor_cache``; with ``calib_batch > 0`` the
        pushed cache is calibrated on ``calib_obs``, falling back to the
        most recent calibration batch if omitted (dynamic per-layer path
        until the first one arrives).  The swap is
        one reference assignment: in-flight batches finish on the cache
        they snapshotted, new dispatches see the new version immediately.
        """
        if actorq.is_quantized(self.actor_backend):
            if self.calib_batch > 0:
                if calib_obs is not None:
                    calib_obs = actorq.calib_slice(jnp.asarray(calib_obs),
                                                   self.calib_batch)
                    self._calib_obs = calib_obs
                else:
                    calib_obs = self._calib_obs
            else:
                calib_obs = None
            cache = actorq.make_actor_cache(
                params, self.actor_backend, calib_obs=calib_obs,
                backend=self.kernel_backend)
        else:
            cache = params
        with self._push_mu:
            entry = CacheEntry(cache=cache, version=self._versions.next(),
                               actor_backend=self.actor_backend,
                               nbytes=ptq.tree_nbytes(cache),
                               pushed_at=time.perf_counter())
            self._entry = entry              # the atomic hot-swap
        return entry

    @property
    def current(self) -> Optional[CacheEntry]:
        """The live cache entry (``None`` before the first push)."""
        return self._entry

    # -- session lifecycle -------------------------------------------------

    def open_session(self) -> int:
        """Open a serving session; returns its id."""
        return self.sessions.open(at_step=self.steps.value)

    def close_session(self, sid: int) -> None:
        """Close session ``sid`` (its queued requests still complete)."""
        self.sessions.close(sid)

    # -- request path ------------------------------------------------------

    def submit(self, sid: int, obs) -> Request:
        """Enqueue one observation for session ``sid``; returns the
        ``Request`` whose ``result()`` blocks for the action.

        ``obs`` is a single observation (no batch axis) of
        ``env_spec.obs_shape``; raises ``KeyError`` for unknown/closed
        sessions and ``ValueError`` on a shape mismatch.
        """
        self.sessions.checkout(sid)
        obs = np.asarray(obs, dtype=np.float32)
        if obs.shape != tuple(self.env_spec.obs_shape):
            raise ValueError(f"obs shape {obs.shape} != spec "
                             f"{tuple(self.env_spec.obs_shape)}")
        req = Request(sid, obs)
        self.batcher.put(req)
        return req

    def serve_batch(self, requests: List[Request]) -> None:
        """Answer one admitted batch against a single cache snapshot.

        Stacks the requests' observations, pads to the selected bucket
        (repeat-last-row), runs the jitted act function once, unpads, and
        completes every request with its action + the snapshot's version.
        The cache reference is read exactly once, so a concurrent
        ``push_params`` never tears the batch.
        """
        entry = self._entry   # single snapshot read — hot-swap safety
        if entry is None:
            raise RuntimeError("no actor cache: call push_params first")
        try:
            n = len(requests)
            bucket = select_bucket(n, self.buckets)
            obs = pad_rows(np.stack([r.obs for r in requests]), bucket)
            out = self._step_fn(entry.cache, jnp.asarray(obs))
            # unpad on the HOST: slicing the jax array would compile one
            # slice program per distinct live batch size (a fresh ~50ms
            # retrace in the dispatch path every time a new n shows up)
            actions = remove_padding(np.asarray(out), n)
            step = self.steps.next()
            t_done = time.perf_counter()
            self._served += n
            self._padded += bucket - n
            self._bucket_counts[bucket] += 1
            for r, a in zip(requests, actions):
                self.sessions.on_step(r.sid, entry.version)
                r.complete(a, entry.version, step, t_done)
        except Exception as e:              # fail waiters, don't hang them
            for r in requests:
                r.fail(e)
            raise

    def serve(self, sid_obs: Sequence) -> List[np.ndarray]:
        """Synchronous convenience: serve ``[(sid, obs), ...]`` as one
        admitted batch and return the actions in order (no worker thread
        involved — the deterministic path the parity tests pin down)."""
        reqs = [self.submit(sid, obs) for sid, obs in sid_obs]
        batch = self.batcher.get_batch(timeout=0)
        served: List[Request] = []
        while batch:
            self.serve_batch(batch)
            served.extend(batch)
            batch = self.batcher.get_batch(timeout=0)
        if len(served) != len(reqs):
            # a real error, not an assert: the dispatch path must survive
            # ``python -O``, and the unserved waiters must be failed —
            # not left hanging on ``result()`` forever
            err = RuntimeError(
                f"dispatch drained {len(served)} of {len(reqs)} admitted "
                f"requests — batcher admission invariant violated")
            drained = {id(r) for r in served}
            for r in reqs:
                if id(r) not in drained:
                    r.fail(err)
            raise err
        return [r.result(timeout=0).action for r in reqs]

    # -- dispatch loop -----------------------------------------------------

    def _run(self) -> None:
        """Worker body: drain the admission queue until stopped."""
        while not self._stop.is_set():
            batch = self.batcher.get_batch(timeout=0.05)
            if batch:
                try:
                    self.serve_batch(batch)
                except Exception:
                    # requests already failed individually; keep serving
                    continue

    def start(self) -> "PolicyServer":
        """Start the background dispatch thread (idempotent).

        A server stopped earlier restarts cleanly: ``stop`` closes the
        admission queue terminally, so restart swaps in a fresh one
        (sessions, caches and counters all survive the cycle).
        """
        if self._worker is None or not self._worker.is_alive():
            if self.batcher.closed:
                self.batcher = Batcher(max_batch=self.buckets[-1],
                                       max_wait_us=self.max_wait_us)
            self._stop.clear()
            self._worker = threading.Thread(target=self._run,
                                            name="policy-server",
                                            daemon=True)
            self._worker.start()
        return self

    def stop(self) -> None:
        """Stop dispatching; queued-but-unserved requests fail fast.
        ``start`` brings the server back up afterwards."""
        self._stop.set()
        drained = self.batcher.close()
        err = RuntimeError("server stopped")
        for r in drained:
            r.fail(err)
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None

    def __enter__(self) -> "PolicyServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- ops ---------------------------------------------------------------

    def warmup(self) -> None:
        """Compile the act program for every bucket shape up front (one
        retrace per bucket) so first requests don't pay compile latency."""
        entry = self._entry
        if entry is None:
            raise RuntimeError("no actor cache: call push_params first")
        for b in self.buckets:
            obs = jnp.zeros((b,) + tuple(self.env_spec.obs_shape),
                            jnp.float32)
            jax.block_until_ready(self._step_fn(entry.cache, obs))

    def stats(self) -> Dict[str, Any]:
        """Serving counters snapshot.

        Keys: ``served`` (requests answered), ``dispatches`` (batches),
        ``padding_rows`` (total padded rows — the bucketing overhead),
        ``bucket_counts`` (dispatches per bucket), ``version`` (live cache
        version or -1), ``cache_nbytes``, plus the ``sessions`` table
        counters.
        """
        entry = self._entry
        return {
            "served": self._served,
            "dispatches": self.steps.value,
            "padding_rows": self._padded,
            "bucket_counts": dict(self._bucket_counts),
            "version": -1 if entry is None else entry.version,
            "cache_nbytes": 0 if entry is None else entry.nbytes,
            "sessions": self.sessions.stats(),
        }
