"""Policy server: packed-actor cache registry, hot-swap, dispatch loop.

``PolicyServer`` multiplexes any number of open sessions onto shape-
bucketed padded batches answered by ONE immutable actor-cache snapshot per
dispatch:

* **Cache registry / hot-swap.**  ``push_params`` packs the learner's fp32
  params into the backend's serving form (``rl.actorq`` int8/int4 packing,
  optionally calibrated so MLP actors run the single-pass fused kernel;
  fp32 stores the pytree as-is) and publishes it as a frozen ``CacheEntry``
  under a single reference assignment.  Dispatches read that reference
  exactly once, so an in-flight batch keeps computing against the cache it
  started with — a swap can never tear a batch across two versions (the
  ``test_hot_swap_*`` suite).  Zero-copy: no tree copy on either side of
  the swap; old caches are garbage once the last in-flight batch drops
  them.
* **Dispatch loop.**  A single worker thread drains the ``Batcher``
  admission queue and calls ``serve_batch``; per-step compute is the same
  jitted act function for every bucket (jax retraces per bucket shape,
  ``warmup()`` pre-compiles them all).
* **Backends.**  ``actor_backend`` fp32 | int8 | int4 exactly as in
  training (``rl.actorq``); ``kernel_backend`` selects the quantized GEMM
  path (pallas/interpret/ref/xla/auto) as everywhere else.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ptq
from repro.resilience import guards as _guards
from repro.rl import actorq
from repro.serving.batcher import (Batcher, DeadlineExceededError,
                                   Request, pad_rows, remove_padding,
                                   select_bucket)
from repro.serving.session import SessionTable, StepCounter

DEFAULT_BUCKETS = (8, 32, 128, 512)


class WorkerCrashError(RuntimeError):
    """Raised (by a fault hook or dispatch internals) to crash the
    worker thread deliberately; the outer worker loop counts the crash
    and auto-restarts the dispatch body (``stats()['worker']``)."""


def make_fp32_act_fn(env_spec) -> Callable:
    """Deterministic fp32 policy ``act(params, obs)`` mirroring the
    quantized ``actorq.make_act_fn`` head contract.

    ``params`` is the plain fp32 pytree (``rl.networks`` naming: ``fc*``/
    ``out`` MLP or ``conv*``/``fc``/``out`` CNN); ``obs`` is f32 with any
    leading batch dims.  Discrete specs argmax the first ``n_actions``
    head outputs (int32 actions); continuous specs apply the DDPG
    ``tanh * action_scale`` head (f32 actions).
    """
    from repro.core.fake_quant import NullQATContext
    from repro.rl import networks

    ctx = NullQATContext()

    def apply(params, obs):
        """Head outputs, dispatching MLP vs CNN on the param naming."""
        names = set(params)
        n_convs = sum(1 for n in names if n.startswith("conv"))
        if n_convs:
            return networks.cnn_apply(ctx, params, obs, n_convs)
        n_hidden = sum(1 for n in names if n.startswith("fc"))
        return networks.mlp_apply(ctx, params, obs, n_hidden)

    if env_spec.continuous:
        def act(params, obs):
            """Continuous head: tanh * action_scale, f32 actions."""
            return jnp.tanh(apply(params, obs)) * env_spec.action_scale
    else:
        n_act = env_spec.n_actions

        def act(params, obs):
            """Discrete head: argmax over n_actions logits, int32."""
            out = apply(params, obs)
            return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)
    return act


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One immutable published actor cache.

    ``cache`` is the serving pytree (packed ``QuantizedParams`` for int8/
    int4 — calibrated when the server has ``calib_batch > 0`` and the
    policy is an MLP — or the fp32 params), ``version`` the monotone push
    counter, ``nbytes`` its parameter-memory footprint, ``pushed_at`` a
    ``perf_counter`` stamp, ``crc32`` the push-time payload checksum
    (``resilience.guards.tree_crc32`` over every leaf; ``verify_current``
    re-checks the live cache against it).  Frozen: hot-swap publishes a
    new entry rather than mutating, so concurrent dispatches can never
    observe a half-updated cache.
    """

    cache: Any
    version: int
    actor_backend: str
    nbytes: int
    pushed_at: float
    crc32: int = 0


def greedy_calib_obs(env, qparams, calib_batch: int, seed: int = 0, *,
                     kernel_backend: str = "auto") -> jnp.ndarray:
    """Collect ``calib_batch`` observations for deploy-time calibration.

    Rolls the *served* greedy policy (over the freshly packed ``qparams``)
    a few auto-reset steps from reset — reset draws alone sit near the
    origin for the classic-control envs and would saturate the static
    scales once the policy drifts.  Returns (calib_batch, \\*obs_shape) f32.
    """
    from repro.rl.env import batched_env

    roll_steps = 8
    benv = batched_env(env, max(-(-calib_batch // roll_steps), 1))
    key = jax.random.PRNGKey(seed)
    act = actorq.make_act_fn(env.spec, backend=kernel_backend)
    e_state, obs = benv.reset(key)
    seen = [obs]
    for t in range(roll_steps - 1):
        a = act(qparams, obs)
        e_state, obs, _, _ = benv.step(e_state, a, jax.random.fold_in(key, t))
        seen.append(obs)
    return jnp.concatenate(seen)[:calib_batch]


class PolicyServer:
    """Continuous-batching policy server over one actor cache.

    Construction wires the policy (from ``env_spec``), the cache backend,
    and the batching policy; ``push_params`` publishes the first cache;
    ``start``/``stop`` run the background dispatch loop (or call
    ``serve_batch``/``serve`` directly for synchronous use — the tests and
    the bitwise-parity contract run that way).

    Args:
        env_spec: frozen ``rl.env.EnvSpec`` — defines obs shape and the
            deterministic action head.
        actor_backend: ``"fp32" | "int8" | "int4"`` serving cache format.
        kernel_backend: quantized-GEMM backend knob
            (``pallas/interpret/ref/xla/auto``), ignored for fp32.
        buckets: ascending padded batch shapes; the largest is the
            admission ``max_batch``.
        max_wait_us: admission straggler wait (see ``batcher.Batcher``).
        calib_batch: > 0 calibrates static activation scales at every
            push from the observations handed to ``push_params`` (MLP
            caches then serve through the single-pass fused kernel);
            0 keeps the dynamic per-layer path.
        max_queue: admission-queue bound; a ``submit`` against a full
            queue raises the typed ``batcher.QueueFullError`` (load
            shedding) instead of growing the queue without bound.
            0 (default) = unbounded.
        request_deadline_s: per-request deadline; a request still
            undispatched past it fails with ``DeadlineExceededError``
            at dispatch time instead of being served stale.  0 = none.
        verify_pushes: validate every pushed quantized cache's
            structural invariants (``resilience.guards.validate_cache``)
            before publishing; the push-time CRC is always recorded in
            the entry (``verify_current`` re-checks it on demand).
        fault_hook: optional callable ``hook(batch)`` run before each
            worker dispatch — the fault-injection seam
            (``resilience.ResilienceContext.serving_fault_hook``).  An
            exception from it crashes the worker, which the outer loop
            auto-restarts (counted in ``stats()['worker']``).
    """

    def __init__(self, env_spec, *, actor_backend: str = "int8",
                 kernel_backend: str = "auto",
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_us: int = 2000, calib_batch: int = 0,
                 max_queue: int = 0, request_deadline_s: float = 0.0,
                 verify_pushes: bool = True,
                 fault_hook: Optional[Callable] = None):
        """See class docstring."""
        actorq.validate_actor_backend(actor_backend)
        if not buckets or list(buckets) != sorted(set(int(b) for b in
                                                      buckets)):
            raise ValueError(f"buckets must be ascending and unique, "
                             f"got {buckets!r}")
        self.env_spec = env_spec
        self.actor_backend = actor_backend
        self.kernel_backend = kernel_backend
        self.buckets = tuple(int(b) for b in buckets)
        self.max_wait_us = int(max_wait_us)
        self.calib_batch = int(calib_batch)
        self.max_queue = int(max_queue)
        self.request_deadline_s = float(request_deadline_s)
        self.verify_pushes = bool(verify_pushes)
        self._fault_hook = fault_hook
        if actorq.is_quantized(actor_backend):
            act = actorq.make_act_fn(env_spec, backend=kernel_backend)
        else:
            act = make_fp32_act_fn(env_spec)
        self._step_fn = jax.jit(act)
        self._entry: Optional[CacheEntry] = None
        self._calib_obs = None              # last calibration batch seen
        self._push_mu = threading.Lock()
        self._versions = StepCounter()
        self.batcher = self._make_batcher()
        self.sessions = SessionTable()
        self.steps = StepCounter()          # dispatch (batch) tickets
        self._served = 0                    # requests answered
        self._padded = 0                    # padding rows dispatched
        self._bucket_counts: Dict[int, int] = {b: 0 for b in self.buckets}
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # failure observability (satellite: no silent continue/leak)
        self._deadline_expired = 0
        self._dispatch_failures = 0
        self._consecutive_failures = 0
        self._last_error: Optional[str] = None
        self._worker_crashes = 0
        self._worker_restarts = 0
        self._wedged = 0

    def _make_batcher(self) -> Batcher:
        return Batcher(max_batch=self.buckets[-1],
                       max_wait_us=self.max_wait_us,
                       max_queue=self.max_queue)

    # -- cache registry / hot-swap -----------------------------------------

    def push_params(self, params, calib_obs=None) -> CacheEntry:
        """Pack + publish a new actor cache; returns the new entry.

        ``params`` is the learner's fp32 pytree.  Quantized backends pack
        it via ``actorq.make_actor_cache``; with ``calib_batch > 0`` the
        pushed cache is calibrated on ``calib_obs``, falling back to the
        most recent calibration batch if omitted (dynamic per-layer path
        until the first one arrives).  The swap is
        one reference assignment: in-flight batches finish on the cache
        they snapshotted, new dispatches see the new version immediately.
        """
        if actorq.is_quantized(self.actor_backend):
            if self.calib_batch > 0:
                if calib_obs is not None:
                    calib_obs = actorq.calib_slice(jnp.asarray(calib_obs),
                                                   self.calib_batch)
                    self._calib_obs = calib_obs
                else:
                    calib_obs = self._calib_obs
            else:
                calib_obs = None
            cache = actorq.make_actor_cache(
                params, self.actor_backend, calib_obs=calib_obs,
                backend=self.kernel_backend)
        else:
            cache = params
        if self.verify_pushes and actorq.is_quantized(self.actor_backend):
            # integrity gate at the swap boundary: a structurally
            # corrupt pack (NaN scales, bad code widths) raises its
            # typed error HERE and the live entry keeps serving
            _guards.validate_cache(cache, what="pushed serving cache")
        crc = _guards.tree_crc32(cache)
        with self._push_mu:
            entry = CacheEntry(cache=cache, version=self._versions.next(),
                               actor_backend=self.actor_backend,
                               nbytes=ptq.tree_nbytes(cache),
                               pushed_at=time.perf_counter(), crc32=crc)
            self._entry = entry              # the atomic hot-swap
        return entry

    def verify_current(self) -> CacheEntry:
        """Re-checksum the live cache against its push-time CRC.

        Raises ``resilience.guards.IntegrityError`` on any bit
        difference (in-memory corruption of a published payload);
        returns the verified entry otherwise.
        """
        entry = self._entry
        if entry is None:
            raise RuntimeError("no actor cache: call push_params first")
        _guards.verify_crc(entry.cache, entry.crc32,
                           what=f"serving cache v{entry.version}")
        return entry

    @property
    def current(self) -> Optional[CacheEntry]:
        """The live cache entry (``None`` before the first push)."""
        return self._entry

    # -- session lifecycle -------------------------------------------------

    def open_session(self) -> int:
        """Open a serving session; returns its id."""
        return self.sessions.open(at_step=self.steps.value)

    def close_session(self, sid: int) -> None:
        """Close session ``sid`` (its queued requests still complete)."""
        self.sessions.close(sid)

    # -- request path ------------------------------------------------------

    def submit(self, sid: int, obs) -> Request:
        """Enqueue one observation for session ``sid``; returns the
        ``Request`` whose ``result()`` blocks for the action.

        ``obs`` is a single observation (no batch axis) of
        ``env_spec.obs_shape``; raises ``KeyError`` for unknown/closed
        sessions, ``ValueError`` on a shape mismatch, and
        ``batcher.QueueFullError`` when ``max_queue`` is set and the
        admission queue is at capacity (typed load shedding — the
        caller's backpressure signal).
        """
        self.sessions.checkout(sid)
        obs = np.asarray(obs, dtype=np.float32)
        if obs.shape != tuple(self.env_spec.obs_shape):
            raise ValueError(f"obs shape {obs.shape} != spec "
                             f"{tuple(self.env_spec.obs_shape)}")
        deadline = (time.perf_counter() + self.request_deadline_s
                    if self.request_deadline_s > 0 else None)
        req = Request(sid, obs, deadline=deadline)
        self.batcher.put(req)
        return req

    def serve_batch(self, requests: List[Request]) -> None:
        """Answer one admitted batch against a single cache snapshot.

        Stacks the requests' observations, pads to the selected bucket
        (repeat-last-row), runs the jitted act function once, unpads, and
        completes every request with its action + the snapshot's version.
        The cache reference is read exactly once, so a concurrent
        ``push_params`` never tears the batch.
        """
        entry = self._entry   # single snapshot read — hot-swap safety
        if entry is None:
            raise RuntimeError("no actor cache: call push_params first")
        # expire dead requests before paying for their compute: a waiter
        # past its deadline gets the typed error now instead of a stale
        # action later
        live = requests
        if any(r.deadline is not None for r in requests):
            now = time.perf_counter()
            live = []
            for r in requests:
                if r.expired(now):
                    self._deadline_expired += 1
                    r.fail(DeadlineExceededError(
                        f"request for session {r.sid} expired "
                        f"{now - r.deadline:.4f}s before dispatch"))
                else:
                    live.append(r)
            if not live:
                return
        try:
            n = len(live)
            bucket = select_bucket(n, self.buckets)
            obs = pad_rows(np.stack([r.obs for r in live]), bucket)
            out = self._step_fn(entry.cache, jnp.asarray(obs))
            # unpad on the HOST: slicing the jax array would compile one
            # slice program per distinct live batch size (a fresh ~50ms
            # retrace in the dispatch path every time a new n shows up)
            actions = remove_padding(np.asarray(out), n)
            step = self.steps.next()
            t_done = time.perf_counter()
            self._served += n
            self._padded += bucket - n
            self._bucket_counts[bucket] += 1
            for r, a in zip(live, actions):
                self.sessions.on_step(r.sid, entry.version)
                r.complete(a, entry.version, step, t_done)
        except Exception as e:              # fail waiters, don't hang them
            for r in live:
                r.fail(e)
            raise

    def serve(self, sid_obs: Sequence) -> List[np.ndarray]:
        """Synchronous convenience: serve ``[(sid, obs), ...]`` as one
        admitted batch and return the actions in order (no worker thread
        involved — the deterministic path the parity tests pin down)."""
        reqs = [self.submit(sid, obs) for sid, obs in sid_obs]
        batch = self.batcher.get_batch(timeout=0)
        served: List[Request] = []
        while batch:
            self.serve_batch(batch)
            served.extend(batch)
            batch = self.batcher.get_batch(timeout=0)
        if len(served) != len(reqs):
            # a real error, not an assert: the dispatch path must survive
            # ``python -O``, and the unserved waiters must be failed —
            # not left hanging on ``result()`` forever
            err = RuntimeError(
                f"dispatch drained {len(served)} of {len(reqs)} admitted "
                f"requests — batcher admission invariant violated")
            drained = {id(r) for r in served}
            for r in reqs:
                if id(r) not in drained:
                    r.fail(err)
            raise err
        return [r.result(timeout=0).action for r in reqs]

    # -- dispatch loop -----------------------------------------------------

    def _run(self) -> None:
        """Worker body: drain the admission queue until stopped.

        A failed dispatch has already failed its own requests
        individually, so the loop keeps serving — but never silently:
        every failure increments ``dispatch_failures``, stamps
        ``last_error``, and consecutive failures back off exponentially
        (capped at 100ms) so a persistently broken dispatch path cannot
        spin the CPU at full speed failing the whole queue.  An
        exception from the fault hook crashes the worker deliberately;
        the outer ``_worker_main`` loop counts it and restarts.
        """
        consecutive = 0
        while not self._stop.is_set():
            batch = self.batcher.get_batch(timeout=0.05)
            if not batch:
                continue
            if self._fault_hook is not None:
                try:
                    self._fault_hook(batch)
                except BaseException as e:
                    for r in batch:     # never leave waiters hanging
                        r.fail(e)
                    raise
            try:
                self.serve_batch(batch)
                consecutive = 0
                self._consecutive_failures = 0
            except Exception as e:
                self._dispatch_failures += 1
                consecutive += 1
                self._consecutive_failures = consecutive
                self._last_error = f"{type(e).__name__}: {e}"
                self._stop.wait(
                    min(0.001 * (2 ** min(consecutive, 7)), 0.1))

    def _worker_main(self) -> None:
        """Outer worker loop: auto-restart a crashed dispatch body.

        Crash/restart counters surface in ``stats()['worker']`` — an
        injected ``WorkerCrashError`` (or any fault-hook exception)
        lands here, is counted, and the dispatch loop comes back up
        without dropping the server.
        """
        while not self._stop.is_set():
            try:
                self._run()
                return                     # clean stop
            except BaseException as e:
                self._worker_crashes += 1
                self._last_error = f"{type(e).__name__}: {e}"
                if self._stop.is_set():
                    return
                self._worker_restarts += 1

    def start(self) -> "PolicyServer":
        """Start the background dispatch thread (idempotent).

        A server stopped earlier restarts cleanly: ``stop`` closes the
        admission queue terminally, so restart swaps in a fresh one
        (sessions, caches and counters all survive the cycle).
        """
        if self._worker is None or not self._worker.is_alive():
            if self.batcher.closed:
                self.batcher = self._make_batcher()
            self._stop.clear()
            self._worker = threading.Thread(target=self._worker_main,
                                            name="policy-server",
                                            daemon=True)
            self._worker.start()
        return self

    def stop(self, join_timeout: float = 5.0) -> None:
        """Stop dispatching; queued-but-unserved requests fail fast.

        A worker that fails to join within ``join_timeout`` is wedged
        (stuck inside a dispatch): it is REPORTED — ``stats()`` shows
        ``worker.wedged`` and a ``RuntimeWarning`` fires — instead of
        silently leaked.  The reference is kept so a later ``stop`` can
        observe it finally exiting.  ``start`` brings the server back
        up afterwards.
        """
        self._stop.set()
        drained = self.batcher.close()
        err = RuntimeError("server stopped")
        for r in drained:
            r.fail(err)
        if self._worker is not None:
            self._worker.join(timeout=join_timeout)
            if self._worker.is_alive():
                self._wedged += 1
                warnings.warn(
                    f"policy-server worker failed to stop within "
                    f"{join_timeout}s (wedged in dispatch) — thread "
                    f"leaked, see stats()['worker']", RuntimeWarning,
                    stacklevel=2)
            else:
                self._worker = None

    def __enter__(self) -> "PolicyServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- ops ---------------------------------------------------------------

    def warmup(self) -> None:
        """Compile the act program for every bucket shape up front (one
        retrace per bucket) so first requests don't pay compile latency."""
        entry = self._entry
        if entry is None:
            raise RuntimeError("no actor cache: call push_params first")
        for b in self.buckets:
            obs = jnp.zeros((b,) + tuple(self.env_spec.obs_shape),
                            jnp.float32)
            jax.block_until_ready(self._step_fn(entry.cache, obs))

    def stats(self) -> Dict[str, Any]:
        """Serving counters snapshot.

        Keys: ``served`` (requests answered), ``dispatches`` (batches),
        ``padding_rows`` (total padded rows — the bucketing overhead),
        ``bucket_counts`` (dispatches per bucket), ``version`` (live cache
        version or -1), ``cache_nbytes``, ``rejected`` (requests shed by
        the ``max_queue`` bound), ``deadline_expired``, ``last_error``
        (most recent dispatch/worker failure, or None), the ``worker``
        health sub-dict (``dispatch_failures``, ``consecutive_failures``,
        ``crashes``, ``restarts``, ``wedged``, ``alive``), plus the
        ``sessions`` table counters.
        """
        entry = self._entry
        return {
            "served": self._served,
            "dispatches": self.steps.value,
            "padding_rows": self._padded,
            "bucket_counts": dict(self._bucket_counts),
            "version": -1 if entry is None else entry.version,
            "cache_nbytes": 0 if entry is None else entry.nbytes,
            "rejected": self.batcher.rejected,
            "deadline_expired": self._deadline_expired,
            "last_error": self._last_error,
            "worker": {
                "dispatch_failures": self._dispatch_failures,
                "consecutive_failures": self._consecutive_failures,
                "crashes": self._worker_crashes,
                "restarts": self._worker_restarts,
                "wedged": self._wedged,
                "alive": (self._worker is not None
                          and self._worker.is_alive()),
            },
            "sessions": self.sessions.stats(),
        }
