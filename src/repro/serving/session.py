"""Session lifecycle + thread-safe counters for the policy server.

A *session* is one concurrent consumer of the served policy — an env
instance, a user connection, an edge device.  The server holds only
accounting state per session (the policy itself is stateless obs -> action;
env state stays client-side), so thousands of sessions are cheap: the cost
of a session is one small dataclass and a dict slot.

Lifecycle::

    sid = server.open_session()       # open     (registered, steppable)
    server.submit(sid, obs).result()  # stepping (any number of times)
    server.close_session(sid)         # closed   (further submits raise)

``StepCounter`` is the saxml ``servable_model`` idiom: a mutex-guarded
monotone counter handing out dispatch/step tickets from host threads.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict


class StepCounter:
    """A thread-safe counter that hands out consecutive step numbers.

    ``next()`` returns the current value and increments — safe to call from
    any number of submitter/dispatcher threads.
    """

    def __init__(self, start: int = 0):
        """Start counting from ``start`` (default 0)."""
        self._mu = threading.Lock()
        self._value = int(start)

    def next(self) -> int:
        """Return the current ticket and advance the counter by one."""
        with self._mu:
            result = self._value
            self._value += 1
            return result

    @property
    def value(self) -> int:
        """Current counter value (the next ticket ``next()`` would return)."""
        with self._mu:
            return self._value


@dataclasses.dataclass
class Session:
    """Accounting record for one open serving session.

    Fields: ``sid`` (server-unique id), ``opened_at_step`` (global dispatch
    step at open time), ``steps`` (actions served to this session),
    ``last_version`` (cache version that answered the latest step; -1
    before the first), ``closed`` (terminal flag — closed sessions reject
    further submits).
    """

    sid: int
    opened_at_step: int
    steps: int = 0
    last_version: int = -1
    closed: bool = False


class SessionTable:
    """Thread-safe registry of open sessions.

    ``open()`` mints monotonically increasing session ids; ``close()`` is
    terminal (the record is dropped, the id is never reused).  ``checkout``
    validates a session id on the submit path and raises ``KeyError`` for
    unknown/closed sessions — a protocol error, not a server fault.
    """

    def __init__(self):
        """Create an empty table."""
        self._mu = threading.Lock()
        self._next_sid = 0
        self._sessions: Dict[int, Session] = {}
        self._opened = 0
        self._closed = 0

    def open(self, at_step: int = 0) -> int:
        """Open a new session and return its id.

        ``at_step`` stamps the global dispatch step at open time (for
        session-age accounting in ``stats``).
        """
        with self._mu:
            sid = self._next_sid
            self._next_sid += 1
            self._sessions[sid] = Session(sid=sid, opened_at_step=at_step)
            self._opened += 1
            return sid

    def checkout(self, sid: int) -> Session:
        """Return the live ``Session`` for ``sid`` or raise ``KeyError``."""
        with self._mu:
            try:
                return self._sessions[sid]
            except KeyError:
                raise KeyError(f"unknown or closed session {sid}") from None

    def on_step(self, sid: int, version: int) -> None:
        """Record one served action for ``sid`` answered by cache
        ``version`` (missing sids are ignored: the session may close
        between submit and dispatch, which is a legal race)."""
        with self._mu:
            s = self._sessions.get(sid)
            if s is not None:
                s.steps += 1
                s.last_version = version

    def close(self, sid: int) -> Session:
        """Close ``sid`` and return its final record; ``KeyError`` if it
        is not open."""
        with self._mu:
            try:
                s = self._sessions.pop(sid)
            except KeyError:
                raise KeyError(f"unknown or closed session {sid}") from None
            s.closed = True
            self._closed += 1
            return s

    def __len__(self) -> int:
        """Number of currently open sessions."""
        with self._mu:
            return len(self._sessions)

    def stats(self) -> Dict[str, int]:
        """Counters: ``open`` (now), ``opened``/``closed`` (lifetime)."""
        with self._mu:
            return {"open": len(self._sessions), "opened": self._opened,
                    "closed": self._closed}
