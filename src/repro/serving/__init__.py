"""Continuous-batching quantized policy-serving subsystem.

The "millions of users" leg of the ROADMAP: many concurrent env/user
sessions multiplexed onto shape-bucketed padded batches (``batcher``),
answered by a packed fp32/int8/int4 actor cache with zero-copy hot-swap
on every param push (``server``), with per-session lifecycle accounting
(``session``).  See ``docs/serving.md`` for the operator's view and
``docs/architecture.md`` for where this sits in the module map.
"""
from repro.serving.batcher import (Batcher, DeadlineExceededError,
                                   QueueFullError, Request, ServeResult,
                                   pad_rows, remove_padding, select_bucket)
from repro.serving.server import (CacheEntry, PolicyServer,
                                  WorkerCrashError, greedy_calib_obs,
                                  make_fp32_act_fn)
from repro.serving.session import Session, SessionTable, StepCounter

__all__ = [
    "Batcher", "DeadlineExceededError", "QueueFullError", "Request",
    "ServeResult", "pad_rows", "remove_padding", "select_bucket",
    "CacheEntry", "PolicyServer", "WorkerCrashError", "greedy_calib_obs",
    "make_fp32_act_fn", "Session", "SessionTable", "StepCounter",
]
