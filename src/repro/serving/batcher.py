"""Shape-bucketed continuous batching: admission queue, padding, unpadding.

The server compiles one program per *bucket* (a fixed batch shape) instead
of one per live batch size.  Incoming requests queue on the host; the
dispatcher admits up to ``max_batch`` of them (waiting at most
``max_wait_us`` after the oldest queued request for stragglers — the tail-
latency knob), pads the stacked observations up to the smallest bucket that
fits, runs the policy once, and slices the padding back off
(``remove_padding``, the saxml ``servable_model`` idiom).

Padding fill is **repeat-last-row**, not zeros: a duplicated row never
changes a per-tensor min/max reduction, so the dynamically-quantized
(``calib_batch=0``) actor path sees the same activation ranges padded as
unpadded at every layer — padding is range-neutral by construction (the
``test_dynamic_path_padding_neutral`` property in ``tests/test_serving.py``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np


class QueueFullError(RuntimeError):
    """Admission queue at capacity — request rejected (load shedding).

    Raised by ``Batcher.put`` when ``max_queue`` is set and reached: the
    typed, immediate alternative to unbounded queue growth.  Callers
    treat it as backpressure (retry later / shed the request).
    """


class DeadlineExceededError(TimeoutError):
    """A request's deadline expired before it was dispatched."""


def select_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` rows.

    ``buckets`` must be sorted ascending; selection is a pure function of
    ``(n, buckets)`` — deterministic, no load feedback — so a replayed
    request stream pads identically (the ``test_bucket_selection_*``
    properties).  Raises ``ValueError`` for ``n < 1`` or ``n`` above the
    largest bucket (the admission loop never admits more than
    ``buckets[-1]``).
    """
    if n < 1:
        raise ValueError(f"need at least one row, got n={n}")
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds largest bucket {buckets[-1]}")


def pad_rows(x: np.ndarray, bucket: int) -> np.ndarray:
    """Pad ``x`` (n, ...) up to (bucket, ...) by repeating the last row.

    Repeat-padding keeps every per-tensor range reduction over the batch
    unchanged (duplicates never move a min/max), which is what makes
    padding invisible to the dynamically-quantized actor path; see the
    module docstring.  No-op when ``n == bucket``.
    """
    n = x.shape[0]
    if n == bucket:
        return x
    if n > bucket:
        raise ValueError(f"{n} rows do not fit bucket {bucket}")
    reps = np.repeat(x[-1:], bucket - n, axis=0)
    return np.concatenate([x, reps], axis=0)


def remove_padding(y, n: int):
    """Slice the first ``n`` rows back out of a padded result.

    Accepts jax or numpy arrays of shape (bucket, ...) and returns the
    (n, ...) prefix — the inverse of ``pad_rows`` on the result side.
    """
    if y.shape[0] == n:
        return y
    return y[:n]


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One answered request: ``action`` (host numpy), ``version`` (the
    cache version that computed it), ``latency_s`` (enqueue -> completion
    wall time), ``step`` (global dispatch-step ticket of the batch)."""

    action: np.ndarray
    version: int
    latency_s: float
    step: int


class Request:
    """A queued obs -> action query for one session.

    Created by ``PolicyServer.submit``; the dispatcher fills it in and sets
    the event.  ``result()`` blocks the submitting thread until then.
    """

    __slots__ = ("sid", "obs", "t_enqueue", "deadline", "_event",
                 "_result", "_error")

    def __init__(self, sid: int, obs: np.ndarray,
                 deadline: Optional[float] = None):
        """Bind a single observation (no batch axis) to session ``sid``.

        ``deadline`` is an absolute ``perf_counter`` time; a request
        still undispatched past it is failed with
        ``DeadlineExceededError`` instead of served stale (None = no
        deadline).
        """
        self.sid = sid
        self.obs = obs
        self.t_enqueue = time.perf_counter()
        self.deadline = deadline
        self._event = threading.Event()
        self._result: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None

    def expired(self, now: Optional[float] = None) -> bool:
        """True when a deadline is set and already past."""
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) \
            > self.deadline

    def complete(self, action: np.ndarray, version: int, step: int,
                 t_done: float) -> None:
        """Fill in the answer and release ``result()`` (dispatcher side)."""
        self._result = ServeResult(action=action, version=version,
                                   latency_s=t_done - self.t_enqueue,
                                   step=step)
        self._event.set()

    def fail(self, err: BaseException) -> None:
        """Propagate a dispatch error to the waiting submitter."""
        self._error = err
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        """Block until served and return the ``ServeResult``.

        Raises ``TimeoutError`` after ``timeout`` seconds, or re-raises the
        dispatcher-side exception if the batch failed / the server shut
        down with this request still queued.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(f"request for session {self.sid} not served "
                               f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class Batcher:
    """Host-side admission queue turning single requests into batches.

    Admission policy (the two tail-latency knobs):

    * ``max_batch``  — largest admitted batch == the largest bucket;
      a full queue dispatches immediately.
    * ``max_wait_us`` — after the *oldest* queued request has waited this
      long, dispatch whatever is queued (0 = never wait for stragglers).

    Overload policy: ``max_queue`` bounds the admission queue; a ``put``
    against a full queue raises the typed ``QueueFullError`` immediately
    (load shedding with backpressure) instead of growing without bound
    while latency quietly diverges.  0 (default) keeps the queue
    unbounded.  Shed requests are counted in ``rejected``.

    ``put`` is called from submitter threads, ``get_batch`` from the
    dispatcher; both are condition-variable synchronized.
    """

    def __init__(self, max_batch: int, max_wait_us: int = 2000,
                 max_queue: int = 0):
        """See class docstring for the three knobs."""
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait_s = max(int(max_wait_us), 0) * 1e-6
        self.max_queue = int(max_queue)
        self._q: deque = deque()
        self._cond = threading.Condition()
        self._closed = False
        self._rejected = 0

    def put(self, req: Request) -> None:
        """Enqueue one request.

        Raises ``RuntimeError`` after ``close``, ``QueueFullError`` when
        ``max_queue`` is set and the queue is at capacity.
        """
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            if 0 < self.max_queue <= len(self._q):
                self._rejected += 1
                raise QueueFullError(
                    f"admission queue full ({len(self._q)}/"
                    f"{self.max_queue}); request for session {req.sid} "
                    f"shed — retry with backoff")
            self._q.append(req)
            self._cond.notify_all()

    @property
    def rejected(self) -> int:
        """Requests shed by the ``max_queue`` bound since construction."""
        with self._cond:
            return self._rejected

    @property
    def closed(self) -> bool:
        """True once ``close`` ran; a closed batcher never reopens."""
        with self._cond:
            return self._closed

    def qsize(self) -> int:
        """Number of requests currently queued (snapshot)."""
        with self._cond:
            return len(self._q)

    def get_batch(self, timeout: Optional[float] = None
                  ) -> Optional[List[Request]]:
        """Admit the next batch (FIFO prefix of the queue), or ``None``.

        Blocks up to ``timeout`` seconds for a first request; once one is
        queued, waits at most ``max_wait_us`` past *its* enqueue time for
        more, then returns up to ``max_batch`` requests.  Returns ``None``
        on timeout with an empty queue, or when closed and drained.
        """
        with self._cond:
            deadline = (time.perf_counter() + timeout
                        if timeout is not None else None)
            while not self._q:
                if self._closed:
                    return None
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    return None
                self._cond.wait(remaining)
            admit_by = self._q[0].t_enqueue + self.max_wait_s
            while (len(self._q) < self.max_batch and not self._closed):
                remaining = admit_by - time.perf_counter()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
            n = min(len(self._q), self.max_batch)
            return [self._q.popleft() for _ in range(n)]

    def close(self) -> List[Request]:
        """Refuse new work, wake the dispatcher, return still-queued
        requests (the server fails them so no submitter blocks forever)."""
        with self._cond:
            self._closed = True
            drained = list(self._q)
            self._q.clear()
            self._cond.notify_all()
            return drained
