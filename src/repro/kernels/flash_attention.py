"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Needed by the prefill_32k / long_500k shapes: dense S×T score materialization
at 32k is ~2 GB per head — far beyond the ~16 MB v5e VMEM — so attention is
computed in (block_q × block_kv) tiles with the streaming softmax recurrence,
keeping the working set (q tile, k/v tile, accumulator, m/l statistics) in
VMEM. Supports causal masking, sliding windows (h2o-danube / mixtral /
gemma2-local / recurrentgemma-local) and gemma2's tanh logit soft-capping.

Grid: (num_q_blocks, num_kv_blocks), kv innermost; the (m, l, acc) softmax
state lives in VMEM scratch across kv iterations. Softmax statistics are fp32
regardless of io dtype. Block sizes default to (256, 512): with d_head=128,
q-tile 256×128 f32 (128 KiB) + kv tiles 512×128×2 (512 KiB) + acc (128 KiB)
comfortably fit VMEM while keeping the MXU shapes multiples of (8, 128).

One (seq, head_dim) problem per call; the ops.py wrapper vmaps over
batch × heads and handles GQA head-group broadcasting.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], seq_q: int, seq_kv: int,
                  block_q: int, block_kv: int, n_kv: int):
    qi = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)             # (bq, d)
    k = k_ref[...].astype(jnp.float32)             # (bkv, d)
    v = v_ref[...].astype(jnp.float32)             # (bkv, d)

    # Zero padded kv-tail rows (pallas pads OOB reads with an unspecified
    # value — NaN in interpret mode — and 0 * NaN would poison the output).
    kv_valid = (kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_kv, 1), 0)) < seq_kv
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # Absolute positions; query positions are aligned to the END of the kv
    # axis (seq_kv - seq_q offset) so the same kernel serves decode.
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + (seq_kv - seq_q)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < seq_kv  # guard padding of the last kv block
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                          # (bq, bkv)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        lse = l_ref[...]
        lse = jnp.where(lse == 0.0, 1.0, lse)       # fully-masked rows -> 0
        o_ref[...] = (acc_ref[...] / lse).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           block_q: int = 256, block_kv: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    """Single-head attention: q (S, D), k/v (T, D) -> (S, D)."""
    seq_q, d = q.shape
    seq_kv = k.shape[0]
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    bq = min(block_q, seq_q)
    bkv = min(block_kv, seq_kv)
    n_q = pl.cdiv(seq_q, bq)
    n_kv = pl.cdiv(seq_kv, bkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, seq_q=seq_q, seq_kv=seq_kv,
        block_q=bq, block_kv=bkv, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(n_q, n_kv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bkv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m — running max
            pltpu.VMEM((bq, 1), jnp.float32),    # l — running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # acc — unnormalized output
        ],
        interpret=interpret,
    )(q, k, v)
