"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between the Pallas hot path (TPU target; ``interpret=True``
execution on CPU for validation) and the pure-jnp oracle in ``ref.py`` (used
inside pjit programs during the CPU dry-run, where XLA fuses it fine and the
kernel is not the object of study). Selection:

    backend="pallas"     pallas_call, compiled (TPU)
    backend="interpret"  pallas_call, interpret mode (CPU correctness)
    backend="ref"        pure-jnp oracle
    backend="auto"       pallas on TPU, ref elsewhere
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "backend"))
def fake_quant(x: jnp.ndarray, bits: int = 8, *, backend: str = "auto"
               ) -> jnp.ndarray:
    """Fused per-tensor quantize-dequantize of an arbitrary-rank tensor."""
    b = _resolve(backend)
    if b == "ref":
        return ref.fake_quant_ref(x, bits)
    vmin = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    vmax = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    out = fake_quant_pallas(x2, vmin, vmax, bits,
                            interpret=(b == "interpret"))
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("out_dtype", "backend"))
def int8_matmul(x_q, w_q, x_scale, x_zero, w_scale, w_zero,
                out_dtype=jnp.float32, *, backend: str = "auto"):
    """(M,K)i8 @ (K,N)i8 -> (M,N)f with affine dequantization."""
    b = _resolve(backend)
    if b == "ref":
        return ref.int8_matmul_ref(x_q, w_q, x_scale, w_scale, x_zero, w_zero,
                                   out_dtype)
    return int8_matmul_pallas(x_q, w_q, x_scale, x_zero, w_scale, w_zero,
                              out_dtype=out_dtype,
                              interpret=(b == "interpret"))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "backend"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    backend: str = "auto"):
    """Multi-head attention.

    q: (..., S, D); k/v: (..., T, D) — leading dims are batch/head and are
    vmapped over. GQA sharing is handled by the caller (repeat/reshape of kv).
    """
    b = _resolve(backend)
    if b == "ref":
        fn = functools.partial(ref.mha_ref, causal=causal, window=window,
                               softcap=softcap, scale=scale)
    else:
        fn = functools.partial(flash_attention_pallas, causal=causal,
                               window=window, softcap=softcap, scale=scale,
                               interpret=(b == "interpret"))
    flat_fn = fn
    for _ in range(q.ndim - 2):
        flat_fn = jax.vmap(flat_fn)
    return flat_fn(q, k, v)
