"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between the Pallas hot path (TPU target; ``interpret=True``
execution on CPU for validation), the native-XLA integer path in
``xla_backend.py`` (the commodity CPU/GPU hot path), and the pure-jnp oracle
in ``ref.py``. Selection:

    backend="pallas"     pallas_call, compiled (TPU)
    backend="interpret"  pallas_call, interpret mode (CPU correctness)
    backend="xla"        lax.dot_general integer GEMM (CPU/GPU hot path)
    backend="ref"        pure-jnp oracle
    backend="auto"       pallas on TPU, xla elsewhere

``auto`` also consults the ``REPRO_KERNEL_BACKEND`` env var: setting it to
``pallas``/``interpret``/``ref``/``xla`` forces that backend at every
``backend="auto"`` call site (CI / debugging without threading the knob
through every config).  An explicit ``backend=`` argument always wins — the
parity tests pin backends on purpose — and the variable is read at trace
time, so set it before the first jitted call.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import affine
from repro.kernels import ref, xla_backend
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.fused_qmlp import fused_qmlp_pallas
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas

ENV_BACKEND = "REPRO_KERNEL_BACKEND"
BACKENDS = ("pallas", "interpret", "ref", "xla")


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    env = os.environ.get(ENV_BACKEND)
    if env:
        if env not in BACKENDS:
            raise ValueError(f"{ENV_BACKEND}={env!r} — must be one of "
                             f"{BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "backend"))
def fake_quant(x: jnp.ndarray, bits: int = 8, *, backend: str = "auto"
               ) -> jnp.ndarray:
    """Fused per-tensor quantize-dequantize of an arbitrary-rank tensor."""
    b = _resolve(backend)
    if b in ("ref", "xla"):
        # elementwise — the oracle IS the optimal XLA program (one fused
        # loop); "xla" aliases it so auto-resolution never breaks an op
        return ref.fake_quant_ref(x, bits)
    vmin = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    vmax = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim != 2 else x
    out = fake_quant_pallas(x2, vmin, vmax, bits,
                            interpret=(b == "interpret"))
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("out_dtype", "backend",
                                             "w_bits"))
def int8_matmul(x_q, w_q, x_scale, x_zero, w_scale, w_zero,
                out_dtype=jnp.float32, *, backend: str = "auto",
                w_bits: int = 8):
    """(M,K)i8 @ (K,N)i8 -> (M,N)f with affine dequantization.

    ``w_bits <= 4`` consumes sub-8-bit packed weights (two int4 codes per
    int8 byte along K, ``core.affine.pack_int4``): the Pallas path unpacks
    in-kernel, the oracle unpacks up front — both see identical codes, so
    the W4A8 product equals the W8A8 product over the unpacked codes.
    """
    b = _resolve(backend)
    if w_bits <= 4:
        if w_q.shape[0] != (x_q.shape[-1] + 1) // 2:
            # the packed layout is easy to get wrong silently (unpacked
            # codes, or an 8-bit cache passed with w_bits=4, would just
            # compute garbage)
            raise ValueError(
                f"w_bits={w_bits} expects byte-packed codes of "
                f"{(x_q.shape[-1] + 1) // 2} rows for K={x_q.shape[-1]}, "
                f"got {w_q.shape}")
    elif w_q.shape[0] != x_q.shape[-1]:
        # a K-mismatched w_q (e.g. a byte-packed int4 cache passed with
        # the default w_bits=8) would otherwise broadcast or contract
        # garbage silently
        raise ValueError(
            f"w_bits={w_bits} expects unpacked codes of "
            f"{x_q.shape[-1]} rows for K={x_q.shape[-1]}, got "
            f"{w_q.shape}; byte-packed int4 caches must pass w_bits<=4")
    if b == "xla":
        return xla_backend.int8_matmul_xla(x_q, w_q, x_scale, x_zero,
                                           w_scale, w_zero,
                                           out_dtype=out_dtype,
                                           w_bits=w_bits)
    if b == "ref":
        if w_bits <= 4:
            w_q = affine.unpack_int4(w_q, x_q.shape[-1])
        return ref.int8_matmul_ref(x_q, w_q, x_scale, w_scale, x_zero, w_zero,
                                   out_dtype)
    return int8_matmul_pallas(x_q, w_q, x_scale, x_zero, w_scale, w_zero,
                              out_dtype=out_dtype,
                              interpret=(b == "interpret"), w_bits=w_bits)


# ---------------------------------------------------------------------------
# fused quantized MLP (single-pass actor forward)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("out_dtype", "backend"))
def fused_qmlp(x, layers, out_dtype=jnp.float32, *, backend: str = "auto"):
    """Whole-MLP quantized forward in one kernel dispatch.

    ``x`` is fp32 with arbitrary leading batch dims; ``layers`` a tuple of
    ``fused_qmlp.QMLPLayer`` whose ``x_delta``/``x_zero`` carry *static*
    activation scales (see ``rl.actorq.calibrate_actor_cache``).  The input
    is quantized here with layer 0's params (one elementwise op XLA fuses
    into the producer); every inter-layer activation then stays int8 inside
    the kernel and only the head dequantizes.
    """
    b = _resolve(backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    l0 = layers[0]
    x_q = affine.quantize_with_params(
        x2, affine.AffineParams(l0.x_delta, l0.x_zero, bits=8))
    if b == "ref":
        y = ref.fused_qmlp_ref(x_q, layers)
    elif b == "xla":
        y = xla_backend.fused_qmlp_xla(x_q, layers, out_dtype=out_dtype)
    else:
        y = fused_qmlp_pallas(x_q, layers, out_dtype=out_dtype,
                              interpret=(b == "interpret"))
    return y.reshape(lead + y.shape[-1:]).astype(out_dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "backend"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    backend: str = "auto"):
    """Multi-head attention.

    q: (..., S, D); k/v: (..., T, D) — leading dims are batch/head and are
    vmapped over. GQA sharing is handled by the caller (repeat/reshape of kv).
    """
    b = _resolve(backend)
    if b in ("ref", "xla"):
        # the dense oracle is already the best plain-XLA attention program
        # at these policy-sized shapes; "xla" aliases it (auto-safe)
        fn = functools.partial(ref.mha_ref, causal=causal, window=window,
                               softcap=softcap, scale=scale)
    else:
        fn = functools.partial(flash_attention_pallas, causal=causal,
                               window=window, softcap=softcap, scale=scale,
                               interpret=(b == "interpret"))
    flat_fn = fn
    for _ in range(q.ndim - 2):
        flat_fn = jax.vmap(flat_fn)
    return flat_fn(q, k, v)


# ---------------------------------------------------------------------------
# int8 KV-cache decode attention
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "backend"))
def int8_cache_attention(q, k_codes, k_scale, v_codes, v_scale, pos, *,
                         window: Optional[int] = None,
                         backend: str = "auto"):
    """Single-token decode attention over an int8-coded KV cache.

    The decode-side counterpart of :func:`flash_attention`: one new query
    attends over a cache of per-token symmetrically quantized keys/values
    (codes + scales from ``core.affine.quantize_symmetric``), dequantizing
    on the fly.  Innermost shapes: ``q (G, Dh)`` — G query heads sharing
    one KV head — against ``k_codes/v_codes (T, Dh)`` int8 and
    ``k_scale/v_scale (T, 1)`` f32.  Slots with index ``> pos`` (and, with
    ``window``, ``<= pos - window``) are masked out, so a zero-initialized
    cache can be attended before it is full.

    Leading dims are vmapped over; ``pos`` broadcasts — its shape must be
    a leading prefix of ``q``'s batch dims (scalar pos = one shared
    position, per-batch pos = ragged decode).  ``ref``/``xla`` run the
    dense oracle ``ref.int8_cache_decode_ref`` (aliased — bitwise-equal by
    construction); ``pallas``/``interpret`` the online-softmax kernel,
    which matches the oracle to fp tolerance (fp path: see
    docs/contracts.md "Attention parity").
    """
    from repro.kernels.int8_cache_attention import int8_cache_decode_attention
    b = _resolve(backend)
    if b in ("ref", "xla"):
        fn = functools.partial(ref.int8_cache_decode_ref, window=window)
    else:
        def fn(q_, kc, ks, vc, vs, p, _w=window, _i=(b == "interpret")):
            return int8_cache_decode_attention(q_, kc, ks, vc, vs, p,
                                               window=_w, interpret=_i)
    pos = jnp.asarray(pos, jnp.int32)
    n_lead = q.ndim - 2
    if pos.ndim > n_lead:
        raise ValueError(f"pos rank {pos.ndim} exceeds batch rank {n_lead}")
    flat_fn = fn
    # wrap innermost-first: pos maps only over its own (leading) dims
    for i in reversed(range(n_lead)):
        ax = 0 if i < pos.ndim else None
        flat_fn = jax.vmap(flat_fn, in_axes=(0, 0, 0, 0, 0, ax))
    return flat_fn(q, k_codes, k_scale, v_codes, v_scale, pos)
