"""Native-XLA int8 backend: the quantized GEMMs as plain lax programs.

This is the off-TPU hot path behind ``kernel_backend="xla"`` (and the
``auto`` default everywhere except TPU — ``kernels.ops._resolve``).  The
Pallas kernels target the TPU MXU; the pure-jnp oracle in ``ref.py`` is
correct everywhere but pays one int32 matmul that XLA:CPU lowers to a
scalar loop, which is how the committed benchmark ended up with int8
actors at 0.17–0.37x fp32 on CPU.  Here each platform gets the lowering
its XLA backend is actually fast at:

* **gpu/tpu** — ``lax.dot_general`` directly on the int8 codes with
  ``preferred_element_type=jnp.int32`` (the native integer-MMA path),
  plus the same ``sum_w``/``sum_x`` zero-point-correction algebra as
  ``ref.int8_matmul_ref``.

* **cpu** — jaxlib's CPU backend emits a naive loop for integer dots
  (measured 7–8x *slower* than its f32 GEMM on an AVX-512 host), so the
  codes are *centered* and the contraction runs on the f32 GEMM:

      (x_q - x_zero) @ (w_q - w_zero)  ==  x_q@w_q - x_zero*sum_w
                                           - w_zero*sum_x + K*x_zero*w_zero

  i.e. the centered product *is* the zero-point-corrected accumulator,
  with every runtime reduction term eliminated (the centering folds into
  the int8->f32 cast pass XLA fuses anyway).  The f32 evaluation is
  **exact**: centered 8-bit codes have magnitude <= 255, so every product
  is an integer below 2**16 and every partial sum stays below the f32
  exact-integer bound 2**24 while the contraction is at most
  ``_exact_chunk`` long.  Longer contractions are split into exact chunks
  accumulated in int32 — the same mod-2**32 arithmetic as the oracle —
  so the result is bit-identical to int32 accumulation at any K.

Either way the float epilogue multiplies in the exact op order of
``ref.int8_matmul_ref`` (scale product, then correction term), which is
the repo's bitwise-anchor contract: ``tests/test_xla_backend.py`` asserts
``assert_array_equal`` against the oracle across the bits/shape matrix.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import affine

# Largest contraction (in elements) whose centered-code f32 dot is exact:
# |products| <= amax * wmax, and f32 adds of integers are exact below 2**24.
_F32_EXACT = 1 << 24
_A8_MAX = 255            # centered 8-bit activation codes: |x_q - x_zero|


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _exact_chunk(w_bits: int) -> int:
    w_max = (1 << w_bits) - 1            # centered |w_q - w_zero| bound
    return max(_F32_EXACT // (_A8_MAX * w_max), 1)


def _exact_f32_matmul(xc: jnp.ndarray, wc: jnp.ndarray, w_bits: int
                      ) -> jnp.ndarray:
    """f32 GEMM over centered integer-valued codes, exact vs int32 accum.

    Single chunk: every partial sum is below 2**24, so the f32 result is
    the exact integer.  Chunked: each chunk is exact, and the chunks are
    summed in int32 — identical (mod 2**32) to the oracle's accumulator.
    """
    k = xc.shape[-1]
    chunk = _exact_chunk(w_bits)
    if k <= chunk:
        return jnp.matmul(xc, wc)
    acc = None
    for s in range(0, k, chunk):
        part = jnp.matmul(xc[:, s:s + chunk], wc[s:s + chunk]
                          ).astype(jnp.int32)
        acc = part if acc is None else acc + part
    return acc.astype(jnp.float32)


def _int_dot_corr(x_q: jnp.ndarray, w_q: jnp.ndarray, x_zero, w_zero
                  ) -> jnp.ndarray:
    """Native int8 dot + zero-point correction (ref algebra), int32 out."""
    k = x_q.shape[-1]
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    sum_w = jnp.sum(w_q.astype(jnp.int32), axis=0)                # (N,)
    sum_x = jnp.sum(x_q.astype(jnp.int32), axis=-1, keepdims=True)  # (M,1)
    xz = x_zero.astype(jnp.int32)
    wz = w_zero.astype(jnp.int32)[None, :]
    return acc - xz * sum_w[None, :] - wz * sum_x + k * xz * wz


def int8_matmul_xla(x_q: jnp.ndarray, w_q: jnp.ndarray, x_scale, x_zero,
                    w_scale, w_zero, out_dtype: Any = jnp.float32, *,
                    w_bits: int = 8) -> jnp.ndarray:
    """(M,K)i8 @ (K,N)i8 -> (M,N)f, bit-identical to ``int8_matmul_ref``.

    ``w_bits <= 4`` consumes byte-packed codes (``affine.pack_int4``
    layout, ``(ceil(K/2), N)``) and unpacks them host-side — XLA fuses
    the nibble shifts into the operand cast, so the GEMM still dominates.
    """
    k = x_q.shape[-1]
    if w_bits <= 4:
        w_q = affine.unpack_int4(w_q, k)
    x_zero = jnp.asarray(x_zero, jnp.float32)
    w_zero = jnp.asarray(w_zero, jnp.float32).reshape(-1)
    if _is_cpu():
        xc = x_q.astype(jnp.float32) - x_zero
        wc = w_q.astype(jnp.float32) - w_zero[None, :]
        corr = _exact_f32_matmul(xc, wc, min(w_bits, 8))
    else:
        corr = _int_dot_corr(x_q, w_q, x_zero, w_zero).astype(jnp.float32)
    w_scale = jnp.asarray(w_scale, jnp.float32).reshape(-1)
    return (x_scale * w_scale[None, :] * corr).astype(out_dtype)


def fused_qmlp_xla(x_q: jnp.ndarray, layers: Tuple, *,
                   out_dtype: Any = jnp.float32) -> jnp.ndarray:
    """Chained-XLA fused quantized MLP: activations stay int8-coded.

    ``x_q`` is ``(M, K0)`` int8, already quantized with layer 0's static
    params (``kernels.ops.fused_qmlp`` does this); ``layers`` a tuple of
    ``fused_qmlp.QMLPLayer`` carrying the ``calibrate_actor_cache`` static
    requant scales.  Between the ``dot_general`` calls each hidden
    activation is requantized with the next layer's static params —
    exactly ``affine.quantize_with_params`` (round of a division, then
    clip), so the chain is bitwise the ref oracle / per-layer path.  On
    CPU the int8 codes ride as centered f32 (see module docstring); on
    gpu/tpu they stay int8 into the native integer dot.
    """
    n_layers = len(layers)
    cpu = _is_cpu()
    h = (x_q.astype(jnp.float32) - layers[0].x_zero) if cpu else x_q
    for i, layer in enumerate(layers):
        w = layer.codes
        if layer.bits <= 4:
            w = affine.unpack_int4(w, layer.k)
        col_zero = layer.col_zero.reshape(-1)
        if cpu:
            wc = w.astype(jnp.float32) - col_zero[None, :]
            corr = _exact_f32_matmul(h, wc, min(layer.bits, 8))
        else:
            corr = _int_dot_corr(h, w, layer.x_zero,
                                 col_zero).astype(jnp.float32)
        y = layer.x_delta * layer.col_scale[None, :] * corr + layer.bias
        if i + 1 < n_layers:
            nxt = layers[i + 1]
            y = jnp.maximum(y, 0.0)
            # static requant == affine.quantize_with_params bit for bit:
            # round(y/delta) (division, not a reciprocal multiply) + zero,
            # clipped to the signed-storage int8 range
            q = jnp.clip(jnp.round(y / nxt.x_delta) + nxt.x_zero,
                         -128.0, 127.0)
            h = (q - nxt.x_zero) if cpu else q.astype(jnp.int8)
        else:
            return y.astype(out_dtype)
