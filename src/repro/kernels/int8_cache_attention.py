"""Pallas TPU kernel: decode attention over an int8-quantized KV cache.

Beyond-paper serving hot spot (DESIGN.md §3): the paper quantizes weights for
deployment; at LLM-serving scale the KV cache dominates decode memory
traffic, so we store it as int8 codes + per-token/head scales
(models/attention.py) and fuse the dequantization into the attention kernel —
codes stream HBM->VMEM at half the bf16 bytes and are widened in-register,
never materializing an fp cache.

One (q, cache) problem per call: q (H, Dh) for a single decode position,
cache k/v (T, KV, Dh) int8 + scales (T, KV). GQA handled by the wrapper
(reshape H -> KV x G). Grid over T blocks with the online-softmax state in
VMEM scratch (same recurrence as flash_attention.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, n_t: int, t_total: int,
            block_t: int, window: Optional[int]):
    tj = pl.program_id(0)

    @pl.when(tj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                 # (G, Dh)
    # dequantize the cache block in-register
    k = k_ref[...].astype(jnp.float32) * ks_ref[...]   # (Bt, Dh)
    v = v_ref[...].astype(jnp.float32) * vs_ref[...]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask: valid slots [0, pos], ring-window if any
    pos = pos_ref[0]
    t_idx = tj * block_t + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_t), 1)
    valid = (t_idx <= pos) & (t_idx < t_total)
    if window is not None:
        valid &= t_idx > pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(tj == n_t - 1)
    def _done():
        lse = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[...] = (acc_ref[...] / lse).astype(o_ref.dtype)


def int8_cache_decode_attention(q: jnp.ndarray, k_codes: jnp.ndarray,
                                k_scale: jnp.ndarray, v_codes: jnp.ndarray,
                                v_scale: jnp.ndarray, pos: jnp.ndarray, *,
                                window: Optional[int] = None,
                                block_t: int = 512,
                                interpret: bool = False) -> jnp.ndarray:
    """q: (G, Dh) queries of ONE kv head group at decode position ``pos``;
    k/v codes: (T, Dh) int8 with (T, 1) scales. Returns (G, Dh)."""
    g, dh = q.shape
    t = k_codes.shape[0]
    bt = min(block_t, t)
    n_t = pl.cdiv(t, bt)
    scale = dh ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_t=n_t, t_total=t,
                          block_t=bt, window=window),
        grid=(n_t,),
        in_specs=[
            pl.BlockSpec((g, dh), lambda j: (0, 0)),
            pl.BlockSpec((bt, dh), lambda j: (j, 0)),
            pl.BlockSpec((bt, 1), lambda j: (j, 0)),
            pl.BlockSpec((bt, dh), lambda j: (j, 0)),
            pl.BlockSpec((bt, 1), lambda j: (j, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((g, dh), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_codes, k_scale, v_codes, v_scale,
      jnp.asarray(pos, jnp.int32).reshape(1))
