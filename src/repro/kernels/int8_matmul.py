"""Pallas TPU kernel: W8A8 integer GEMM with int32 accumulation + dequant.

The deployment hot path of the paper's case study (Sec. 5: int8 policy
inference, 18x speedup on the RasPi) re-thought for the TPU MXU: int8 operands
feed ``lax.dot_general`` with ``preferred_element_type=int32`` (the MXU's
native 8-bit mode doubles matmul throughput on v5e), zero-point corrections
are applied with per-K-block partial sums, and the affine dequant happens once
in the epilogue — one fused kernel instead of dequantize-then-matmul.

Layout: x_q (M,K) int8 with per-tensor scale/zero; w_q (K,N) int8 with
per-output-channel (N,) scale/zero — the paper's per-tensor/per-axis split.

Grid is (M/bm, N/bn, K/bk) with K innermost; the int32 accumulator and the
two zero-point correction sums live in VMEM scratch across the K iterations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import affine


def _int8_matmul_kernel(x_ref, w_ref, xs_ref, xz_ref, ws_ref, wz_ref,
                        o_ref, acc_ref, sumx_ref, sumw_ref, *, n_k: int,
                        k_total: int, w_bits: int = 8):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)
        sumw_ref[...] = jnp.zeros_like(sumw_ref)

    x = x_ref[...].astype(jnp.int32)   # (bm, bk) — widened for CPU interpret;
    w = w_ref[...]                     # on TPU the MXU consumes int8 directly.
    if w_bits <= 4:
        # sub-8-bit weights arrive packed two-per-byte along K: the block
        # holds bk/2 packed rows; unpack in-kernel.  Garbage nibbles (the
        # pad byte of an odd K and OOB block reads) only occupy rows
        # >= k_total, which the k_valid mask below zeroes anyway.
        w = affine.unpack_int4(w, x_ref.shape[1])
    w = w.astype(jnp.int32)
    # Zero the padded K tail of the last block (pallas pads OOB reads with an
    # unspecified value; zero codes are the additive identity for acc AND the
    # zero-point correction sums).
    bk = x_ref.shape[1]
    k_pos = k_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    k_valid = k_pos < k_total
    x = jnp.where(k_valid, x, 0)
    w = jnp.where(k_valid.T, w, 0)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    sumx_ref[...] += jnp.sum(x, axis=1, keepdims=True)       # (bm, 1)
    sumw_ref[...] += jnp.sum(w, axis=0, keepdims=True)       # (1, bn)

    @pl.when(k_idx == n_k - 1)
    def _epilogue():
        # NB: k_total is the TRUE reduction length — padded tail blocks hold
        # zero codes, which contribute nothing to acc/sums, but the
        # zero-point cross term must use the unpadded K.
        xz = xz_ref[0, 0].astype(jnp.int32)
        wz = wz_ref[0, :].astype(jnp.int32)                  # (bn,)
        corr = (acc_ref[...]
                - xz * sumw_ref[...]
                - wz[None, :] * sumx_ref[...]
                + k_total * xz * wz[None, :])
        scale = xs_ref[0, 0] * ws_ref[0, :][None, :]
        o_ref[...] = (scale * corr.astype(jnp.float32)).astype(o_ref.dtype)


def int8_matmul_pallas(x_q: jnp.ndarray, w_q: jnp.ndarray,
                       x_scale: jnp.ndarray, x_zero: jnp.ndarray,
                       w_scale: jnp.ndarray, w_zero: jnp.ndarray,
                       *, block_m: int = 256, block_n: int = 256,
                       block_k: int = 256, out_dtype=jnp.float32,
                       interpret: bool = False,
                       w_bits: int = 8) -> jnp.ndarray:
    """Dequantized (M,N) product of int8 (M,K) x (K,N).

    ``w_bits <= 4``: ``w_q`` is ``(ceil(K/2), N)`` with two int4 codes per
    byte along K (``core.affine.pack_int4``), unpacked in-kernel; K comes
    from ``x_q``.
    """
    m, k = x_q.shape
    if w_bits <= 4:
        assert w_q.shape[0] == (k + 1) // 2, (w_q.shape, k)
        n = w_q.shape[1]
        # even K block so each maps to an integral number of packed rows
        bk = min(block_k, k + (k % 2))
        bk += bk % 2
        w_rows = bk // 2
    else:
        k2, n = w_q.shape
        assert k == k2
        bk = min(block_k, k)
        w_rows = bk
    bm, bn = min(block_m, m), min(block_n, n)
    n_k = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), n_k)

    xs = jnp.asarray(x_scale, jnp.float32).reshape(1, 1)
    xz = jnp.asarray(x_zero, jnp.float32).reshape(1, 1)
    ws = jnp.asarray(w_scale, jnp.float32).reshape(1, n)
    wz = jnp.asarray(w_zero, jnp.float32).reshape(1, n)

    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, n_k=n_k, k_total=k,
                          w_bits=w_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((w_rows, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            # int32 accumulator + zero-point correction partial sums, resident
            # in VMEM across the K reduction.
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((1, bn), jnp.int32),
        ],
        interpret=interpret,
    )(x_q, w_q, xs, xz, ws, wz)
