"""Pallas TPU kernel: the whole quantized-MLP actor forward in ONE pass.

The per-layer ActorQ hot path (``rl.actorq.quantized_mlp_apply``) pays, for
every dense layer: one GEMM kernel dispatch, an fp32 activation round trip
through HBM, a full dynamic min/max reduction over that activation, and a
re-quantize before the next GEMM.  This kernel runs the *entire* MLP forward
— every layer's W8A8 (or W4A8) GEMM with int32 accumulation — inside one
``pallas_call``:

* the grid iterates over batch-row blocks only; every layer's weight block
  is resident in VMEM for the whole pass,
* each hidden layer ends in a fused bias + ReLU + **requantize-to-int8**
  epilogue using *static* activation scales (``QMLPLayer.x_delta`` /
  ``x_zero``, calibrated once per sync — ``core.affine.calibration_params``)
  so inter-layer activations stay int8 in VMEM and never touch fp32 HBM,
* only the head layer dequantizes, writing the fp32 logits/q/mu output.

Sub-8-bit weights (``bits <= 4``) are stored two int4 codes per int8 byte
along the contraction axis (``core.affine.pack_int4``) and unpacked
in-kernel — W4A8: half the actor-cache bytes, same A8 activation protocol.

The float epilogue mirrors ``ref.int8_matmul_ref`` op for op (scale product,
then correction multiply, then bias add), so with static scales equal to the
dynamic ones the fused path is *bitwise* identical to the per-layer path —
the anchor contract tested in ``tests/test_fused_qmlp.py``.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import affine


class QMLPLayer(NamedTuple):
    """One fused-MLP layer: kernel-layout weights + static input quant.

    ``codes`` is ``(K, N)`` int8, or ``(ceil(K/2), N)`` packed pairs when
    ``bits <= 4``; ``col_scale``/``col_zero`` are the per-column dequant
    arrays hoisted at pack time; ``x_delta``/``x_zero`` are the *static*
    affine params (signed-storage form) of this layer's input activation —
    layer 0's pair quantizes the observation, layer ``i+1``'s pair is the
    requant target of hidden layer ``i``'s epilogue.

    ``bits`` and ``k`` (the true contraction length) are static pytree aux
    so jitted callers re-trace on structure, not on values.
    """
    codes: jnp.ndarray
    col_scale: jnp.ndarray    # (N,) f32
    col_zero: jnp.ndarray     # (N,) f32
    bias: jnp.ndarray         # (N,) f32
    x_delta: jnp.ndarray      # () f32 static input-activation scale
    x_zero: jnp.ndarray       # () f32 signed-storage zero point
    bits: int = 8
    k: int = 0


jax.tree_util.register_pytree_node(
    QMLPLayer,
    lambda p: ((p.codes, p.col_scale, p.col_zero, p.bias, p.x_delta,
                p.x_zero), (p.bits, p.k)),
    lambda aux, xs: QMLPLayer(*xs, aux[0], aux[1]))


def _layer_forward(h: jnp.ndarray, w: jnp.ndarray, col_scale, col_zero,
                   bias, x_delta, x_zero, k: int) -> jnp.ndarray:
    """int32 GEMM + zero-point correction + dequant epilogue for one layer.

    ``h`` is (bm, k) int32 codes, ``w`` (k, n) int32 codes; returns the
    fp32 (bm, n) pre-activation.  Float op order matches
    ``ref.int8_matmul_ref`` exactly (the bitwise-anchor contract).
    """
    acc = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    sum_h = jnp.sum(h, axis=1, keepdims=True)            # (bm, 1)
    sum_w = jnp.sum(w, axis=0, keepdims=True)            # (1, n)
    xz = x_zero.astype(jnp.int32)
    wz = col_zero.astype(jnp.int32)                      # (1, n)
    corr = acc - xz * sum_w - wz * sum_h + k * xz * wz
    y = x_delta * col_scale * corr.astype(jnp.float32)
    return y + bias


def _fused_qmlp_kernel(*refs, metas: Tuple[Tuple[int, int], ...]):
    """``refs`` = x, then 6 refs per layer (codes, col_scale, col_zero,
    bias, x_delta, x_zero), then the output; ``metas`` = static
    ``(bits, k)`` per layer."""
    x_ref, o_ref = refs[0], refs[-1]
    h = x_ref[...].astype(jnp.int32)
    n_layers = len(metas)
    for i, (bits, k) in enumerate(metas):
        c_ref, ws_ref, wz_ref, b_ref, xd_ref, xz_ref = refs[1 + 6 * i:
                                                            7 + 6 * i]
        w = c_ref[...]
        if bits <= 4:
            w = affine.unpack_int4(w, k)                 # in-kernel unpack
        y = _layer_forward(h, w.astype(jnp.int32), ws_ref[0, :][None, :],
                           wz_ref[0, :][None, :], b_ref[0, :][None, :],
                           xd_ref[0, 0], xz_ref[0, 0], k)
        if i + 1 < n_layers:
            # fused epilogue: ReLU + static requant — the activation stays
            # int8-coded (held int32 for the next MXU feed) in VMEM
            y = jnp.maximum(y, 0.0)
            nxd_ref, nxz_ref = refs[1 + 6 * (i + 1) + 4:1 + 6 * (i + 1) + 6]
            q = jnp.round(y / nxd_ref[0, 0]) + nxz_ref[0, 0]
            h = jnp.clip(q, -128.0, 127.0).astype(jnp.int32)
        else:
            o_ref[...] = y.astype(o_ref.dtype)


def fused_qmlp_pallas(x_q: jnp.ndarray, layers: Tuple[QMLPLayer, ...], *,
                      block_m: int = 256, out_dtype: Any = jnp.float32,
                      interpret: bool = False) -> jnp.ndarray:
    """Single-pass MLP forward over int8 input codes.

    ``x_q`` is ``(M, K0)`` int8, already quantized with layer 0's static
    params (``kernels.ops.fused_qmlp`` does this).  The grid blocks M only;
    all weights ride as full-array VMEM blocks (actor MLPs are Table-5
    sized — a 3x256 policy is ~200KB packed, far under the VMEM budget).
    Rows past M in the final block compute on padding and are discarded by
    the output masking pallas applies.
    """
    m, k0 = x_q.shape
    if not layers:
        raise ValueError("fused_qmlp needs at least one layer")
    if layers[0].k != k0:
        raise ValueError(f"layer 0 expects K={layers[0].k}, x has {k0}")
    n_out = layers[-1].codes.shape[-1]
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)

    operands = [x_q]
    in_specs = [pl.BlockSpec((bm, k0), lambda i: (i, 0))]
    metas = []
    for layer in layers:
        metas.append((layer.bits, layer.k))
        n = layer.codes.shape[-1]
        full = layer.codes.shape
        for arr, spec in (
                (layer.codes, pl.BlockSpec(full, lambda i: (0, 0))),
                (layer.col_scale.reshape(1, n),
                 pl.BlockSpec((1, n), lambda i: (0, 0))),
                (layer.col_zero.reshape(1, n),
                 pl.BlockSpec((1, n), lambda i: (0, 0))),
                (layer.bias.reshape(1, n).astype(jnp.float32),
                 pl.BlockSpec((1, n), lambda i: (0, 0))),
                (jnp.asarray(layer.x_delta, jnp.float32).reshape(1, 1),
                 pl.BlockSpec((1, 1), lambda i: (0, 0))),
                (jnp.asarray(layer.x_zero, jnp.float32).reshape(1, 1),
                 pl.BlockSpec((1, 1), lambda i: (0, 0)))):
            operands.append(arr)
            in_specs.append(spec)

    return pl.pallas_call(
        functools.partial(_fused_qmlp_kernel, metas=tuple(metas)),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), out_dtype),
        interpret=interpret,
    )(*operands)
