"""Pallas TPU kernel: fused affine quantize-dequantize (fake quantization).

This is the inner loop of both QAT (executed on every weight/activation tensor
every step) and PTQ evaluation. On TPU the win over the naive jnp chain
(div, round, add, clip, sub, mul — six HBM-bound elementwise passes when not
fused) is a single HBM read + write per element with all arithmetic in VREGs.

Tiling: 2D tiles of (block_rows, block_cols); the last dim is kept a multiple
of 128 (lane width) and rows a multiple of 8 (sublane, f32) by the wrapper.
The quantizer range (vmin/vmax) is a precomputed scalar pair — computing it
requires a global reduction which XLA already does optimally, so the kernel
takes (1,1) scalars and fuses only the elementwise map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fake_quant_kernel(x_ref, vmin_ref, vmax_ref, o_ref, *, bits: int):
    x = x_ref[...]
    vmin = jnp.minimum(vmin_ref[0, 0], 0.0)
    vmax = jnp.maximum(vmax_ref[0, 0], 0.0)
    n_levels = jnp.float32(2.0 ** bits)
    delta = (jnp.abs(vmin) + jnp.abs(vmax)) / n_levels
    delta = jnp.where(delta == 0.0, 1.0, delta)
    zero_point = jnp.round(-vmin / delta)
    q = jnp.round(x.astype(jnp.float32) / delta) + zero_point
    q = jnp.clip(q, 0.0, n_levels - 1.0)
    o_ref[...] = (delta * (q - zero_point)).astype(o_ref.dtype)


def fake_quant_pallas(x: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray,
                      bits: int, *, block_rows: int = 256,
                      block_cols: int = 512,
                      interpret: bool = False) -> jnp.ndarray:
    """Fused quantize-dequantize of a 2D tensor with a given scalar range."""
    assert x.ndim == 2, "wrapper reshapes to 2D"
    rows, cols = x.shape
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    vmin2 = jnp.asarray(vmin, jnp.float32).reshape(1, 1)
    vmax2 = jnp.asarray(vmax, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_fake_quant_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x, vmin2, vmax2)
