"""Pallas TPU kernels for the framework compute hot spots.

Layout per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec
implementation, ``ops.py`` the jit dispatching wrapper, ``ref.py`` the
pure-jnp oracle the tests assert against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
