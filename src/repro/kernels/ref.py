"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against
(``np.testing.assert_allclose(kernel(x), ref(x))`` over shape/dtype sweeps),
and double as the CPU/compile-path implementations used by the models when the
Pallas hot path is disabled (e.g. during the multi-pod dry-run, which lowers
for 512 host devices).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import affine


# ---------------------------------------------------------------------------
# fake_quant — fused quantize-dequantize (paper's Q_n / D maps)
# ---------------------------------------------------------------------------

def fake_quant_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-tensor affine quantize-dequantize with the paper's formula."""
    return affine.ptq_tensor(x, bits)


def fake_quant_with_range_ref(x: jnp.ndarray, vmin: jnp.ndarray,
                              vmax: jnp.ndarray, bits: int) -> jnp.ndarray:
    p = affine.affine_params_from_range(vmin, vmax, bits)
    return affine.quantize_dequantize(x, p)


# ---------------------------------------------------------------------------
# int8_matmul — W8A8 GEMM with int32 accumulation + affine dequant
# ---------------------------------------------------------------------------

def int8_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray,
                    x_scale: jnp.ndarray, w_scale: jnp.ndarray,
                    x_zero: jnp.ndarray, w_zero: jnp.ndarray,
                    out_dtype=jnp.float32) -> jnp.ndarray:
    """Dequantized product of int8 operands.

    x_q: (M, K) int8 codes with scalar (per-tensor) x_scale / x_zero.
    w_q: (K, N) int8 codes with per-column (per-output-channel) w_scale /
         w_zero of shape (N,) — the paper's per-axis scheme.

    result = (x_scale * (x_q - x_zero)) @ (w_scale * (w_q - w_zero))
           = x_scale * w_scale * [ x_q@w_q - x_zero*sum_k(w_q)
                                   - w_zero*sum_k(x_q) + K*x_zero*w_zero ]
    computed in int32 to mirror the MXU integer path.
    """
    k = x_q.shape[-1]
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    sum_w = jnp.sum(w_q.astype(jnp.int32), axis=0)          # (N,)
    sum_x = jnp.sum(x_q.astype(jnp.int32), axis=-1, keepdims=True)  # (M,1)
    corr = (acc
            - x_zero.astype(jnp.int32) * sum_w[None, :]
            - w_zero.astype(jnp.int32)[None, :] * sum_x
            + k * x_zero.astype(jnp.int32) * w_zero.astype(jnp.int32)[None, :])
    return (x_scale * w_scale[None, :] * corr.astype(jnp.float32)
            ).astype(out_dtype)


def fused_qmlp_ref(x_q: jnp.ndarray, layers) -> jnp.ndarray:
    """Oracle for the single-pass fused quantized MLP (``fused_qmlp.py``).

    ``x_q``: (M, K0) int8 codes statically quantized with layer 0's params;
    ``layers``: tuple of ``fused_qmlp.QMLPLayer``.  Each layer reuses
    ``int8_matmul_ref`` verbatim — the same float op order as the per-layer
    path — then applies the fused bias + ReLU + static-requant epilogue
    (``affine.quantize_with_params``), so with static scales equal to the
    dynamic ones this is bitwise the per-layer ``quantized_mlp_apply``.
    """
    h = x_q
    n_layers = len(layers)
    for i, layer in enumerate(layers):
        w = layer.codes
        if layer.bits <= 4:
            w = affine.unpack_int4(w, layer.k)
        y = int8_matmul_ref(h, w, layer.x_delta, layer.col_scale,
                            layer.x_zero, layer.col_zero)
        y = y + layer.bias
        if i + 1 < n_layers:
            nxt = layers[i + 1]
            h = affine.quantize_with_params(
                jax.nn.relu(y),
                affine.AffineParams(nxt.x_delta, nxt.x_zero, bits=8))
        else:
            return y


def quantized_dense_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                        w_scale: jnp.ndarray, w_zero: jnp.ndarray,
                        out_dtype=jnp.float32) -> jnp.ndarray:
    """Weight-only int8 dense (activations fp): x @ dequant(w)."""
    w = (w_scale[None, :] * (w_q.astype(jnp.float32) - w_zero[None, :]))
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)


# ---------------------------------------------------------------------------
# flash_attention — blockwise online-softmax attention
# ---------------------------------------------------------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            *, causal: bool = True, window: Optional[int] = None,
            softcap: Optional[float] = None,
            scale: Optional[float] = None) -> jnp.ndarray:
    """Dense reference attention.

    q: (S, D), k/v: (T, D); single head (tests vmap over heads/batch).
    window: sliding-window size (attend to keys in (i-window, i]).
    softcap: gemma2-style tanh logit soft-capping.
    """
    s, d = q.shape
    t = k.shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(s)[:, None] + (t - s)   # align ends (decode-friendly)
    k_pos = jnp.arange(t)[None, :]
    mask = k_pos <= q_pos if causal else jnp.ones((s, t), bool)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return (probs @ v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8_cache_attention — decode attention over a quantized KV cache
# ---------------------------------------------------------------------------

def int8_cache_decode_ref(q, k_codes, k_scale, v_codes, v_scale, pos,
                          window=None):
    """q (G, Dh); codes (T, Dh) int8 + (T,1) scales; one decode position."""
    k = k_codes.astype(jnp.float32) * k_scale
    v = v_codes.astype(jnp.float32) * v_scale
    t = k.shape[0]
    s = (q.astype(jnp.float32) @ k.T) * (q.shape[-1] ** -0.5)
    idx = jnp.arange(t)[None, :]
    valid = idx <= pos
    if window is not None:
        valid = valid & (idx > pos - window)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v).astype(q.dtype)
