"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

MUST be the very first lines — before any other import (jax locks the device
count on first init):
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ---------------------------------------------------------------------------
import argparse        # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402,F401  (init under the fake-device flags)

from repro.configs import base as cfgs          # noqa: E402
from repro.launch import mesh as mesh_lib       # noqa: E402
from repro.launch import steps as steps_lib     # noqa: E402
from repro.launch.hlo_analysis import collective_stats, summarize_memory  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            out_dir: str) -> dict:
    cfg = cfgs.get(arch)
    shape = cfgs.INPUT_SHAPES[shape_name]
    cfg, variant = steps_lib.resolve_arch_for_shape(cfg, shape)

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        lowered, kind = steps_lib.lower_step(cfg, shape, mesh,
                                             multi_pod=multi_pod)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        memory = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # older jax: one dict per executable
            cost = cost[0] if cost else {}
        # Post-SPMD HLO: collectives are explicit here (pre-partitioning
        # stablehlo has none); trip-count-weighted per hlo_analysis.py.
        coll = collective_stats(compiled.as_text())

    mem = summarize_memory(memory)
    n_dev = 512 if multi_pod else 256
    record = {
        "arch": arch, "shape": shape_name, "kind": kind, "variant": variant,
        "multi_pod": multi_pod, "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll["total"],
        "collective_breakdown": coll,
        "memory": mem,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "tokens": shape.tokens if kind != "decode" else shape.global_batch,
    }
    print(f"[dryrun] {arch} x {shape_name} ({'2-pod' if multi_pod else '1-pod'}"
          f", {kind}, {variant}): lower {t_lower:.0f}s compile "
          f"{t_compile:.0f}s")
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis: flops={record['flops']:.3e} "
          f"bytes={record['bytes_accessed']:.3e} "
          f"collective_bytes={coll['total']:.3e}")

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'2pod' if multi_pod else '1pod'}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch name or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = cfgs.names() if args.arch == "all" else [args.arch]
    shapes = list(cfgs.INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, multi_pod=mp, out_dir=args.out)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape} "
                          f"({'2pod' if mp else '1pod'}): {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nAll dry-runs compiled successfully.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
