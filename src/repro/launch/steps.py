"""pjit-able train / prefill / serve steps + their input specs and shardings.

This is the glue between the model substrate and the production mesh:

* ``input_specs(cfg, shape)``       — ShapeDtypeStruct stand-ins for every
                                      input of the step (no allocation).
* ``input_shardings(...)``          — matching PartitionSpec trees.
* ``make_train_step(cfg)``          — loss -> grads -> Adam update, with
                                      mixed precision and optional QAT state.
* ``make_prefill_step(cfg)``        — full-sequence forward (last logits).
* ``make_serve_step(cfg)``          — one decode token through KV caches.

Activation sharding policy (see DESIGN.md §6): batch over the data axes;
sequence over 'model' between blocks for train/prefill (sequence
parallelism — bounds the lax.scan carry memory at 40-100 layers); decode
activations batch-only.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import base as cfgs
from repro.core import mixed_precision as mp_lib
from repro.models import transformer
from repro.optim import adam as adam_lib

PyTree = Any


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct only — never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg: cfgs.ArchConfig, shape: cfgs.InputShape
                ) -> Dict[str, Any]:
    """Model inputs for the given input shape, as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": tok((b, s), jnp.int32),
                 "labels": tok((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        specs = {"tokens": tok((b, s), jnp.int32)}
    else:  # decode
        specs = {"tokens": tok((b, 1), jnp.int32)}
    if cfg.cross_attn or cfg.encoder_layers:
        dtype = jnp.dtype(cfg.mp.compute_dtype)
        specs["encoder_out"] = tok((b, cfg.encoder_seq, cfg.d_model), dtype)
    return specs


def _tree_sds(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_sds(cfg: cfgs.ArchConfig, *, dtype=None) -> PyTree:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation).

    Training carries fp32 (or cfg.mp.param_dtype) master weights; serving /
    prefill carries compute-dtype (bf16) weights — inference has no master
    copy (fp32 weights doubled decode residency, §Perf C4).
    """
    dtype = dtype or jnp.dtype(cfg.mp.param_dtype)
    return jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0),
                                        dtype=dtype))


def opt_sds(cfg: cfgs.ArchConfig, adam_cfg: adam_lib.AdamConfig) -> PyTree:
    params = param_sds(cfg)
    return jax.eval_shape(lambda p: adam_lib.adam_init(p, adam_cfg), params)


def cache_sds(cfg: cfgs.ArchConfig, batch: int, seq_len: int) -> PyTree:
    return jax.eval_shape(
        lambda: transformer.init_caches(cfg, batch, seq_len))


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def batch_shardings(cfg: cfgs.ArchConfig, shape: cfgs.InputShape,
                    mesh: Mesh, multi_pod: bool) -> PyTree:
    data = ("pod", "data") if multi_pod else ("data",)
    dp = 32 if multi_pod else 16
    b = shape.global_batch
    # NB: the axis tuple is ONE PartitionSpec entry (batch dim sharded over
    # both pod and data), not multiple entries.
    bspec = data if b % dp == 0 else None

    def ns(*spec):
        return NamedSharding(mesh, PartitionSpec(*spec))

    specs = {"tokens": ns(bspec, None)}
    if shape.kind == "train":
        specs["labels"] = ns(bspec, None)
    if cfg.cross_attn or cfg.encoder_layers:
        specs["encoder_out"] = ns(bspec, None, None)
    return specs


def param_shardings(cfg: cfgs.ArchConfig, mesh: Mesh,
                    multi_pod: bool) -> PyTree:
    pspecs = transformer.partition_specs(cfg, multi_pod=multi_pod)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def opt_shardings(cfg: cfgs.ArchConfig, adam_cfg: adam_lib.AdamConfig,
                  mesh: Mesh, multi_pod: bool) -> Any:
    """AdamState shardings. fp32 moments mirror params; 8-bit state shards
    its flat code/scale vectors over the data axes when divisible."""
    pspecs = transformer.partition_specs(cfg, multi_pod=multi_pod)
    p_ns = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    if not adam_cfg.eightbit:
        return adam_lib.AdamState(
            step=NamedSharding(mesh, PartitionSpec()), m=p_ns, v=p_ns)

    # Shape-preserving 8-bit moments: codes inherit the exact parameter spec;
    # scales inherit it minus the last axis (their last dim is 1/256th of the
    # param's and usually not divisible by the mesh axis — they are tiny).
    params = param_sds(cfg)

    def one(p_leaf, pspec: PartitionSpec):
        sspec = PartitionSpec(*pspec[:-1], None) if len(pspec) else pspec
        return adam_lib.BlockQuantized(
            codes=NamedSharding(mesh, pspec),
            scales=NamedSharding(mesh, sspec), shape=p_leaf.shape)

    moments = jax.tree_util.tree_map(
        one, params, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return adam_lib.AdamState(step=NamedSharding(mesh, PartitionSpec()),
                              m=moments, v=moments)


def cache_shardings(cfg: cfgs.ArchConfig, shape: cfgs.InputShape,
                    mesh: Mesh, multi_pod: bool) -> PyTree:
    """KV caches: batch over data (seq over data when batch=1), head_dim
    over 'model' when divisible (flash-decoding-style split)."""
    data = ("pod", "data") if multi_pod else ("data",)
    dp = 32 if multi_pod else 16
    b = shape.global_batch
    batch_ok = b % dp == 0
    template = cache_sds(cfg, b, shape.seq_len)

    def one(leaf):
        # KVCache k/v[/scales]: (L, B, T, KV, Dh) or (L, B, T, KV, 1).
        # The context dim T shards over 'model' (flash-decoding style): the
        # q·k contraction reduces over T so each model shard scores its own
        # context slice and only the (B,H,1,T)-scores ever cross the ICI.
        # Sharding Dh instead forces a full-cache all-gather per step
        # (measured 45 GB/step on gemma2-9b decode_32k; §Perf C3).
        if leaf.ndim == 5:
            L, B, T, KV, Dh = leaf.shape
            spec = [None, None, None, None, None]
            if batch_ok:
                spec[1] = data
            elif T % dp == 0:
                spec[2] = data
            if T % 16 == 0 and spec[2] is None:
                spec[2] = "model"
            elif Dh % 16 == 0 and Dh > 1:
                spec[4] = "model"
            return NamedSharding(mesh, PartitionSpec(*spec))
        # Recurrent state (L, B, ...) / positions (L, T)
        if leaf.ndim >= 2 and batch_ok and leaf.shape[1] == b:
            return NamedSharding(mesh,
                                 PartitionSpec(None, data,
                                               *([None] * (leaf.ndim - 2))))
        return NamedSharding(mesh, PartitionSpec(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map(one, template)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: cfgs.ArchConfig,
                    adam_cfg: Optional[adam_lib.AdamConfig] = None,
                    multi_pod: bool = False):
    adam_cfg = adam_cfg or adam_lib.AdamConfig(eightbit=cfg.optimizer_8bit)
    grad_pspecs = transformer.partition_specs(cfg, multi_pod=multi_pod)

    def _constrain_grads(grads):
        # Pin gradient shardings to the parameter layout. Without this the
        # scan-transpose accumulators for stacked layer grads can end up
        # replicated (observed: ~300 GB/device for grok's stacked MoE grads).
        from repro.models import common as _common
        return jax.tree_util.tree_map(
            lambda g, s: _common.with_constraint(g, s), grads, grad_pspecs)

    def train_step(params, opt_state, batch, qat_collection):
        step = opt_state.step

        def loss_of(p):
            p_c = mp_lib.to_compute(p, cfg.mp)
            return transformer.loss_fn(
                cfg, p_c, batch, qat_collection=qat_collection, step=step,
                multi_pod=multi_pod)

        if cfg.grad_accum > 1:
            a = cfg.grad_accum

            def micro(batch_i):
                def lf(p):
                    p_c = mp_lib.to_compute(p, cfg.mp)
                    return transformer.loss_fn(
                        cfg, p_c, batch_i, qat_collection=qat_collection,
                        step=step, multi_pod=multi_pod)
                return jax.value_and_grad(lf, has_aux=True)(params)

            def split(x):
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])
            micro_batches = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, batch_i):
                (loss_a, metrics_a), grads_a = carry
                (loss_i, metrics_i), grads_i = micro(batch_i)
                grads = jax.tree_util.tree_map(jnp.add, grads_a, grads_i)
                return ((loss_a + loss_i, metrics_i), grads), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            init = ((jnp.zeros(()),
                     {"ce_loss": jnp.zeros(()), "aux_loss": jnp.zeros(()),
                      "qat_collection": qat_collection}), zero_g)
            ((loss, metrics), grads), _ = jax.lax.scan(
                acc_fn, init, micro_batches)
            loss = loss / a
            grads = jax.tree_util.tree_map(lambda g: g / a, grads)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params)

        grads = _constrain_grads(grads)
        new_params, new_opt, stats = adam_lib.adam_update(
            grads, opt_state, params, adam_cfg)
        out_metrics = {"loss": loss, "ce_loss": metrics["ce_loss"],
                       "aux_loss": metrics["aux_loss"], **stats}
        return new_params, new_opt, metrics["qat_collection"], out_metrics

    return train_step, adam_cfg


def make_prefill_step(cfg: cfgs.ArchConfig, multi_pod: bool = False):
    def prefill_step(params, batch):
        p_c = mp_lib.to_compute(params, cfg.mp)
        return transformer.prefill(cfg, p_c, batch["tokens"],
                                   encoder_out=batch.get("encoder_out"),
                                   multi_pod=multi_pod)
    return prefill_step


def make_serve_step(cfg: cfgs.ArchConfig, multi_pod: bool = False):
    def serve_step(params, caches, batch, pos):
        p_c = mp_lib.to_compute(params, cfg.mp)
        logits, new_caches = transformer.decode_step(
            cfg, p_c, batch["tokens"], caches, pos,
            encoder_out=batch.get("encoder_out"), multi_pod=multi_pod)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, new_caches
    return serve_step


# ---------------------------------------------------------------------------
# Lowering helper (shared by dryrun and the real launchers)
# ---------------------------------------------------------------------------

def lower_step(cfg: cfgs.ArchConfig, shape: cfgs.InputShape, mesh: Mesh,
               *, multi_pod: bool = False,
               adam_cfg: Optional[adam_lib.AdamConfig] = None):
    """Build + .lower() the right step for (arch, input shape) on ``mesh``.

    Returns (lowered, kind). Uses ShapeDtypeStructs exclusively.
    """
    replicated = NamedSharding(mesh, PartitionSpec())
    batch_sds = input_specs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, mesh, multi_pod)
    infer_dtype = (jnp.dtype(cfg.mp.compute_dtype)
                   if shape.kind != "train" else None)
    p_sds = param_sds(cfg, dtype=infer_dtype)
    p_sh = param_shardings(cfg, mesh, multi_pod)

    if shape.kind == "train":
        train_step, adam_cfg = make_train_step(cfg, adam_cfg,
                                               multi_pod=multi_pod)
        o_sds = opt_sds(cfg, adam_cfg)
        o_sh = opt_shardings(cfg, adam_cfg, mesh, multi_pod)
        qat_coll = (transformer.init_qat_collection(cfg)
                    if cfg.quant.is_qat else {})
        qat_sds = _tree_sds(qat_coll)
        qat_sh = jax.tree_util.tree_map(lambda _: replicated, qat_sds)
        jitted = jax.jit(train_step,
                         in_shardings=(p_sh, o_sh, batch_sh, qat_sh),
                         out_shardings=(p_sh, o_sh, qat_sh, None),
                         donate_argnums=(0, 1, 3))
        return jitted.lower(p_sds, o_sds, batch_sds, qat_sds), "train"

    if shape.kind == "prefill":
        prefill_step = make_prefill_step(cfg, multi_pod=multi_pod)
        jitted = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh))
        return jitted.lower(p_sds, batch_sds), "prefill"

    # decode
    serve_step = make_serve_step(cfg, multi_pod=multi_pod)
    c_sds = cache_sds(cfg, shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(cfg, shape, mesh, multi_pod)
    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, batch_sh, replicated),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(p_sds, c_sds, batch_sds, pos_sds), "decode"


def resolve_arch_for_shape(cfg: cfgs.ArchConfig, shape: cfgs.InputShape
                           ) -> Tuple[cfgs.ArchConfig, str]:
    """Shape-specific config policy.

    * decode shapes serve with TP param sharding — FSDP would re-all-gather
      the full weights every decoded token (measured: 53.5 GB/step on
      gemma2-9b decode_32k; §Perf C2). Weights fit per-device under TP for
      every assigned arch except grok/llama-90b, which keep FSDP (documented).
    * long_500k on pure full-attention archs runs the SWA *variant*
      (window 4096) per the assignment.
    """
    import dataclasses
    variant = "native"
    if shape.name == "long_500k" and not cfg.supports_long_500k:
        cfg = dataclasses.replace(cfg, long_context_window=4096)
        variant = "swa-variant"
    if shape.kind == "decode" and cfg.sharding == "fsdp" \
            and cfg.n_params() * 2 / 16 < 12e9:  # bf16 weights fit under TP
        cfg = dataclasses.replace(cfg, sharding="tp")
    return cfg, variant
