"""Analytic FLOP / HBM-traffic model per (arch × input shape).

Used as the roofline's compute/memory terms because XLA's
``cost_analysis()`` counts ``while`` bodies once (see hlo_analysis.py).
The dry-run additionally measures a *depth probe* (1-unit vs 2-unit unrolled
programs) whose delta gives exact per-unit HLO numbers for cross-checking.

Conventions:
* FLOPs are global (whole step, all devices).
* Training matmul FLOPs = 3x forward (fwd + 2x bwd) + 1x forward for the
  per-unit rematerialization => 4x forward on in-scan compute, 3x on the
  embedding/head (not rematerialized).
* HBM bytes are per-device per-step, the sum of parameter traffic
  (stream weights once per pass: fwd, bwd, remat), gradient/optimizer
  traffic, activation traffic, and (decode) KV-cache reads.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import base as cfgs


def _unit_counts(cfg: cfgs.ArchConfig) -> Dict[str, float]:
    kinds = list(cfg.pattern) * cfg.pattern_repeats \
        + list(cfg.pattern_remainder)
    out: Dict[str, float] = {}
    for k in kinds:
        out[k] = out.get(k, 0) + 1
    return out


def forward_flops(cfg: cfgs.ArchConfig, shape: cfgs.InputShape,
                  decode: bool = False) -> float:
    """Forward-pass FLOPs for one step (global)."""
    b = shape.global_batch
    s = 1 if decode else shape.seq_len
    ctx = shape.seq_len if decode else shape.seq_len
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    tokens = b * s

    def matmul(m, k, n):
        return 2.0 * m * k * n

    total = matmul(tokens, d, v)  # lm head
    counts = _unit_counts(cfg)
    for kind, n_blocks in counts.items():
        # attention projections
        attn_proj = (matmul(tokens, d, nh * hd)
                     + 2 * matmul(tokens, d, nkv * hd)
                     + matmul(tokens, nh * hd, d))
        local = kind in (cfgs.ATTN_LOCAL, cfgs.MOE_LOCAL)
        window = (cfg.window if local else cfg.long_context_window) or ctx
        if decode:
            ctx_eff = min(ctx, window)
            attn_core = 2 * matmul(b * nh, hd, ctx_eff)
        else:
            ctx_eff = min(ctx, window)
            # causal: each query sees ~min(pos, window) keys; average ~W/2
            # for S >> W, S/2 otherwise.
            avg_keys = ctx_eff / 2 if window >= s else \
                (window if window < s else s / 2)
            attn_core = 2 * 2.0 * tokens * nh * hd * avg_keys

        ffn = 0.0
        moe_overhead = 0.0
        if kind in (cfgs.ATTN, cfgs.ATTN_LOCAL):
            ffn = 3 * matmul(tokens, d, f)
            blk = attn_proj + attn_core + ffn
        elif kind in (cfgs.MOE, cfgs.MOE_LOCAL):
            ffn = cfg.moe_top_k * 3 * matmul(tokens, d, f) \
                * cfg.capacity_factor
            # dispatch/combine einsums: tokens x (E*C) x d, twice
            group = min(512, tokens)
            cap = max(int(cfg.capacity_factor * cfg.moe_top_k * group
                          / cfg.n_experts), cfg.moe_top_k)
            moe_overhead = 2 * 2.0 * tokens * cfg.n_experts * cap * d
            blk = attn_proj + attn_core + ffn + moe_overhead
        elif kind == cfgs.CROSS:
            enc = cfg.encoder_seq
            cross_core = 2 * matmul(tokens * nh, hd, enc)
            cross_proj = (matmul(tokens, d, nh * hd)
                          + 2 * matmul(b * enc, d, nkv * hd)
                          + matmul(tokens, nh * hd, d))
            ffn = 3 * matmul(tokens, d, f)
            blk = attn_proj + attn_core + cross_proj + cross_core + ffn
        elif kind == cfgs.RGLRU:
            # wx, wg, gates, wo ~ 5 d^2 matmuls + elementwise scan
            blk = 5 * matmul(tokens, d, d) + 10.0 * tokens * d \
                + 3 * matmul(tokens, d, f)
        elif kind in (cfgs.MLSTM, cfgs.SLSTM):
            di = nh * hd
            proj = 5 * matmul(tokens, d, di)
            core = (2.0 * tokens * nh * hd * hd * 3 if kind == cfgs.MLSTM
                    else 8.0 * tokens * di)
            blk = proj + core
        else:
            blk = 0.0
        total += n_blocks * blk

    if cfg.encoder_layers:
        enc_tokens = b * cfg.encoder_seq
        total += cfg.encoder_layers * (
            4 * matmul(enc_tokens, d, nh * hd) + 3 * matmul(enc_tokens, d, f)
            + 2 * 2.0 * enc_tokens * nh * hd * cfg.encoder_seq / 2)
    return total


def step_flops(cfg: cfgs.ArchConfig, shape: cfgs.InputShape) -> float:
    """Total FLOPs for the lowered step (train: fwd+bwd+remat)."""
    if shape.kind == "train":
        return 4.0 * forward_flops(cfg, shape)  # 1 fwd + 2 bwd + 1 remat
    if shape.kind == "prefill":
        return forward_flops(cfg, shape)
    return forward_flops(cfg, shape, decode=True)


def model_flops(cfg: cfgs.ArchConfig, shape: cfgs.InputShape) -> float:
    """The 6·N·D (train) / 2·N·D (inference) convention (active params)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch


def hbm_bytes_per_device(cfg: cfgs.ArchConfig, shape: cfgs.InputShape,
                         devices: int = 256, *,
                         eightbit_opt: bool = False) -> float:
    """Approximate per-device HBM traffic for one step."""
    n = cfg.n_params()
    n_active = cfg.n_active_params()
    d = cfg.d_model
    depth = cfg.n_layers
    if shape.kind == "train":
        # weights bf16 streamed fwd + bwd + remat; grads f32 written+read;
        # master f32 read+write; opt moments read+write.
        w = n / devices
        opt_bytes = (2 * 2 * w) if eightbit_opt else (2 * 8 * w)
        param_traffic = 3 * 2 * w + 2 * 4 * w + 2 * 4 * w + opt_bytes
        tokens_dev = shape.tokens / min(devices, 256)
        act_traffic = tokens_dev * d * 2 * depth * 8  # ~8 tensors/block rw
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        w = 2 * n_active / devices
        tokens_dev = shape.tokens / devices
        return w + tokens_dev * d * 2 * depth * 4
    # decode: weights once per step + cache read
    w = 2 * n_active / devices
    cache_bytes = 1 if cfg.quant.int8_kv_cache else 2
    window = cfg.long_context_window or cfg.window
    kinds = _unit_counts(cfg)
    cache = 0.0
    for kind, cnt in kinds.items():
        if kind in (cfgs.ATTN, cfgs.MOE, cfgs.CROSS):
            ctx = min(shape.seq_len, cfg.long_context_window or
                      shape.seq_len)
        elif kind in (cfgs.ATTN_LOCAL, cfgs.MOE_LOCAL):
            ctx = min(shape.seq_len, window or shape.seq_len)
        else:
            ctx = 0
        cache += cnt * shape.global_batch * ctx * cfg.n_kv_heads \
            * cfg.hd * 2 * cache_bytes
    return w + cache / devices
