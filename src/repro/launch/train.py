"""Training launcher.

Two entry points, matching the paper's two workload kinds:

* ``--mode rl``  — the QuaRL study itself: train an RL policy with any
  algorithm/env/quantization mode (this is what the benchmarks drive).
* ``--mode lm``  — the framework's LM trainer: any assigned architecture,
  on the local host mesh (CPU smoke) or the production mesh, with mixed
  precision, QAT, 8-bit Adam, checkpointing, and the synthetic data
  pipeline. On real TPU pods the same script runs under
  ``jax.distributed.initialize()``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode rl --algo ppo \\
      --env cartpole --quant qat8:delay=100 --iterations 300
  PYTHONPATH=src python -m repro.launch.train --mode lm \\
      --arch xlstm-125m --reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("rl", "lm"), default="rl")
    # rl
    ap.add_argument("--algo", default="ppo")
    ap.add_argument("--env", default="cartpole")
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--quant", default="none")
    ap.add_argument("--seed", type=int, default=0)
    # lm
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized variant")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    # both modes: fault tolerance (repro.checkpoint)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest committed checkpoint in "
                         "--ckpt-dir (rl mode: bitwise-identical to the "
                         "uninterrupted run)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="checkpoints retained in --ckpt-dir (<=0: all)")
    # rl mode: self-healing supervisor (repro.resilience)
    ap.add_argument("--fault-plan", default=None, metavar="SEED:SPEC",
                    help="run under the resilience supervisor with this "
                         "deterministic fault plan, e.g. "
                         "'7:bitflip_push@4,straggler@6:delay_s=0.2' "
                         "(see docs/resilience.md)")
    ap.add_argument("--supervised", action="store_true",
                    help="run under the resilience supervisor without "
                         "injected faults (retry/rollback on real ones)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="supervisor resume-retries per rollback level")
    ap.add_argument("--rollback", type=int, default=1,
                    help="supervisor rollback-to-previous-checkpoint "
                         "escalations after retries exhaust")
    args = ap.parse_args(argv)

    if args.mode == "rl":
        return run_rl(args)
    return run_lm(args)


def run_rl(args) -> int:
    from repro.core.qconfig import QuantConfig
    from repro.rl import loops
    quant = QuantConfig.parse(args.quant)
    kwargs = dict(algo=args.algo, env_name=args.env,
                  iterations=args.iterations, quant=quant, seed=args.seed,
                  record_every=max(args.iterations // 10, 1),
                  checkpoint_dir=args.ckpt_dir,
                  checkpoint_every=args.ckpt_every,
                  resume=args.resume, checkpoint_keep=args.ckpt_keep)
    if args.fault_plan is not None or args.supervised:
        from repro import resilience
        plan = (resilience.FaultPlan.parse(args.fault_plan)
                if args.fault_plan else None)
        sup_cfg = resilience.SupervisorConfig(
            max_retries=args.max_retries, max_rollbacks=args.rollback)
        try:
            res, report = resilience.supervise(kwargs, plan=plan,
                                               config=sup_cfg)
        except resilience.SupervisorAbort as e:
            print(f"[train/rl] {e.report.summary()}")
            return 1
        print(f"[train/rl] {report.summary()}")
    else:
        res = loops.train(**kwargs)
    print(f"[train/rl] {args.algo} on {args.env} quant={quant.label()}: "
          f"eval rewards {['%.1f' % r for r in res.rewards]} "
          f"({res.wall_time_s:.0f}s)")
    return 0


def run_lm(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro import checkpoint as ckpt_lib
    from repro.configs import base as cfgs
    from repro.data import SyntheticLMDataset
    from repro.launch import steps as steps_lib
    from repro.models import transformer
    from repro.optim import adam as adam_lib

    cfg = cfgs.get_reduced(args.arch) if args.reduced else cfgs.get(args.arch)
    adam_cfg = adam_lib.AdamConfig(lr=args.lr, eightbit=cfg.optimizer_8bit)
    train_step, adam_cfg = steps_lib.make_train_step(cfg, adam_cfg)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key,
                                     dtype=jnp.dtype(cfg.mp.param_dtype))
    if args.resume and args.ckpt_dir:
        # params-only warm start (the rl mode has the full bitwise-resume
        # contract; the lm demo loop checkpoints just the params)
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            params = ckpt_lib.load_checkpoint(
                args.ckpt_dir, {"params": params}, step=last)["params"]
            print(f"[train/lm] resumed params from step {last}")
    opt = adam_lib.adam_init(params, adam_cfg)
    qat = transformer.init_qat_collection(cfg) if cfg.quant.is_qat else {}
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train/lm] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"quant={cfg.quant.label()}, mp={cfg.mp.compute_dtype}, "
          f"8bit-adam={adam_cfg.eightbit}")

    data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq,
                              batch=args.batch, seed=args.seed)
    it = data.batches()
    t0 = time.time()
    for step, batch in enumerate(it):
        if step >= args.steps:
            break
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.cross_attn or cfg.encoder_layers:
            jbatch["encoder_out"] = jnp.zeros(
                (args.batch, max(cfg.encoder_seq, 4), cfg.d_model),
                jnp.dtype(cfg.mp.compute_dtype))
        params, opt, qat, metrics = train_step(params, opt, jbatch, qat)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"  step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"grad_norm {float(metrics.get('grad_norm', 0)):.3f}  "
                  f"({time.time() - t0:.0f}s)")
        if args.ckpt_dir and args.ckpt_every and \
                (step + 1) % args.ckpt_every == 0:
            path = ckpt_lib.save_checkpoint(args.ckpt_dir,
                                            {"params": params}, step=step)
            print(f"  saved {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
