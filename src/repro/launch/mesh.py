"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — device counts are locked on first jax init, and only dryrun.py (which
sets XLA_FLAGS before any import) should see 512 fake host devices.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip on v5e)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def n_chips(multi_pod: bool) -> int:
    return 512 if multi_pod else 256
