"""Serving launcher: batched greedy decoding with (optionally int8) weights
and (optionally int8) KV caches — the paper's deployment case study scaled to
the assigned architectures.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \\
      --reduced --batch 4 --prompt-len 32 --new-tokens 32 --quant ptq_int8 \\
      --int8-cache
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    help="none | ptq_fp16 | ptq_int8 (weights)")
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.core import ptq
    from repro.core.qconfig import QuantConfig
    from repro.models import transformer

    cfg = cfgs.get_reduced(args.arch) if args.reduced else cfgs.get(args.arch)
    quant = QuantConfig.parse(args.quant)
    if args.int8_cache:
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, int8_kv_cache=True))

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    fp32_bytes = ptq.tree_nbytes(params)
    if quant.is_ptq:
        params = ptq.ptq_simulate(params, quant)  # simulated int math
    print(f"[serve] {cfg.name} quant={quant.label()} "
          f"int8_cache={cfg.quant.int8_kv_cache} "
          f"params={fp32_bytes / 1e6:.1f}MB fp32"
          + (f" -> {fp32_bytes / 4 / 1e6:.1f}MB int8 packed"
             if quant.mode.value == "ptq_int" else ""))

    total_len = args.prompt_len + args.new_tokens
    caches = transformer.init_caches(cfg, args.batch, total_len,
                                     dtype=jnp.float32)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    enc = None
    if cfg.cross_attn or cfg.encoder_layers:
        enc = jax.random.normal(key, (args.batch, max(cfg.encoder_seq, 4),
                                      cfg.d_model)) * 0.02

    @jax.jit
    def step(params, caches, tok, pos):
        logits, caches = transformer.decode_step(cfg, params, tok, caches,
                                                 pos, encoder_out=enc)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches

    # prefill token-by-token (teacher forcing) then greedy decode
    t0 = time.time()
    out_tokens = []
    tok = tokens[:, :1]
    for pos in range(total_len - 1):
        nxt, caches = step(params, caches, tok, jnp.asarray(pos))
        tok = tokens[:, pos + 1:pos + 2] if pos + 1 < args.prompt_len \
            else nxt[:, None]
        if pos + 1 >= args.prompt_len:
            out_tokens.append(nxt)
    dt = time.time() - t0
    n_gen = args.batch * len(out_tokens)
    print(f"[serve] generated {len(out_tokens)} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({n_gen / dt:.1f} tok/s on CPU)")
    print("        first sequence:", [int(t[0]) for t in out_tokens][:16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
