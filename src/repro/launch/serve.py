"""Serving launcher: LM decoding demo + the RL policy-serving service.

Two modes:

* **LM mode** (default): batched greedy decoding with (optionally int8)
  weights and (optionally int8) KV caches — the paper's deployment case
  study scaled to the assigned architectures.
* **RL mode** (``--rl-env``): trains a policy (any topology —
  ``fused`` / ``actor-learner`` / ``async`` — with fp32/int8/int4 actors,
  uniform or prioritized replay, any kernel backend incl. the native-XLA
  int8 path), then stands up the **continuous-batching policy server**
  (``repro.serving``): concurrent sessions multiplexed onto shape-bucketed
  padded batches against a packed actor cache with zero-copy hot-swap.
  This CLI is a thin veneer — the subsystem lives in
  ``src/repro/serving/``; see ``docs/serving.md``.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \\
      --reduced --batch 4 --prompt-len 32 --new-tokens 32 --quant ptq_int8 \\
      --int8-cache
  PYTHONPATH=src python -m repro.launch.serve --rl-env cartpole \\
      --topology async --actor-backend int4 --calib-batch 64 \\
      --serve-sessions 256 --serve-steps 4
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time


def _serve_policy(args) -> int:
    """ActorQ deployment through the continuous-batching policy server.

    Trains the policy, then pushes it into a ``repro.serving.PolicyServer``
    (``--actor-backend`` fp32 | int8 | int4 packed caches; ``--calib-batch``
    > 0 calibrates static activation scales at push so MLP actors serve
    through the single-pass fused kernel; ``--kernel-backend`` = pallas |
    interpret | ref | xla | auto picks the GEMM path) and drives
    ``--serve-sessions`` concurrent env sessions against it, demonstrating
    a zero-copy hot-swap mid-load.  Reports cache footprint, sustained
    actions/sec and p50/p99 per-step latency.
    """
    import jax
    import jax.numpy as jnp

    from repro import serving
    from repro.core import ptq
    from repro.rl import actorq, loops
    from repro.rl.actor_learner import ALGOS as REPLAY_ALGOS
    from repro.rl.envs import make as make_env

    env = make_env(args.rl_env)
    topo_kw = {}
    if args.topology in ("actor-learner", "async"):
        # replay algorithms only (the paper's DQN/D4PG analogues)
        algo = "dqn" if not env.spec.continuous else "ddpg"
        topo_kw = dict(topology=args.topology,
                       num_actors=args.num_actors,
                       sync_every=args.sync_every)
    else:
        algo = "ppo" if not env.spec.continuous else "ddpg"
    if args.replay != "uniform" and algo not in REPLAY_ALGOS:
        raise SystemExit(
            f"--replay {args.replay} needs a replay algorithm; fused "
            f"discrete envs train {algo} — use --topology actor-learner")
    if algo in REPLAY_ALGOS:
        topo_kw.update(replay=args.replay,
                       priority_exponent=args.priority_exponent,
                       is_beta=args.is_beta)
    res = loops.train(algo, args.rl_env, iterations=max(args.rl_iters, 1),
                      record_every=max(args.rl_iters, 1), eval_episodes=2,
                      seed=args.seed, steps_per_call=args.steps_per_call,
                      actor_backend=args.actor_backend,
                      calib_batch=args.calib_batch,
                      checkpoint_dir=args.ckpt_dir,
                      checkpoint_every=args.ckpt_every,
                      resume=args.resume, **topo_kw)
    if algo in REPLAY_ALGOS and args.replay == "prioritized":
        print(f"[serve-rl] prioritized replay: alpha="
              f"{args.priority_exponent} is_beta={args.is_beta}")
    if args.topology in ("actor-learner", "async") and res.divergences:
        div = ", ".join(f"{d:.4f}" for d in res.divergences[-1])
        unit = "learner updates" if args.topology == "async" \
            else "iterations"
        print(f"[serve-rl] {args.topology} ({algo}): {args.num_actors} "
              f"actors, sync_every={args.sync_every} {unit}, last "
              f"per-actor divergence [{div}]")
    if args.topology == "async" and res.actor_lags:
        print(f"[serve-rl] async overlap: {len(res.actor_lags)} param "
              f"pushes, mean actor lag "
              f"{sum(res.actor_lags) / len(res.actor_lags):.1f} learner "
              f"updates")
    params = res.state.params
    fp32_bytes = ptq.tree_nbytes(params)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    server = serving.PolicyServer(
        env.spec, actor_backend=args.actor_backend,
        kernel_backend=args.kernel_backend, buckets=buckets,
        max_wait_us=args.max_wait_us, calib_batch=args.calib_batch)

    calib_obs = None
    if actorq.is_quantized(args.actor_backend) and args.calib_batch:
        # deployment-time calibration: static activation scales from the
        # states the *trained* policy actually visits — a short greedy
        # rollout from reset (reset draws alone sit near the origin for
        # the classic-control envs and would saturate the scales once the
        # served policy drifts) -> the single-pass fused MLP kernel
        # answers every action query in one dispatch
        qparams = actorq.pack_actor_params(
            params, actorq.backend_bits(args.actor_backend))
        calib_obs = serving.greedy_calib_obs(
            env, qparams, args.calib_batch, args.seed + 1,
            kernel_backend=args.kernel_backend)
    entry = server.push_params(params, calib_obs=calib_obs)
    if calib_obs is not None:
        if actorq.ACT_QUANT in entry.cache:
            print(f"[serve-rl] static requant: calibrated on "
                  f"{calib_obs.shape[0]} obs -> fused single-pass actor")
        else:
            # conv policies keep the per-layer path (calibration is a
            # documented no-op for CNN caches)
            print("[serve-rl] static requant: conv policy — calibration "
                  "skipped, per-layer path served")
    server.warmup()
    print(f"[serve-rl] env={args.rl_env} algo={algo} "
          f"actor={args.actor_backend} kernel={args.kernel_backend} "
          f"params={fp32_bytes / 1e3:.1f}KB fp32 -> "
          f"{entry.nbytes / 1e3:.1f}KB served "
          f"({fp32_bytes / max(entry.nbytes, 1):.2f}x) "
          f"buckets={list(buckets)} max_wait={args.max_wait_us}us")

    # drive N concurrent env sessions against the server: each session
    # steps its own (client-side) env with the actions the server returns
    import numpy as np

    from repro.rl.env import batched_env

    n = args.serve_sessions
    benv = batched_env(env, n)
    e_state, obs = benv.reset(jax.random.PRNGKey(args.seed))
    latencies = []
    t0 = time.time()
    with server:
        sids = [server.open_session() for _ in range(n)]
        for step_i in range(args.serve_steps):
            if step_i == args.serve_steps // 2 and args.serve_steps > 1:
                # live hot-swap under load: repack + republish (zero-copy
                # reference swap; in-flight batches finish on the old
                # cache, the next dispatch serves the new version)
                swapped = server.push_params(params)
                print(f"[serve-rl] hot-swap at step {step_i}: now serving "
                      f"cache version {swapped.version}")
            o_host = np.asarray(obs)
            reqs = [server.submit(sid, o_host[i])
                    for i, sid in enumerate(sids)]
            results = [r.result(timeout=120) for r in reqs]
            latencies.extend(r.latency_s for r in results)
            actions = jnp.asarray(np.stack([r.action for r in results]))
            if not env.spec.continuous:
                actions = actions.astype(jnp.int32)
            e_state, obs, _, _ = benv.step(
                e_state, actions, jax.random.fold_in(
                    jax.random.PRNGKey(args.seed), step_i))
        for sid in sids:
            server.close_session(sid)
    dt = time.time() - t0
    stats = server.stats()
    lat = np.asarray(latencies) * 1e3
    print(f"[serve-rl] {n} sessions x {args.serve_steps} steps in "
          f"{dt:.3f}s ({len(latencies) / dt:.0f} actions/s); per-step "
          f"latency p50 {np.percentile(lat, 50):.2f}ms "
          f"p99 {np.percentile(lat, 99):.2f}ms; "
          f"{stats['dispatches']} dispatches, mean batch "
          f"{stats['served'] / max(stats['dispatches'], 1):.1f}, "
          f"served by cache v{stats['version']}")
    print("           first actions:",
          np_list(results[0].action) if env.spec.continuous
          else [int(r.action) for r in results[:8]])
    return 0


def np_list(x):
    import numpy as np
    return np.asarray(x).tolist()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    help="LM mode: transformer architecture to decode")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="LM mode: decoding batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", default="none",
                    help="none | ptq_fp16 | ptq_int8 (weights)")
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rl-env", default=None,
                    help="serve an RL policy instead of an LM "
                         "(ActorQ deployment; e.g. cartpole, airnav)")
    ap.add_argument("--actor-backend", default="fp32",
                    choices=["fp32", "int8", "int4"],
                    help="int8 = W8A8 packed actor; int4 = byte-packed "
                         "W4A8 (half the served cache)")
    ap.add_argument("--kernel-backend", default="auto",
                    choices=["pallas", "interpret", "ref", "xla", "auto"])
    ap.add_argument("--calib-batch", type=int, default=0,
                    help="static-requant calibration batch for quantized "
                         "actors: >0 calibrates per-layer activation "
                         "scales (training caches at every sync, the "
                         "served cache once at deploy) and runs MLP "
                         "actors as ONE fused kernel pass; 0 = dynamic "
                         "per-layer quantization")
    ap.add_argument("--rl-iters", type=int, default=20,
                    help="training iterations before serving (--rl-env)")
    ap.add_argument("--steps-per-call", type=int, default=10,
                    help="scan-fused driver chunk for --rl-env training")
    ap.add_argument("--topology", default="fused",
                    choices=["fused", "actor-learner", "async"],
                    help="--rl-env training topology. actor-learner = the "
                         "paper's distributed ActorQ paradigm "
                         "(bulk-synchronous); async = overlapped actors/"
                         "learner over a double-buffered replay (no "
                         "host barrier). Both need a replay algorithm, so "
                         "discrete envs train DQN there vs PPO under "
                         "fused (the printed summary names the algo)")
    ap.add_argument("--num-actors", type=int, default=2,
                    help="actor replicas for the actor-learner topologies")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="learner->actor param push cadence: iterations "
                         "under --topology actor-learner, learner "
                         "*updates* under --topology async")
    ap.add_argument("--replay", default="uniform",
                    choices=["uniform", "prioritized"],
                    help="--rl-env replay discipline (DQN/DDPG): "
                         "prioritized = sum-tree PER with IS correction")
    ap.add_argument("--priority-exponent", type=float, default=0.6,
                    help="PER alpha; 0.0 degrades to bitwise-uniform")
    ap.add_argument("--is-beta", type=float, default=0.4,
                    help="initial IS-correction exponent (anneals to 1)")
    ap.add_argument("--serve-sessions", type=int, default=64,
                    help="concurrent env sessions driven against the "
                         "policy server after training (--rl-env)")
    ap.add_argument("--serve-steps", type=int, default=5,
                    help="env steps each serving session takes (a live "
                         "hot-swap fires at the halfway step)")
    ap.add_argument("--buckets", default="8,32,128,512",
                    help="ascending padded batch shapes the server "
                         "compiles (largest = admission max batch)")
    ap.add_argument("--max-wait-us", type=int, default=2000,
                    help="admission straggler wait: dispatch once the "
                         "oldest queued request is this old (0 = never "
                         "wait; the tail-latency knob)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint the training phase here "
                         "(repro.checkpoint async writer)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="iterations between training checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="resume training from the newest checkpoint in "
                         "--ckpt-dir before serving")
    args = ap.parse_args(argv)

    if args.rl_env:
        return _serve_policy(args)

    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.core import ptq
    from repro.core.qconfig import QuantConfig
    from repro.models import transformer

    cfg = cfgs.get_reduced(args.arch) if args.reduced else cfgs.get(args.arch)
    quant = QuantConfig.parse(args.quant)
    if args.int8_cache:
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, int8_kv_cache=True))

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    fp32_bytes = ptq.tree_nbytes(params)
    if quant.is_ptq:
        params = ptq.ptq_simulate(params, quant)  # simulated int math
    print(f"[serve] {cfg.name} quant={quant.label()} "
          f"int8_cache={cfg.quant.int8_kv_cache} "
          f"params={fp32_bytes / 1e6:.1f}MB fp32"
          + (f" -> {fp32_bytes / 4 / 1e6:.1f}MB int8 packed"
             if quant.mode.value == "ptq_int" else ""))

    total_len = args.prompt_len + args.new_tokens
    caches = transformer.init_caches(cfg, args.batch, total_len,
                                     dtype=jnp.float32)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    enc = None
    if cfg.cross_attn or cfg.encoder_layers:
        enc = jax.random.normal(key, (args.batch, max(cfg.encoder_seq, 4),
                                      cfg.d_model)) * 0.02

    @jax.jit
    def step(params, caches, tok, pos):
        logits, caches = transformer.decode_step(cfg, params, tok, caches,
                                                 pos, encoder_out=enc)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches

    # prefill token-by-token (teacher forcing) then greedy decode
    t0 = time.time()
    out_tokens = []
    tok = tokens[:, :1]
    for pos in range(total_len - 1):
        nxt, caches = step(params, caches, tok, jnp.asarray(pos))
        tok = tokens[:, pos + 1:pos + 2] if pos + 1 < args.prompt_len \
            else nxt[:, None]
        if pos + 1 >= args.prompt_len:
            out_tokens.append(nxt)
    dt = time.time() - t0
    n_gen = args.batch * len(out_tokens)
    print(f"[serve] generated {len(out_tokens)} tokens x {args.batch} seqs "
          f"in {dt:.2f}s ({n_gen / dt:.1f} tok/s on CPU)")
    print("        first sequence:", [int(t[0]) for t in out_tokens][:16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
