"""Launchers: mesh construction, pjit step builders, dry-run, train, serve."""
