"""Post-SPMD HLO analysis: trip-count-aware collective byte counting.

Why this exists: ``compiled.cost_analysis()`` exposes FLOPs/bytes but not
collective traffic, and XLA's analysis counts a ``while`` body ONCE rather
than once per iteration — under ``lax.scan``-over-layers that undercounts by
the layer count. We therefore parse ``compiled.as_text()`` (post-partitioning
HLO, where all-gather/all-reduce/... are explicit ops):

1. split the module into named computations,
2. find every ``while`` op and its condition/body computations; recover the
   static trip count from the ``s32[] constant(N)`` the condition compares
   against,
3. propagate execution multipliers down the call graph (entry = 1, a while
   body inherits parent_multiplier x trip_count),
4. sum result-operand bytes of every collective op weighted by its
   computation's multiplier.

The same caveat applies to FLOPs/bytes — the roofline uses analytic model
FLOPs (benchmarks/flops.py) as the compute term and reports raw
cost_analysis numbers alongside (EXPERIMENTS.md documents this).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# "f32[4,512,768]{2,1,0} all-reduce(" — possibly tuple results "(f32[..], ..)"
_COLL_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9_]+\[[0-9,]*\][^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
# Computation definition: a line like "%name (params...) -> type {". Params
# and return types contain nested parens AND layout braces ("{3,2,1,0}"), so
# just anchor on: line starts with the name, ends with "{".
_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", re.M)
_WHILE_RE = re.compile(
    r"while\(%[\w.\-]+\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_bytes(sig: str) -> int:
    """Sum over all tensors in a (possibly tuple) result signature."""
    return sum(_tensor_bytes(d, dims) for d, dims in _SHAPE_RE.findall(sig))


def split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (brace-matched from the header)."""
    comps: Dict[str, str] = {}
    for m in _COMP_HEADER_RE.finditer(hlo_text):
        name = m.group(1)
        brace = hlo_text.rfind("{", m.start(), m.end())  # header's own "{"
        if brace < 0:
            continue
        depth, i = 1, brace + 1
        while depth and i < len(hlo_text):
            c = hlo_text[i]
            depth += c == "{"
            depth -= c == "}"
            i += 1
        comps[name] = hlo_text[brace:i]
    return comps


def computation_multipliers(hlo_text: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (1 outside loops)."""
    comps = split_computations(hlo_text)
    entry = None
    m = re.search(r"ENTRY %?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)

    # while edges: parent_comp -> (body_comp, trip)
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, body in comps.items():
        for w in _WHILE_RE.finditer(body):
            cond, wbody = w.group(1), w.group(2)
            consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
            trip = float(max(consts)) if consts else 1.0
            edges.setdefault(name, []).append((wbody, trip))
            # the condition itself runs trip+1 times; negligible, skipped

    mult: Dict[str, float] = {name: 1.0 for name in comps}
    # propagate breadth-first from the entry; computations not reached by
    # while-edges keep multiplier 1 (fusions are accounted at their call site
    # because collectives never live inside fusion computations).
    order = [entry] if entry in comps else list(comps)
    seen = set(order)
    while order:
        cur = order.pop(0)
        for child, trip in edges.get(cur, []):
            new = mult.get(cur, 1.0) * trip
            if new > mult.get(child, 0.0) or child not in seen:
                mult[child] = new
                seen.add(child)
                order.append(child)
    return mult


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Trip-count-weighted collective bytes, total and per collective kind."""
    comps = split_computations(hlo_text)
    mult = computation_multipliers(hlo_text)
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    total = 0.0
    for name, body in comps.items():
        m = mult.get(name, 1.0)
        for op in _COLL_OP_RE.finditer(body):
            sig, kind = op.group(1), op.group(2)
            b = _shape_bytes(sig) * m
            out[kind] += b
            total += b
    out["total"] = total
    return out


def collective_bytes(hlo_text: str) -> float:
    return collective_stats(hlo_text)["total"]


def while_trip_counts(hlo_text: str) -> List[float]:
    comps = split_computations(hlo_text)
    trips = []
    for body in comps.values():
        for w in _WHILE_RE.finditer(body):
            consts = [int(c) for c in _CONST_RE.findall(
                comps.get(w.group(1), ""))]
            trips.append(float(max(consts)) if consts else 1.0)
    return trips


def summarize_memory(memory_analysis) -> Dict[str, float]:
    """Pick the useful fields out of compiled.memory_analysis()."""
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes")
    out = {}
    for f in fields:
        v = getattr(memory_analysis, f, None)
        if v is not None:
            out[f] = float(v)
    if out.get("argument_size_in_bytes") is not None:
        out["total_nonalias_bytes"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    return out
