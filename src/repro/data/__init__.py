"""Data pipeline: synthetic token streams + sharded host->device batching."""
from repro.data.synthetic import SyntheticLMDataset, make_lm_batch
from repro.data.pipeline import ShardedBatcher

__all__ = ["SyntheticLMDataset", "make_lm_batch", "ShardedBatcher"]
