"""Synthetic language-modeling data.

Offline container -> no corpora; training examples use a deterministic
mixture of structured sequences (ngram-ish Markov chains + copy tasks) so a
~100M model actually has signal to fit (loss decreases measurably within a
few hundred steps, unlike uniform-random tokens).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    """Markov-chain token stream with a copy motif.

    Transition matrix is low-entropy (each token has ~8 plausible
    successors), so cross-entropy has a floor around log(8) ~ 2.1 nats and a
    model that learns reduces loss well below log(vocab).
    """
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._succ = rng.integers(
            0, self.vocab, size=(self.vocab, self.branching)).astype(np.int32)

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            yield self.sample(rng)

    def sample(self, rng: np.random.Generator) -> Dict[str, np.ndarray]:
        b, s = self.batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=b)
        choices = rng.integers(0, self.branching, size=(b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_lm_batch(key: jax.Array, vocab: int, batch: int, seq_len: int
                  ) -> Dict[str, jnp.ndarray]:
    """Jax-native quick batch (uniform tokens) for smoke/bench paths."""
    toks = jax.random.randint(key, (batch, seq_len + 1), 0, vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
