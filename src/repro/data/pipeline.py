"""Host -> device batching with explicit shardings.

``ShardedBatcher`` places host numpy batches onto the mesh with
``jax.device_put`` + NamedSharding (batch dim over the data axes), which is
the single-controller analogue of a per-host input pipeline: on a real
multi-host pod each host feeds its slice via
``jax.make_array_from_process_local_data`` (same sharding object).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class ShardedBatcher:
    def __init__(self, mesh: Optional[Mesh], multi_pod: bool = False):
        self.mesh = mesh
        axes = ("pod", "data") if multi_pod else "data"
        self.spec = PartitionSpec(axes)

    def put(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = PartitionSpec(*self.spec, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def __call__(self, it: Iterator[Dict[str, np.ndarray]]
                 ) -> Iterator[Dict[str, jax.Array]]:
        for batch in it:
            yield self.put(batch)
