"""repro.core — the paper's contribution: quantization for RL/LM systems.

Public surface:
  QuantConfig / MixedPrecisionConfig   configuration
  affine.*                             paper-faithful uniform affine quantizer
  fake_quant.*                         QAT: STE + observers + quant delay
  ptq.*                                post-training quantization of pytrees
  mixed_precision.*                    bf16/fp16 compute, fp32 master, loss scale
  metrics.*                            paper's analysis metrics
"""
from repro.core.qconfig import QuantConfig, QuantMode, MixedPrecisionConfig
from repro.core import affine, fake_quant, ptq, mixed_precision, metrics

__all__ = [
    "QuantConfig", "QuantMode", "MixedPrecisionConfig",
    "affine", "fake_quant", "ptq", "mixed_precision", "metrics",
]
