"""Post-training quantization over parameter pytrees (QuaRL Algorithm 1).

Two forms are provided:

* ``ptq_simulate(params, config)`` — quantize-dequantize every weight matrix in
  place (values change, dtypes don't). This is what the paper evaluates: the
  policy is run in float math on quantization-error-injected weights.
* ``ptq_pack(params, config)`` / ``ptq_unpack`` — the deployment form: weights
  stored as int8 (+ per-tensor/per-axis scales), 4x smaller than fp32. The
  int8 matmul kernel in ``repro.kernels`` consumes these directly.

Which leaves quantize: any float array with ndim >= 2 is treated as a weight
(dense kernels, conv kernels, embeddings); biases/norm scales (ndim <= 1) stay
full precision, matching the paper's per-layer weight quantization. Conv
kernels (ndim == 4) get per-axis quantization over the output-channel axis.
A ``predicate(path, leaf)`` hook lets callers exclude e.g. MoE routers.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import affine
from repro.core.qconfig import QuantConfig, QuantMode

PyTree = Any
Predicate = Callable[[Tuple[Any, ...], jnp.ndarray], bool]


def _is_weight(path: Tuple[Any, ...], leaf: Any) -> bool:
    return (isinstance(leaf, (jnp.ndarray, jax.Array))
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and leaf.ndim >= 2)


def _axis_for(leaf: jnp.ndarray, config: QuantConfig) -> Optional[int]:
    # Per-axis (output-channel) quantization for conv kernels (HWIO -> axis -1),
    # per-tensor for everything else, per the paper.
    if config.per_axis_conv and leaf.ndim == 4:
        return leaf.ndim - 1
    return None


def ptq_simulate(params: PyTree, config: QuantConfig,
                 predicate: Predicate = _is_weight) -> PyTree:
    """Quantize-dequantize all weights (Algorithm 1's Q applied to M)."""
    if not config.is_ptq:
        return params

    def one(path, leaf):
        if not predicate(path, leaf):
            return leaf
        if config.mode == QuantMode.PTQ_FP16:
            return affine.fp16_quantize(leaf)
        return affine.ptq_tensor(leaf, config.bits, _axis_for(leaf, config))

    return jax.tree_util.tree_map_with_path(one, params)


class PackedTensor(NamedTuple):
    """An int-packed weight: codes + affine params (deployment format).

    ``col_scale`` / ``col_zero`` are the kernel-layout per-column ``(N,)``
    f32 dequant arrays the W8A8 GEMM epilogue consumes, materialized once
    at pack time (a per-tensor dense scale broadcasts, a per-channel conv
    scale flattens) instead of being rebuilt on every forward call.

    Sub-8-bit weights (``bits <= 4``) store ``codes`` *packed*: two int4
    codes per int8 byte along the GEMM contraction axis, already in the
    kernel's ``(K, N)`` layout (conv kernels are pre-transposed from HWIO
    to the im2col ``(C_in*kh*kw, C_out)`` feature order).  ``orig_shape``
    carries the unpacked weight shape; ``None`` means codes are stored in
    the weight's natural layout (the int8 path).
    """
    codes: jnp.ndarray        # int8/int16; packed pairs when bits <= 4
    delta: jnp.ndarray
    zero_point: jnp.ndarray
    bits: int
    col_scale: Any = None     # (N,) f32 kernel-layout per-column scale
    col_zero: Any = None      # (N,) f32 kernel-layout per-column zero
    orig_shape: Any = None    # unpacked shape when codes are sub-8-bit

    def unpacked_codes(self) -> jnp.ndarray:
        """Codes widened to one-per-int8 in the stored layout."""
        if self.orig_shape is None:
            return self.codes
        k = 1
        for d in self.orig_shape[:-1]:
            k *= d
        return affine.unpack_int4(self.codes, k)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        p = affine.AffineParams(self.delta, self.zero_point, self.bits)
        codes = self.unpacked_codes()
        if self.orig_shape is not None and len(self.orig_shape) == 4:
            # packed conv codes live in im2col (C_in*kh*kw, C_out) layout;
            # restore HWIO so delta/zero_point broadcast as at pack time
            kh, kw, ci, co = self.orig_shape
            codes = codes.reshape(ci, kh, kw, co).transpose(1, 2, 0, 3)
        elif self.orig_shape is not None:
            codes = codes.reshape(self.orig_shape)
        return affine.dequantize_from_int(codes, p, dtype)

    @property
    def nbytes(self) -> int:
        # col_scale/col_zero are *derived* broadcasts of delta/zero_point
        # (hoisted to pack time for the kernel epilogue) — not counted, so
        # the footprint metric stays about the quantizer payload: codes +
        # canonical affine params (the paper's ~4x claim; exactly-halved
        # codes under int4).
        return (self.codes.size * self.codes.dtype.itemsize
                + self.delta.size * 4 + self.zero_point.size * 4)


jax.tree_util.register_pytree_node(
    PackedTensor,
    lambda p: ((p.codes, p.delta, p.zero_point, p.col_scale, p.col_zero),
               (p.bits, p.orig_shape)),
    lambda aux, xs: PackedTensor(xs[0], xs[1], xs[2], aux[0], xs[3], xs[4],
                                 aux[1]))


def _pack_leaf(leaf: jnp.ndarray, bits: int,
               axis: Optional[int]) -> PackedTensor:
    """Quantize one weight into the kernel-ready PackedTensor layout."""
    codes, p = affine.quantize_to_int(leaf, bits, axis)
    n = leaf.shape[-1]
    col_scale = jnp.broadcast_to(
        jnp.asarray(p.delta, jnp.float32).reshape(-1), (n,))
    col_zero = jnp.broadcast_to(
        jnp.asarray(p.zero_point, jnp.float32).reshape(-1), (n,))
    # jnp.broadcast_to returns a view under tracing; commit real buffers so
    # the cache is self-contained when carried across program boundaries
    col_scale, col_zero = jnp.array(col_scale), jnp.array(col_zero)
    if bits > 4:
        return PackedTensor(codes, p.delta, p.zero_point, bits,
                            col_scale, col_zero)
    # sub-8-bit: pre-transpose to the GEMM contraction layout and pack
    # two codes per byte along K (see PackedTensor docstring)
    if leaf.ndim == 4:
        kh, kw, ci, co = codes.shape
        codes = codes.transpose(2, 0, 1, 3).reshape(kh * kw * ci, co)
    else:
        codes = codes.reshape(-1, n)
    return PackedTensor(affine.pack_int4(codes), p.delta, p.zero_point,
                        bits, col_scale, col_zero,
                        orig_shape=tuple(leaf.shape))


def ptq_pack(params: PyTree, config: QuantConfig,
             predicate: Predicate = _is_weight) -> PyTree:
    """Pack weights into int storage; non-weights pass through unchanged."""
    if config.mode != QuantMode.PTQ_INT:
        raise ValueError(f"packing is for int PTQ, got {config.mode}")

    def one(path, leaf):
        if not predicate(path, leaf):
            return leaf
        return _pack_leaf(leaf, config.bits, _axis_for(leaf, config))

    return jax.tree_util.tree_map_with_path(one, params)


def ptq_unpack(packed: PyTree, dtype=jnp.float32) -> PyTree:
    def one(leaf):
        if isinstance(leaf, PackedTensor):
            return leaf.dequantize(dtype)
        return leaf
    return jax.tree_util.tree_map(
        one, packed, is_leaf=lambda x: isinstance(x, PackedTensor))


def tree_nbytes(params: PyTree) -> int:
    """Parameter-memory footprint (paper's 4x memory-reduction claim)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedTensor)):
        if isinstance(leaf, PackedTensor):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
