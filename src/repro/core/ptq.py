"""Post-training quantization over parameter pytrees (QuaRL Algorithm 1).

Two forms are provided:

* ``ptq_simulate(params, config)`` — quantize-dequantize every weight matrix in
  place (values change, dtypes don't). This is what the paper evaluates: the
  policy is run in float math on quantization-error-injected weights.
* ``ptq_pack(params, config)`` / ``ptq_unpack`` — the deployment form: weights
  stored as int8 (+ per-tensor/per-axis scales), 4x smaller than fp32. The
  int8 matmul kernel in ``repro.kernels`` consumes these directly.

Which leaves quantize: any float array with ndim >= 2 is treated as a weight
(dense kernels, conv kernels, embeddings); biases/norm scales (ndim <= 1) stay
full precision, matching the paper's per-layer weight quantization. Conv
kernels (ndim == 4) get per-axis quantization over the output-channel axis.
A ``predicate(path, leaf)`` hook lets callers exclude e.g. MoE routers.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import affine
from repro.core.qconfig import QuantConfig, QuantMode

PyTree = Any
Predicate = Callable[[Tuple[Any, ...], jnp.ndarray], bool]


def _is_weight(path: Tuple[Any, ...], leaf: Any) -> bool:
    return (isinstance(leaf, (jnp.ndarray, jax.Array))
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and leaf.ndim >= 2)


def _axis_for(leaf: jnp.ndarray, config: QuantConfig) -> Optional[int]:
    # Per-axis (output-channel) quantization for conv kernels (HWIO -> axis -1),
    # per-tensor for everything else, per the paper.
    if config.per_axis_conv and leaf.ndim == 4:
        return leaf.ndim - 1
    return None


def ptq_simulate(params: PyTree, config: QuantConfig,
                 predicate: Predicate = _is_weight) -> PyTree:
    """Quantize-dequantize all weights (Algorithm 1's Q applied to M)."""
    if not config.is_ptq:
        return params

    def one(path, leaf):
        if not predicate(path, leaf):
            return leaf
        if config.mode == QuantMode.PTQ_FP16:
            return affine.fp16_quantize(leaf)
        return affine.ptq_tensor(leaf, config.bits, _axis_for(leaf, config))

    return jax.tree_util.tree_map_with_path(one, params)


class PackedTensor(NamedTuple):
    """An int-packed weight: codes + affine params (deployment format)."""
    codes: jnp.ndarray        # int8/int16
    delta: jnp.ndarray
    zero_point: jnp.ndarray
    bits: int

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        p = affine.AffineParams(self.delta, self.zero_point, self.bits)
        return affine.dequantize_from_int(self.codes, p, dtype)

    @property
    def nbytes(self) -> int:
        return (self.codes.size * self.codes.dtype.itemsize
                + self.delta.size * 4 + self.zero_point.size * 4)


jax.tree_util.register_pytree_node(
    PackedTensor,
    lambda p: ((p.codes, p.delta, p.zero_point), p.bits),
    lambda bits, xs: PackedTensor(xs[0], xs[1], xs[2], bits))


def ptq_pack(params: PyTree, config: QuantConfig,
             predicate: Predicate = _is_weight) -> PyTree:
    """Pack weights into int storage; non-weights pass through unchanged."""
    assert config.mode == QuantMode.PTQ_INT, "packing is for int PTQ"

    def one(path, leaf):
        if not predicate(path, leaf):
            return leaf
        codes, p = affine.quantize_to_int(leaf, config.bits,
                                          _axis_for(leaf, config))
        return PackedTensor(codes, p.delta, p.zero_point, config.bits)

    return jax.tree_util.tree_map_with_path(one, params)


def ptq_unpack(packed: PyTree, dtype=jnp.float32) -> PyTree:
    def one(leaf):
        if isinstance(leaf, PackedTensor):
            return leaf.dequantize(dtype)
        return leaf
    return jax.tree_util.tree_map(
        one, packed, is_leaf=lambda x: isinstance(x, PackedTensor))


def tree_nbytes(params: PyTree) -> int:
    """Parameter-memory footprint (paper's 4x memory-reduction claim)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedTensor)):
        if isinstance(leaf, PackedTensor):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
