"""Fake quantization with the straight-through estimator + range observers.

Implements the paper's QAT machinery (Sec. 3.2 / Algorithm 2):

* ``fake_quant(w, params)`` — quantize-dequantize in the forward pass; identity
  gradient in the backward pass (straight-through estimator, Hinton 2012).
* ``Observer`` state — running min/max (optionally EMA-smoothed) monitored
  during the first ``quant_delay`` updates; afterwards the captured ranges are
  frozen and used for quantization.
* ``QuantTensorFn`` — the function a layer applies to its weights/activations;
  it reads a per-tensor observer slot out of a ``QATCollection`` pytree that is
  threaded through the model as mutable-state-as-value.

The observer collection is a flat dict ``name -> ObserverState`` living inside
the train state, so the whole QAT schedule (delay, monitoring, freezing) is a
pure function of (params, qat_state, step) and jit/pjit-compatible:
``enabled = step >= quant_delay`` is computed with lax.select so one compiled
program covers both phases.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import affine
from repro.core.qconfig import QuantConfig


class ObserverState(NamedTuple):
    """Running range of one tensor. Scalar min/max (per-tensor quantization)."""
    vmin: jnp.ndarray  # f32 scalar
    vmax: jnp.ndarray  # f32 scalar
    initialized: jnp.ndarray  # bool scalar

    @staticmethod
    def init() -> "ObserverState":
        return ObserverState(vmin=jnp.zeros((), jnp.float32),
                             vmax=jnp.zeros((), jnp.float32),
                             initialized=jnp.zeros((), jnp.bool_))


def observe(state: ObserverState, x: jnp.ndarray, ema_decay: float,
            monitoring: jnp.ndarray) -> ObserverState:
    """Update running range with tensor ``x`` while ``monitoring`` is True.

    During monitoring the paper's tf.contrib observers track moving min/max; we
    use an EMA of the batch min/max (first batch initializes directly). Once
    monitoring ends (step >= quant_delay) the state is frozen (returned as-is).
    """
    bmin = jnp.minimum(jnp.min(x), 0.0).astype(jnp.float32)
    bmax = jnp.maximum(jnp.max(x), 0.0).astype(jnp.float32)
    d = ema_decay
    new_min = jnp.where(state.initialized, d * state.vmin + (1 - d) * bmin, bmin)
    new_max = jnp.where(state.initialized, d * state.vmax + (1 - d) * bmax, bmax)
    upd = ObserverState(vmin=new_min, vmax=new_max,
                        initialized=jnp.ones((), jnp.bool_))
    return jax.tree_util.tree_map(
        lambda new, old: jnp.where(monitoring, new, old), upd, state)


# ---------------------------------------------------------------------------
# Straight-through estimator
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_quantize_dequantize(w: jnp.ndarray, delta: jnp.ndarray,
                             zero_point: jnp.ndarray, bits: jnp.ndarray
                             ) -> jnp.ndarray:
    q = jnp.round(w / delta) + zero_point
    q = jnp.clip(q, 0.0, 2.0 ** bits - 1.0)
    return (delta * (q - zero_point)).astype(w.dtype)


def _ste_fwd(w, delta, zero_point, bits):
    out = _ste_quantize_dequantize(w, delta, zero_point, bits)
    return out, (delta, zero_point, bits)


def _ste_bwd(res, g):
    # Paper: "the gradient is passed through the quantization function
    # unchanged" — identity w.r.t. w, no gradient to quantizer params.
    delta, zero_point, bits = res
    return (g.astype(g.dtype), jnp.zeros_like(delta),
            jnp.zeros_like(zero_point), jnp.zeros_like(bits))


_ste_quantize_dequantize.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(w: jnp.ndarray, vmin: jnp.ndarray, vmax: jnp.ndarray,
               bits: int) -> jnp.ndarray:
    """Paper's Q_n^train with STE, using monitored range (vmin, vmax)."""
    params = affine.affine_params_from_range(vmin, vmax, bits)
    return _ste_quantize_dequantize(
        w.astype(jnp.float32),
        params.delta.astype(jnp.float32),
        params.zero_point.astype(jnp.float32),
        jnp.asarray(bits, jnp.float32)).astype(w.dtype)


def fake_quant_self_range(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """STE fake quant with the tensor's own instantaneous range.

    Used for weights (the paper recomputes weight ranges from the live weights;
    the monitored/frozen ranges matter mostly for activations) and for
    evaluation-time PTQ-with-gradient experiments.
    """
    wmin = jnp.minimum(jnp.min(w), 0.0)
    wmax = jnp.maximum(jnp.max(w), 0.0)
    return fake_quant(w, wmin, wmax, bits)


# ---------------------------------------------------------------------------
# QAT collection — observers threaded through the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QATContext:
    """Mutable-during-trace context collecting observer reads/writes.

    A model function runs under a ``QATContext``; every quantized tensor site
    calls ``ctx.activation(name, x)`` / ``ctx.weight(name, w)``. The context
    reads old observer state from ``collection`` and records updates in
    ``updates``; the trainer merges them back into the train state.

    ``enabled`` / ``monitoring`` are traced booleans implementing the paper's
    quantization delay:
      step <  quant_delay : monitoring=True,  enabled=False  (full precision)
      step >= quant_delay : monitoring=False, enabled=True   (frozen ranges)
    """
    config: QuantConfig
    collection: Dict[str, ObserverState]
    step: jnp.ndarray
    updates: Dict[str, ObserverState] = dataclasses.field(default_factory=dict)

    @property
    def monitoring(self) -> jnp.ndarray:
        return self.step < self.config.quant_delay

    @property
    def enabled(self) -> jnp.ndarray:
        return self.step >= self.config.quant_delay

    def _slot(self, name: str) -> ObserverState:
        if name in self.updates:
            return self.updates[name]
        return self.collection.get(name, ObserverState.init())

    def weight(self, name: str, w: jnp.ndarray) -> jnp.ndarray:
        """Fake-quantize a weight tensor (per-tensor, self-range)."""
        if not self.config.is_qat:
            return w
        fq = fake_quant_self_range(w, self.config.bits)
        return jnp.where(self.enabled, fq, w)

    def activation(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        """Observe + fake-quantize an activation tensor (monitored range)."""
        if not (self.config.is_qat and self.config.quantize_activations):
            return x
        st = self._slot(name)
        st = observe(st, jax.lax.stop_gradient(x), self.config.ema_decay,
                     self.monitoring)
        self.updates[name] = st
        fq = fake_quant(x, st.vmin, st.vmax, self.config.bits)
        return jnp.where(self.enabled & st.initialized, fq, x)

    def merged_collection(self) -> Dict[str, ObserverState]:
        out = dict(self.collection)
        out.update(self.updates)
        return out


class NullQATContext:
    """No-op context used when quantization is disabled (keeps call sites clean)."""
    config = QuantConfig.none()
    enabled = False  # ctx contract: every context exposes ``enabled``

    def weight(self, name: str, w: jnp.ndarray) -> jnp.ndarray:
        return w

    def activation(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        return x

    def merged_collection(self) -> Dict[str, ObserverState]:
        return {}


def make_context(config: QuantConfig,
                 collection: Optional[Dict[str, ObserverState]],
                 step) -> QATContext | NullQATContext:
    if not config.is_qat:
        return NullQATContext()
    return QATContext(config=config, collection=collection or {},
                      step=jnp.asarray(step))


class NameRecorder:
    """Trace-time context that records every activation-site name.

    Used to pre-build the observer collection before the first jitted
    update — scan carries need a fixed pytree structure, so all observer
    slots must exist up front.
    """

    enabled = False  # ctx contract: recording never applies quantization

    def __init__(self, config: QuantConfig):
        self.config = config
        self.names: set = set()

    def weight(self, name: str, w):
        return w

    def activation(self, name: str, x):
        self.names.add(name)
        return x

    def merged_collection(self) -> Dict[str, ObserverState]:
        return {}

    def collection(self) -> Dict[str, ObserverState]:
        return {name: ObserverState.init() for name in sorted(self.names)}


def discover_observers(config: QuantConfig, trace_fn) -> Dict[str,
                                                              ObserverState]:
    """Run ``trace_fn(recorder_ctx)`` under eval_shape; return fresh slots."""
    rec = NameRecorder(config)
    jax.eval_shape(lambda: (trace_fn(rec), ())[1])
    return rec.collection()
