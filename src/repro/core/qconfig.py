"""Quantization configuration objects shared across the framework.

The vocabulary follows the paper (QuaRL):

* ``none``      — full precision (fp32 or the mixed-precision compute dtype).
* ``ptq_fp16``  — post-training quantization to IEEE fp16 (Sec. 3.1).
* ``ptq_int<n>``— post-training uniform affine quantization to ``n`` bits.
* ``qat<n>``    — quantization-aware training at ``n`` bits with the
  straight-through estimator and a quantization delay (Sec. 3.2).

``QuantConfig`` is a frozen dataclass so it can live inside jitted closures and
model configs hashed by jax.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class QuantMode(enum.Enum):
    NONE = "none"
    PTQ_FP16 = "ptq_fp16"
    PTQ_INT = "ptq_int"
    QAT = "qat"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration for the paper's quantizers.

    Attributes:
      mode: which quantization regime is active.
      bits: integer bitwidth for PTQ_INT / QAT (paper sweeps 2..8).
      quant_delay: number of *training updates* run in full precision while the
        min/max observers monitor ranges (paper: ``quant_delay`` in
        tf.contrib.quantize; 500k env steps for Atari DQN). After the delay the
        monitored ranges freeze and fake quantization turns on.
      ema_decay: decay for the exponential-moving-average min/max observers used
        during the monitoring phase.
      quantize_activations: QAT quantizes activations as well as weights
        (paper Sec. 3.2); PTQ quantizes weights only (Sec. 3.1).
      per_axis_conv: per-output-channel quantization for convolution kernels
        (paper: "per-axis" for conv, per-tensor for fully connected).
      quantize_router: whether MoE router / gating layers are quantized
        (default False: small, numerically sensitive).
      int8_kv_cache: beyond-paper — store decode KV cache as int8 + scales.
    """

    mode: QuantMode = QuantMode.NONE
    bits: int = 8
    quant_delay: int = 0
    ema_decay: float = 0.999
    quantize_activations: bool = True
    per_axis_conv: bool = True
    quantize_router: bool = False
    int8_kv_cache: bool = False

    # ---- constructors -----------------------------------------------------
    @staticmethod
    def none() -> "QuantConfig":
        return QuantConfig(mode=QuantMode.NONE)

    @staticmethod
    def ptq_fp16() -> "QuantConfig":
        return QuantConfig(mode=QuantMode.PTQ_FP16, quantize_activations=False)

    @staticmethod
    def ptq_int(bits: int = 8) -> "QuantConfig":
        return QuantConfig(mode=QuantMode.PTQ_INT, bits=bits,
                           quantize_activations=False)

    @staticmethod
    def qat(bits: int = 8, quant_delay: int = 0,
            quantize_activations: bool = True) -> "QuantConfig":
        return QuantConfig(mode=QuantMode.QAT, bits=bits,
                           quant_delay=quant_delay,
                           quantize_activations=quantize_activations)

    @staticmethod
    def parse(spec: str) -> "QuantConfig":
        """Parse a CLI spec: none | ptq_fp16 | ptq_int8 | ptq_int4 | qat8 | qat4:delay=1000."""
        spec = spec.strip().lower()
        if spec in ("none", "fp32", "full"):
            return QuantConfig.none()
        if spec in ("ptq_fp16", "fp16"):
            return QuantConfig.ptq_fp16()
        if spec.startswith("ptq_int"):
            return QuantConfig.ptq_int(int(spec[len("ptq_int"):]))
        if spec.startswith("qat"):
            body = spec[len("qat"):]
            delay = 0
            if ":" in body:
                body, opts = body.split(":", 1)
                for kv in opts.split(","):
                    k, v = kv.split("=")
                    if k == "delay":
                        delay = int(v)
            return QuantConfig.qat(int(body), quant_delay=delay)
        raise ValueError(f"unknown quant spec: {spec!r}")

    # ---- predicates --------------------------------------------------------
    @property
    def is_qat(self) -> bool:
        return self.mode == QuantMode.QAT

    @property
    def is_ptq(self) -> bool:
        return self.mode in (QuantMode.PTQ_FP16, QuantMode.PTQ_INT)

    @property
    def enabled(self) -> bool:
        return self.mode != QuantMode.NONE

    def label(self) -> str:
        if self.mode == QuantMode.NONE:
            return "fp32"
        if self.mode == QuantMode.PTQ_FP16:
            return "ptq_fp16"
        if self.mode == QuantMode.PTQ_INT:
            return f"ptq_int{self.bits}"
        return f"qat{self.bits}"


@dataclasses.dataclass(frozen=True)
class MixedPrecisionConfig:
    """Mixed/half-precision training policy (paper Sec. 5, Micikevicius et al.).

    ``compute_dtype`` is used for activations/matmuls, ``param_dtype`` is the
    master-weight dtype, loss scaling guards fp16 gradient underflow (bf16 does
    not need it; it is kept for paper fidelity with fp16).
    """

    compute_dtype: str = "float32"   # "bfloat16" | "float16" | "float32"
    param_dtype: str = "float32"
    loss_scale: Optional[float] = None     # static scale; None = no scaling
    dynamic_loss_scale: bool = False       # dynamic scaling overrides static

    @property
    def enabled(self) -> bool:
        return self.compute_dtype != self.param_dtype

    @staticmethod
    def fp32() -> "MixedPrecisionConfig":
        return MixedPrecisionConfig()

    @staticmethod
    def bf16() -> "MixedPrecisionConfig":
        return MixedPrecisionConfig(compute_dtype="bfloat16")

    @staticmethod
    def fp16() -> "MixedPrecisionConfig":
        return MixedPrecisionConfig(compute_dtype="float16",
                                    dynamic_loss_scale=True)
