"""Mixed/half-precision training (QuaRL Sec. 5 case study; Micikevicius 2017).

Master weights stay fp32; the forward/backward pass runs in a compute dtype
(bf16 on TPU; fp16 with loss scaling for paper fidelity). ``DynamicLossScale``
implements the standard doubling/halving schedule: halve on non-finite grads
and skip the update, double every ``growth_interval`` clean steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import MixedPrecisionConfig

PyTree = Any


def cast_floating(tree: PyTree, dtype) -> PyTree:
    def one(x):
        if isinstance(x, (jnp.ndarray, jax.Array)) and jnp.issubdtype(
                x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(one, tree)


def to_compute(params: PyTree, mp: MixedPrecisionConfig) -> PyTree:
    if not mp.enabled:
        return params
    return cast_floating(params, jnp.dtype(mp.compute_dtype))


class DynamicLossScale(NamedTuple):
    scale: jnp.ndarray          # f32 scalar
    good_steps: jnp.ndarray     # i32 scalar

    @staticmethod
    def init(initial: float = 2.0 ** 15) -> "DynamicLossScale":
        return DynamicLossScale(jnp.asarray(initial, jnp.float32),
                                jnp.zeros((), jnp.int32))


def all_finite(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]
    if not leaves:
        return jnp.ones((), jnp.bool_)
    return jnp.stack(leaves).all()


def scale_loss(loss: jnp.ndarray, ls: DynamicLossScale | None) -> jnp.ndarray:
    return loss if ls is None else loss * ls.scale.astype(loss.dtype)


def unscale_grads(grads: PyTree, ls: DynamicLossScale | None) -> PyTree:
    if ls is None:
        return grads
    inv = (1.0 / ls.scale)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * inv).astype(g.dtype), grads)


def update_loss_scale(ls: DynamicLossScale, grads_finite: jnp.ndarray,
                      growth_interval: int = 2000,
                      factor: float = 2.0,
                      min_scale: float = 1.0) -> DynamicLossScale:
    grew = ls.good_steps + 1 >= growth_interval
    new_scale = jnp.where(
        grads_finite,
        jnp.where(grew, ls.scale * factor, ls.scale),
        jnp.maximum(ls.scale / factor, min_scale))
    new_good = jnp.where(grads_finite & ~grew, ls.good_steps + 1, 0)
    return DynamicLossScale(new_scale, new_good)


def select_tree(pred: jnp.ndarray, on_true: PyTree, on_false: PyTree) -> PyTree:
    """Elementwise lax.select over matching pytrees (skip-update-on-NaN)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), on_true, on_false)
