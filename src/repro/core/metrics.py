"""Analysis metrics used by the paper's studies.

* Weight-distribution statistics (Fig. 3/4: distribution *width* predicts
  post-training-quantization error).
* Action-distribution variance (Fig. 1: exploration proxy under QAT).
* Relative reward error E = (fp32_reward - quant_reward) / |fp32_reward|
  (Tables 2, 5-8; negative error = quantized model outperformed fp32).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import affine

PyTree = Any


def weight_distribution_stats(params: PyTree) -> Dict[str, float]:
    """Width statistics of the concatenated weight distribution."""
    leaves = [np.asarray(x).ravel() for x in jax.tree_util.tree_leaves(params)
              if hasattr(x, "ndim") and getattr(x, "ndim", 0) >= 2
              and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]
    if not leaves:
        return {"range": 0.0, "std": 0.0, "min": 0.0, "max": 0.0, "p999": 0.0}
    w = np.concatenate(leaves)
    return {
        "range": float(w.max() - w.min()),
        "std": float(w.std()),
        "min": float(w.min()),
        "max": float(w.max()),
        "p999": float(np.quantile(np.abs(w), 0.999)),
    }


def mean_int8_weight_error(params: PyTree, bits: int = 8) -> float:
    """Mean abs affine-quantization error across weight tensors (Fig. 3)."""
    errs = []
    for x in jax.tree_util.tree_leaves(params):
        if hasattr(x, "ndim") and getattr(x, "ndim", 0) >= 2 and \
                jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            errs.append(float(affine.quantization_error(jnp.asarray(x), bits)))
    return float(np.mean(errs)) if errs else 0.0


def relative_error(fp32_reward: float, quant_reward: float) -> float:
    """Paper's E_% — positive means the quantized policy is worse."""
    denom = abs(fp32_reward) if fp32_reward != 0 else 1.0
    return 100.0 * (fp32_reward - quant_reward) / denom


def action_distribution_variance(logits: jnp.ndarray) -> jnp.ndarray:
    """Variance of the softmax action distribution (exploration proxy, Fig. 1).

    Lower variance over actions == flatter distribution == more exploration,
    per the paper's argument.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.var(probs, axis=-1).mean()


def ema(values, decay: float = 0.95):
    """Paper smooths action-variance curves with factor .95."""
    out, acc = [], None
    for v in values:
        acc = v if acc is None else decay * acc + (1 - decay) * v
        out.append(acc)
    return out
