"""Paper-faithful uniform affine quantization (QuaRL Sec. 3.1).

The paper defines, for an n-bit quantizer over a tensor W:

    delta = (|min(W, 0)| + |max(W, 0)|) / 2**n
    z     = round(-min(W, 0) / delta)
    Q(W)  = round(W / delta) + z
    D(q)  = delta * (q - z)

``min(W,0)``/``max(W,0)`` extend the range to always include zero so that zero
is exactly representable (required so that e.g. zero-padding and ReLU zeros are
exact). Quantized codes live in [0, 2**n - 1].

Per-tensor quantization is used for fully connected layers; per-axis
(output-channel) quantization for convolutions — both per the paper.

Faithfulness note: the paper divides the range by 2**n (not 2**n - 1), so the
top of the range maps to code 2**n, which clips to 2**n - 1 — edge values can
lose up to ~1.5*delta (vs 0.5*delta interior). We reproduce this exactly; the
property tests encode the 1.5*delta bound.

Everything here is pure jnp so it can serve as the oracle for the Pallas
kernels in ``repro.kernels`` and be fused inside jitted training steps.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class AffineParams(NamedTuple):
    """Quantizer parameters. ``delta`` and ``zero_point`` broadcast against W."""
    delta: jnp.ndarray       # step size (>0)
    zero_point: jnp.ndarray  # integer offset (stored as float for jax friendliness)
    bits: int


def _order_keys(i: jnp.ndarray) -> jnp.ndarray:
    """Self-inverse int32 transform of f32 bit patterns whose int ordering
    matches the float ordering (flip the magnitude bits of negatives)."""
    return i ^ ((i >> 31) & jnp.int32(0x7FFFFFFF))


def _range_including_zero(w: jnp.ndarray, axes: Optional[Sequence[int]]
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(min(W,0), max(W,0)) reduced over ``axes`` (None = all axes)."""
    keep = axes is not None
    if w.dtype == jnp.float32 and jax.default_backend() == "cpu":
        # XLA:CPU lowers float min/max reductions to a slow scalar loop
        # (~7x its integer reductions — this range pass dominated the
        # dynamic-quantization cost of the int8 actor hot path), so reduce
        # order-isomorphic int32 keys instead.  Exact for every finite
        # float: only the sign of a -0.0/0.0 tie and NaN propagation can
        # differ, neither of which changes the derived affine params.
        keys = _order_keys(jax.lax.bitcast_convert_type(w, jnp.int32))
        wmin = jax.lax.bitcast_convert_type(
            _order_keys(jnp.min(keys, axis=axes, keepdims=keep)),
            jnp.float32)
        wmax = jax.lax.bitcast_convert_type(
            _order_keys(jnp.max(keys, axis=axes, keepdims=keep)),
            jnp.float32)
    else:
        wmin = jnp.min(w, axis=axes, keepdims=keep)
        wmax = jnp.max(w, axis=axes, keepdims=keep)
    return jnp.minimum(wmin, 0.0), jnp.maximum(wmax, 0.0)


def affine_params_from_range(wmin: jnp.ndarray, wmax: jnp.ndarray,
                             bits: int) -> AffineParams:
    """Paper's delta/z from a (min,max) range. Range is first extended to 0."""
    wmin = jnp.minimum(wmin, 0.0)
    wmax = jnp.maximum(wmax, 0.0)
    n_levels = 2.0 ** bits
    delta = (jnp.abs(wmin) + jnp.abs(wmax)) / n_levels
    # Degenerate all-zero tensor: delta == 0. Use 1.0 so Q(0)=z, D(z)=0 exactly.
    delta = jnp.where(delta == 0.0, 1.0, delta)
    zero_point = jnp.round(-wmin / delta)
    return AffineParams(delta=delta, zero_point=zero_point, bits=bits)


def compute_affine_params(w: jnp.ndarray, bits: int,
                          axis: Optional[int] = None) -> AffineParams:
    """Per-tensor (axis=None) or per-axis (quantization axis kept) params."""
    if axis is None:
        wmin, wmax = _range_including_zero(w, None)
    else:
        axis = axis % w.ndim
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis)
        wmin, wmax = _range_including_zero(w, reduce_axes)
    return affine_params_from_range(wmin, wmax, bits)


def quantize(w: jnp.ndarray, params: AffineParams) -> jnp.ndarray:
    """W -> integer codes in [0, 2**bits - 1] (returned as float dtype of W)."""
    q = jnp.round(w / params.delta) + params.zero_point
    return jnp.clip(q, 0.0, 2.0 ** params.bits - 1.0)


def dequantize(q: jnp.ndarray, params: AffineParams) -> jnp.ndarray:
    return params.delta * (q - params.zero_point)


def quantize_dequantize(w: jnp.ndarray, params: AffineParams) -> jnp.ndarray:
    """The paper's Q followed by D — the "fake quantization" value map."""
    return dequantize(quantize(w, params), params)


def ptq_tensor(w: jnp.ndarray, bits: int, axis: Optional[int] = None
               ) -> jnp.ndarray:
    """One-shot post-training quantize-dequantize of a tensor (Algorithm 1)."""
    return quantize_dequantize(w, compute_affine_params(w, bits, axis))


def quantize_to_int(w: jnp.ndarray, bits: int, axis: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, AffineParams]:
    """Quantize and pack into the narrowest integer dtype (deployment path)."""
    params = compute_affine_params(w, bits, axis)
    q = quantize(w, params)
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    # int8 holds [0,255]? No — shift to signed storage: store q - 2**(bits-1).
    offset = 2.0 ** (bits - 1)
    q_signed = (q - offset).astype(dtype)
    shifted = AffineParams(delta=params.delta,
                           zero_point=params.zero_point - offset,
                           bits=bits)
    return q_signed, shifted


def dequantize_from_int(q: jnp.ndarray, params: AffineParams,
                        dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    return (params.delta * (q.astype(dtype) - params.zero_point)).astype(dtype)


def quantize_with_params(w: jnp.ndarray, params: AffineParams
                         ) -> jnp.ndarray:
    """Quantize with *precomputed* signed-storage params (static requant).

    ``params`` must be the shifted form produced by ``quantize_to_int`` /
    ``calibration_params`` (zero_point offset by ``-2**(bits-1)`` so codes
    store signed).  With params computed from the same tensor this is
    bit-identical to ``quantize_to_int(w, bits)[0]`` — the contract behind
    the fused kernel's static-requant anchor: clip(round(w/delta) + z, 0,
    2**b - 1) - 2**(b-1) == clip(round(w/delta) + (z - 2**(b-1)),
    -2**(b-1), 2**(b-1) - 1).
    """
    half = 2.0 ** (params.bits - 1)
    q = jnp.round(w / params.delta) + params.zero_point
    dtype = jnp.int8 if params.bits <= 8 else jnp.int16
    return jnp.clip(q, -half, half - 1.0).astype(dtype)


def calibration_params(w: jnp.ndarray, bits: int = 8) -> AffineParams:
    """Signed-storage activation params from a calibration batch.

    The static-requant helper behind the fused actor kernel: the affine
    params ``quantize_to_int`` would derive from ``w`` (paper formula,
    range extended to zero) in the shifted signed form, WITHOUT quantizing
    — cache these once per sync, then ``quantize_with_params`` replaces the
    per-call dynamic min/max pass.
    """
    params = compute_affine_params(w, bits, axis=None)
    offset = 2.0 ** (bits - 1)
    return AffineParams(delta=params.delta,
                        zero_point=params.zero_point - offset, bits=bits)


# ---------------------------------------------------------------------------
# Sub-8-bit storage: two int4 codes per int8 byte
# ---------------------------------------------------------------------------

def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack signed int4 codes (values in [-8, 7], stored int8) pairwise.

    Packs along axis 0 (the GEMM contraction axis): rows ``2i`` go to the
    low nibble, rows ``2i+1`` to the high nibble of one int8 byte —
    ``(K, N) -> (ceil(K/2), N)``.  An odd K is zero-padded; consumers mask
    rows ``>= K`` (zero codes are already masked out of the kernels'
    zero-point corrections by the true-K contract).
    """
    k = codes.shape[0]
    if k % 2:
        pad = [(0, 1)] + [(0, 0)] * (codes.ndim - 1)
        codes = jnp.pad(codes, pad)
    lo = codes[0::2].astype(jnp.uint8) & 0xF
    hi = codes[1::2].astype(jnp.uint8) & 0xF
    # same-width bitcast, not a value convert: 0x80..0xFF must become the
    # negative byte patterns, which int astype leaves implementation-defined
    return (lo | (hi << 4)).view(jnp.int8)


def unpack_int4(packed: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of ``pack_int4``: ``(ceil(K/2), N) -> (K, N)`` int8 codes.

    Sign-extends each nibble via a left-then-arithmetic-right shift pair —
    pure jnp, so it runs unchanged inside Pallas kernels (the in-kernel
    unpack of the W4A8 GEMMs) and in the ref oracles.
    """
    lo = packed.astype(jnp.int8) << 4
    lo = lo >> 4                           # arithmetic shift: sign-extended
    hi = packed.astype(jnp.int8) >> 4
    both = jnp.stack([lo, hi], axis=1)     # (Kp, 2, ...)
    out = both.reshape((-1,) + packed.shape[1:])
    return out[:k]


def quantize_symmetric(x: jnp.ndarray, axis: int = -1
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-slice int8 quantization (the KV-cache token quantizer).

    Reduces ``|x|`` over ``axis`` (keepdims) and maps the slice onto
    [-127, 127] with ``scale = amax / 127`` (an all-zero slice gets scale 1
    so its codes are exactly zero).  Returns ``(codes int8, scale f32)``
    with ``scale`` broadcastable against ``x``; dequantization is
    ``codes * scale``.

    This is the *symmetric* (zero-point-free) companion to the affine
    scheme above — attention caches quantize per token where a zero-point
    correction would put an extra (T,)-shaped term inside the attention
    kernel for no range benefit (K/V activations are roughly centered).
    It is the single source of truth for KV-cache codes:
    ``models.attention.cache_update`` and the ActorQ sequence actors
    (``rl.actorq``) both call it, and the regression test
    ``tests/test_seq_policy.py::test_symmetric_quantizer_matches_legacy``
    pins it bitwise to the formula ``models/attention.py`` used before the
    merge (amax/127 scale, round, clip to [-127, 127]).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                     ).astype(jnp.int8)
    return codes, scale


def fp16_quantize(w: jnp.ndarray) -> jnp.ndarray:
    """IEEE-754 fp16 round-trip (paper's Q_fp16)."""
    return w.astype(jnp.float16).astype(w.dtype)


def quantization_error(w: jnp.ndarray, bits: int,
                       axis: Optional[int] = None) -> jnp.ndarray:
    """Mean absolute quantization error — used by the weight-distribution study."""
    return jnp.mean(jnp.abs(w - ptq_tensor(w, bits, axis)))
