"""Mixture-of-Experts feed-forward (mixtral-8x7b, grok-1: 8 experts, top-2).

GShard-style capacity-based dispatch so every shape is static under pjit:

  router logits (fp32, never quantized by default — small and sensitive)
  -> top-k expert choice + normalized weights
  -> position-in-expert via cumsum; tokens beyond ``capacity`` are dropped
  -> dispatch einsum to (experts, capacity, d) slots
  -> per-expert SwiGLU FFN (expert weights stacked on a leading axis; the
     d_ff dimension is tensor-parallel over the 'model' mesh axis)
  -> combine einsum back with routing weights.

The auxiliary load-balance loss (Switch/Mixtral form: E * Σ_e f_e · p_e) is
returned so the trainer can add it to the task loss.

Tokens are processed in groups (seq chunks) to bound the dispatch one-hot
tensor at (groups, group_size, experts * capacity) — the classic GShard
grouping trade-off.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import P, dense_spec


def moe_spec(d_model: int, d_ff: int, n_experts: int) -> Dict[str, Any]:
    return {
        "router": dense_spec(d_model, n_experts, "embed", None),
        "wi": {"w": P((n_experts, d_model, d_ff),
                      ("expert", "embed", "moe_mlp"))},
        "wg": {"w": P((n_experts, d_model, d_ff),
                      ("expert", "embed", "moe_mlp"))},
        "wo": {"w": P((n_experts, d_ff, d_model),
                      ("expert", "moe_mlp", "embed"))},
    }


def moe_ffn(ctx, params, x: jnp.ndarray, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, group_size: int = 512,
            activation: str = "silu", quantize_router: bool = False,
            name: str = "moe") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    tokens = b * s
    group_size = min(group_size, tokens)
    assert tokens % group_size == 0, (tokens, group_size)
    n_groups = tokens // group_size
    capacity = int(capacity_factor * top_k * group_size / n_experts)
    capacity = max(capacity, top_k)

    # Groups follow the batch sharding: constrain x to batch-only (undoes the
    # inter-block sequence-parallel layout so the (b,s)->(g,s_g) reshape is a
    # local reshape, not an involuntary full rematerialization).
    from jax.sharding import PartitionSpec as _PS
    x = common.with_constraint(x, _PS("data", None, None))
    xg = x.reshape(n_groups, group_size, d)
    xg = common.with_constraint(xg, _PS("data", None, None))

    # Router in fp32 (optionally quantized — off by default, see DESIGN.md).
    rw = params["router"]["w"]
    if quantize_router:
        rw = ctx.weight(f"{name}/router", rw)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        rw.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (g, s, e)

    # top-k choice; weights renormalized over the chosen experts (Mixtral).
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (g, s, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # one-hot (g, s, k, e); position of each token within its expert queue
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32)
    pos_in_expert = (jnp.cumsum(onehot.reshape(n_groups, group_size * top_k,
                                               n_experts), axis=1)
                     .reshape(n_groups, group_size, top_k, n_experts) - 1.0)
    keep = (pos_in_expert < capacity) * onehot                 # drop overflow
    pos = jnp.sum(pos_in_expert * keep, axis=-1)               # (g, s, k)

    # combine[g, s, e, c] = gate weight if token s went to slot (e, c)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                            dtype=jnp.float32)                 # (g,s,k,c)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, keep, pos_oh)
    from jax.sharding import PartitionSpec as _PS
    combine = common.with_constraint(combine, _PS("data", None, None, None))
    dispatch = (combine > 0.0).astype(x.dtype)                 # (g,s,e,c)

    # load-balance auxiliary loss: E * sum_e fraction_e * prob_e
    frac = jnp.mean(jnp.sum(onehot[:, :, 0, :], axis=1)
                    / group_size, axis=0)                      # top-1 fraction
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(frac * mean_prob)

    # dispatch -> expert FFN -> combine. Expert-buffer activations are
    # explicitly sharded: token groups over the data axes, the expert hidden
    # dim over 'model' (matching the tensor-parallel expert weights) —
    # without these the (e, g, c, d) buffers replicate across 'model'.
    from jax.sharding import PartitionSpec as PS
    data = "data"
    hid_spec = PS(None, data, None, "model")

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)            # (e,g,c,d)
    # NB: xe/ye deliberately carry NO sharding constraint — the f-contraction
    # produces partial sums over 'model', and the combine einsum is linear in
    # d, so GSPMD can defer the all-reduce to the (g,s,d) output (2.5x less
    # volume than reducing the (e,g,c,d) expert buffer; §Perf iteration A1).
    wi = ctx.weight(f"{name}/wi", params["wi"]["w"]).astype(x.dtype)
    wg = ctx.weight(f"{name}/wg", params["wg"]["w"]).astype(x.dtype)
    wo = ctx.weight(f"{name}/wo", params["wo"]["w"]).astype(x.dtype)
    h = jnp.einsum("egcd,edf->egcf", xe, wi)
    gate = jnp.einsum("egcd,edf->egcf", xe, wg)
    act = jax.nn.silu(gate) if activation == "silu" else jax.nn.gelu(gate)
    h = ctx.activation(f"{name}/h", h * act)
    h = common.with_constraint(h, hid_spec)
    # (§Perf A2, REFUTED: combining over capacity before the wo contraction
    # — einsum('gsec,egcf->gsef') then ('gsef,efd->gsd') — shrinks the
    # all-reduce but recomputes wo over s instead of the c=cf·k·s/e capacity
    # slots: 3.2x more matmul FLOPs. Reverted; see EXPERIMENTS.md §Perf.)
    ye = jnp.einsum("egcf,efd->egcd", h, wo)                   # (e,g,c,d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    y = common.with_constraint(y, _PS("data", None, None))
    return y.reshape(b, s, d), aux.astype(jnp.float32)
