"""Small decoder-transformer policy for partially-observed RL.

The merged-model layer (ROADMAP item 5): a pre-norm decoder transformer
sized for RL actors (a few thousand params, single-head attention) whose
parameters pack through the same ``core.ptq.PackedTensor`` machinery as
the MLP/CNN actors and whose decode path runs on the int8 KV cache
through ``kernels.ops.int8_cache_attention`` (see ``rl.actorq``).

Observation contract (produced by ``rl.envs.wrappers.make_framestack``):
``obs`` is ``(..., context, feat)`` — a causal window of per-step feature
rows, oldest first, newest last.  Each row is ``[inner_obs..., t /
max_steps, valid]``; the trailing ``valid`` flag masks rows that predate
the episode (the frame stack is zero-initialized at reset), and the
normalized time feature is the only positional signal — rows are
*shifted* between successive observations, so row-index positional
encodings would be inconsistent; an in-row time feature is shift-stable.
That shift-stability is exactly what makes the windowed form below and
the incremental KV-cache form (``rl.actorq.quantized_seq_step``) agree:
both attend over the same token set with the same per-token features.

Two equivalent evaluation forms:

* ``seq_apply(ctx, params, obs)`` — windowed: full self-attention over
  the ``context`` rows, head on the newest row.  Used by the fp32
  learner (TD targets, gradients), fp32 behaviour policies, eval, and
  the stateless ``rl.actorq.quantized_seq_apply`` int8 mirror.
* per-step decode with a carried KV cache — one token in, cache write,
  masked attention over previous slots.  Lives in ``rl.actorq``
  (``quantized_seq_step``) since it is the deployment hot path.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import P

NEG_INF = -1e30


class SeqPolicyConfig(NamedTuple):
    """Static shape/config record carried on ``rl.networks.Network.seq_cfg``.

    ``context``/``feat_dim`` mirror the env's ``obs_shape = (context,
    feat_dim)``; the rest size the transformer.  ``n_layers`` and
    ``d_model`` are what ``rl.actorq`` needs to build the per-env KV-cache
    actor state (``seq_cache_zeros``).
    """
    context: int
    feat_dim: int
    d_model: int
    n_layers: int
    d_ff: int
    out_dim: int


def _dense_spec(d_in: int, d_out: int, scale=None) -> Dict[str, P]:
    return {"w": P((d_in, d_out), (None, None), scale=scale),
            "b": P((d_out,), (None,), init="zeros")}


def _dense(ctx, name, params, x, act=None):
    w = ctx.weight(f"{name}/w", params["w"])
    y = x @ w.astype(x.dtype) + params["b"].astype(x.dtype)
    if act is not None:
        y = act(y)
    return ctx.activation(f"{name}/out", y)


def seq_spec(cfg: SeqPolicyConfig) -> Dict[str, Any]:
    """Parameter spec tree for the decoder-transformer policy.

    Top-level keys are the packing/dispatch contract with ``rl.actorq``:
    ``"embed"`` marks the tree as a sequence policy (``quantized_apply``
    dispatches on it), ``"blk{i}"`` holds each block's q/k/v/o and
    fc/proj dense layers plus the (never-packed, 1-D) rms-norm gains, and
    ``"head"`` is the output projection applied to the newest token.
    Every 2-D weight packs to int8/int4 codes under
    ``actorq.pack_actor_params``; biases and norm gains stay fp32.
    """
    d, f = cfg.d_model, cfg.d_ff
    spec: Dict[str, Any] = {"embed": _dense_spec(cfg.feat_dim, d)}
    for i in range(cfg.n_layers):
        spec[f"blk{i}"] = {
            "ln1": common.rms_norm_spec(d),
            "q": _dense_spec(d, d),
            "k": _dense_spec(d, d),
            "v": _dense_spec(d, d),
            "o": _dense_spec(d, d),
            "ln2": common.rms_norm_spec(d),
            "fc": _dense_spec(d, f),
            "proj": _dense_spec(f, d),
        }
    spec["head"] = _dense_spec(d, cfg.out_dim, scale=0.01)
    return spec


def valid_mask(obs: jnp.ndarray) -> jnp.ndarray:
    """(..., S) row-validity mask from the trailing per-row valid flag."""
    return obs[..., -1] > 0.5


def seq_apply(ctx, params, obs: jnp.ndarray, cfg: SeqPolicyConfig
              ) -> jnp.ndarray:
    """Windowed fp32 forward: obs (..., context, feat) -> (..., out_dim).

    Causal single-head self-attention over the frame rows with invalid
    (pre-episode) rows masked out of the key set; the head reads the
    newest row only.  Arbitrary leading batch dims.
    """
    s = obs.shape[-2]
    x = _dense(ctx, "embed", params["embed"], obs)          # (..., S, D)
    valid = valid_mask(obs)                                 # (..., S)
    causal = jnp.tril(jnp.ones((s, s), bool))
    mask = causal & valid[..., None, :]                     # (..., S, S)
    scale = cfg.d_model ** -0.5
    for i in range(cfg.n_layers):
        blk = params[f"blk{i}"]
        h = common.rms_norm(blk["ln1"], x)
        q = _dense(ctx, f"blk{i}/q", blk["q"], h)
        k = _dense(ctx, f"blk{i}/k", blk["k"], h)
        v = _dense(ctx, f"blk{i}/v", blk["v"], h)
        logits = jnp.einsum("...sd,...td->...st",
                            q.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        a = jnp.einsum("...st,...td->...sd", p,
                       v.astype(jnp.float32)).astype(x.dtype)
        x = x + _dense(ctx, f"blk{i}/o", blk["o"], a)
        h2 = common.rms_norm(blk["ln2"], x)
        y = _dense(ctx, f"blk{i}/fc", blk["fc"], h2, act=jax.nn.relu)
        x = x + _dense(ctx, f"blk{i}/proj", blk["proj"], y)
    return _dense(ctx, "head", params["head"], x[..., -1, :])


def make_seq_policy(obs_shape: Tuple[int, int], out_dim: int, *,
                    d_model: int = 32, n_layers: int = 2, d_ff: int = 64
                    ) -> Tuple[Dict[str, Any], Any, SeqPolicyConfig]:
    """(spec, apply_fn, cfg) for a frame-stacked env's ``(S, F)`` obs.

    ``rl.networks.make_network(..., transformer={...})`` wraps this into
    a ``Network``; the returned ``cfg`` rides on ``Network.seq_cfg`` so
    the RL layer can build matching KV-cache actor state.
    """
    if len(obs_shape) != 2:
        raise ValueError("sequence policies need obs_shape (context, "
                         f"feat), got {obs_shape}")
    cfg = SeqPolicyConfig(context=int(obs_shape[0]),
                          feat_dim=int(obs_shape[1]), d_model=d_model,
                          n_layers=n_layers, d_ff=d_ff, out_dim=out_dim)

    def apply_fn(ctx, params, obs):
        return seq_apply(ctx, params, obs, cfg)

    return seq_spec(cfg), apply_fn, cfg
