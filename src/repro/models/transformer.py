"""The full language model: embed -> scanned block pattern -> norm -> head.

Layer stacking
--------------
``cfg.pattern`` is the repeating unit of block kinds (e.g. gemma2's
``(attn_local, attn)``, llama-3.2-vision's ``(attn, attn, attn, attn,
cross)``). Parameters for all repeats are stacked on a leading "layers" axis
and applied with ``lax.scan`` — one HLO body regardless of depth, which keeps
compile time and code size bounded for 40-100 layer configs. A remainder
(``n_layers % len(pattern)``) is applied unrolled.

QAT observers inside the scan are carried through the scan state (one
observer slot per site name, shared across repeats — see DESIGN.md; the
RL-study networks are unscanned and get exact per-layer observers).

Modes
-----
* ``forward(...)``                      — logits for a full sequence (train).
* ``loss_fn(...)``                      — seq-chunked cross-entropy (+ MoE aux).
* ``prefill(...)``                      — hidden pass returning last-token
                                          logits (prefill_32k dry-run shape).
* ``decode_step(...)``                  — one token through per-layer caches.
* ``init_caches(...)``                  — decode state for a context length.

Encoder (whisper) / vision (llama-3.2-vision) frontends are STUBS per the
assignment: ``encoder_out`` arrives as precomputed frame/patch embeddings;
whisper additionally runs its transformer *encoder* stack over them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs import base as cfgs
from repro.core import fake_quant
from repro.core.qconfig import QuantConfig
from repro.models import attention, blocks, common
from repro.models.common import P

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _unit_spec(cfg: cfgs.ArchConfig) -> Dict[str, Any]:
    return {f"b{i}_{kind}": blocks.block_spec(kind, cfg)
            for i, kind in enumerate(cfg.pattern)}


def param_specs(cfg: cfgs.ArchConfig) -> Dict[str, Any]:
    spec: Dict[str, Any] = {
        "embed": {"w": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                         init="embed")},
        "final_norm": (common.rms_norm_spec(cfg.d_model) if cfg.norm == "rms"
                       else common.layer_norm_spec(cfg.d_model)),
        "layers": common.stack_specs(_unit_spec(cfg), cfg.pattern_repeats),
    }
    if cfg.pattern_remainder:
        spec["remainder"] = {
            f"r{i}_{kind}": blocks.block_spec(kind, cfg)
            for i, kind in enumerate(cfg.pattern_remainder)}
    if not cfg.tie_embeddings:
        spec["lm_head"] = {"w": P((cfg.d_model, cfg.vocab),
                                  ("embed", "vocab"))}
    if cfg.encoder_layers:
        spec["encoder"] = common.stack_specs(
            {"b0_attn": blocks.block_spec(cfgs.ATTN, cfg)},
            cfg.encoder_layers)
        spec["encoder_norm"] = (common.rms_norm_spec(cfg.d_model)
                                if cfg.norm == "rms"
                                else common.layer_norm_spec(cfg.d_model))
    return spec


def init_params(cfg: cfgs.ArchConfig, key: jax.Array,
                dtype=jnp.float32) -> PyTree:
    return common.init_params(key, param_specs(cfg), dtype)


def partition_specs(cfg: cfgs.ArchConfig, *, multi_pod: bool = False) -> PyTree:
    mesh_div = 32 if multi_pod else 16  # data-axis size for fsdp 'embed'

    def divisible(axis: str) -> bool:
        model = 16
        if axis == "vocab":
            return cfg.vocab % model == 0
        if axis == "heads":
            return (cfg.n_heads * cfg.hd) % model == 0
        if axis == "kv":
            return (cfg.n_kv_heads * cfg.hd) % model == 0
        if axis in ("mlp", "moe_mlp"):
            return cfg.d_ff % model == 0 if cfg.d_ff else False
        if axis == "embed":
            return cfg.d_model % mesh_div == 0
        return True

    rules = common.sharding_rules(cfg.sharding, multi_pod=multi_pod,
                                  divisible=divisible)
    return common.partition_specs(param_specs(cfg), rules)


# ---------------------------------------------------------------------------
# QAT observer collection discovery
# ---------------------------------------------------------------------------

class _NameRecorder:
    """Trace-time context that records every activation site name."""

    enabled = False  # ctx contract: recording never applies quantization

    def __init__(self, config: QuantConfig):
        self.config = config
        self.names: set[str] = set()

    def weight(self, name: str, w):
        return w

    def activation(self, name: str, x):
        self.names.add(name)
        return x

    def merged_collection(self):
        return {}


def qat_site_names(cfg: cfgs.ArchConfig, *, scan_sites: bool = True
                   ) -> Tuple[set, set]:
    """Discover activation-observer site names (inside vs outside the scan)."""
    rec_in, rec_out = _NameRecorder(cfg.quant), _NameRecorder(cfg.quant)

    def run():
        params = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32),
            param_specs(cfg), is_leaf=lambda x: isinstance(x, P))
        tokens = jnp.zeros((1, max(len(cfg.pattern), 2)), jnp.int32)
        enc = (jnp.zeros((1, 4, cfg.d_model), jnp.float32)
               if (cfg.cross_attn or cfg.encoder_layers) else None)
        forward(cfg, params, tokens, ctx_in=rec_in, ctx_out=rec_out,
                encoder_out=enc)
        return ()

    jax.eval_shape(run)
    return rec_in.names, rec_out.names


def init_qat_collection(cfg: cfgs.ArchConfig) -> Dict[str, Any]:
    inside, outside = qat_site_names(cfg)
    return {name: fake_quant.ObserverState.init()
            for name in sorted(inside | outside)}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _batch_constraint(x: jnp.ndarray, multi_pod: bool) -> jnp.ndarray:
    """Activation sharding between blocks: batch over the data axes, and —
    sequence parallelism — the seq dim over 'model' when divisible. This
    bounds the lax.scan carry (and remat residuals) at 40-100 layers: the
    (B, S, D) carry is fully sharded instead of model-axis-replicated."""
    axes = ("pod", "data") if multi_pod else "data"
    seq = "model" if (x.ndim == 3 and x.shape[1] % 16 == 0
                      and x.shape[1] > 1) else None
    return common.with_constraint(
        x, PartitionSpec(axes, seq, *([None] * (x.ndim - 2))))


def _embed(cfg, ctx, params, tokens):
    w = params["embed"]["w"]
    x = jnp.take(w, tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return ctx.activation("embed/out", x)


def _head(cfg, ctx, params, x):
    if cfg.tie_embeddings:
        w = ctx.weight("lm_head/w", params["embed"]["w"])
        logits = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))
    else:
        w = ctx.weight("lm_head/w", params["lm_head"]["w"])
        logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    return logits


def _make_ctx(cfg, collection, step):
    return fake_quant.make_context(cfg.quant, collection, step)


def _run_encoder(cfg, params, ctx, encoder_out):
    """Whisper: run the transformer encoder over stub frame embeddings."""
    if not cfg.encoder_layers:
        return encoder_out

    def enc_unit(x, layer_params):
        h = common.rms_norm(layer_params["b0_attn"]["norm1"], x) \
            if cfg.norm == "rms" else \
            common.layer_norm(layer_params["b0_attn"]["norm1"], x)
        h, _ = attention.attention_layer(
            ctx, layer_params["b0_attn"]["attn"], h, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=False,
            rope_theta=cfg.rope_theta, name="enc/attn")
        x = x + h
        h = common.rms_norm(layer_params["b0_attn"]["norm2"], x) \
            if cfg.norm == "rms" else \
            common.layer_norm(layer_params["b0_attn"]["norm2"], x)
        x = x + blocks.mlp(ctx, layer_params["b0_attn"]["mlp"], h,
                           cfg.activation, name="enc/mlp")
        return x, None

    x, _ = jax.lax.scan(lambda c, p: enc_unit(c, p), encoder_out,
                        params["encoder"])
    norm = (common.rms_norm if cfg.norm == "rms" else common.layer_norm)
    return norm(params["encoder_norm"], x)


def forward(cfg: cfgs.ArchConfig, params: PyTree, tokens: jnp.ndarray, *,
            qat_collection: Optional[Dict] = None, step=0,
            encoder_out: Optional[jnp.ndarray] = None,
            multi_pod: bool = False,
            ctx_in=None, ctx_out=None,
            return_hidden: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """Full-sequence forward. Returns (logits_or_hidden, aux_loss, new_qat).

    ``ctx_in``/``ctx_out`` override the QAT contexts (used by name discovery);
    ``ctx_in`` is used inside the scanned units, ``ctx_out`` outside.
    """
    collection = qat_collection or {}
    inside_coll = {k: v for k, v in collection.items() if k.startswith("unit/")}
    outside_coll = {k: v for k, v in collection.items()
                    if not k.startswith("unit/")}
    ctx_out = ctx_out or _make_ctx(cfg, outside_coll, step)

    x = _embed(cfg, ctx_out, params, tokens)
    x = _batch_constraint(x, multi_pod)
    if encoder_out is not None:
        encoder_out = _run_encoder(cfg, params, ctx_out, encoder_out)

    def unit_fn(carry, layer_params):
        x, obs, aux = carry
        ctx = ctx_in or _make_ctx(cfg, obs, step)
        for i, kind in enumerate(cfg.pattern):
            x, _, a = blocks.apply_block(
                kind, cfg, ctx, layer_params[f"b{i}_{kind}"], x,
                encoder_out=encoder_out, name=f"unit/b{i}")
            aux = aux + a
        x = _batch_constraint(x, multi_pod)
        new_obs = obs if ctx_in is not None else ctx.merged_collection()
        return (x, new_obs, aux), None

    if cfg.remat:
        unit_fn = jax.checkpoint(unit_fn)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, inside_coll, aux), _ = jax.lax.scan(
            unit_fn, (x, inside_coll, aux0), params["layers"])
    else:
        carry = (x, inside_coll, aux0)
        for li in range(cfg.pattern_repeats):
            unit = jax.tree_util.tree_map(lambda p: p[li], params["layers"])
            carry, _ = unit_fn(carry, unit)
        x, inside_coll, aux = carry

    for i, kind in enumerate(cfg.pattern_remainder):
        ctx_r = ctx_in or _make_ctx(cfg, inside_coll, step)
        x, _, a = blocks.apply_block(
            kind, cfg, ctx_r, params["remainder"][f"r{i}_{kind}"], x,
            encoder_out=encoder_out, name=f"unit/b{i}")
        if ctx_in is None:
            inside_coll = ctx_r.merged_collection()
        aux = aux + a

    norm = (common.rms_norm if cfg.norm == "rms" else common.layer_norm)
    x = norm(params["final_norm"], x)
    if return_hidden:
        out = x
    else:
        out = _head(cfg, ctx_out, params, x)
    new_coll = {**ctx_out.merged_collection(), **inside_coll}
    return out, aux, new_coll


# ---------------------------------------------------------------------------
# Loss (sequence-chunked cross entropy)
# ---------------------------------------------------------------------------

def loss_fn(cfg: cfgs.ArchConfig, params: PyTree, batch: Dict[str, jnp.ndarray],
            *, qat_collection=None, step=0, multi_pod: bool = False,
            ce_chunk: int = 256, aux_weight: float = 0.01
            ) -> Tuple[jnp.ndarray, Dict]:
    """Causal-LM loss. ``batch`` = {"tokens": (B,S) int32, "labels": (B,S)}.

    The lm-head matmul + log-softmax is computed in sequence chunks under
    jax.checkpoint so the (B, S, vocab) logits tensor never materializes —
    required for the 256k-vocab configs at 4k×256 tokens.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    encoder_out = batch.get("encoder_out")
    hidden, aux, new_coll = forward(
        cfg, params, tokens, qat_collection=qat_collection, step=step,
        encoder_out=encoder_out, multi_pod=multi_pod, return_hidden=True)

    ctx = _make_ctx(cfg, {k: v for k, v in (qat_collection or {}).items()
                          if not k.startswith("unit/")}, step)

    b, s, d = hidden.shape
    ce_chunk = min(ce_chunk, s)
    n_chunks = s // ce_chunk if s % ce_chunk == 0 else 1
    if s % ce_chunk != 0:
        ce_chunk = s

    @jax.checkpoint
    def chunk_loss(h_chunk, y_chunk):
        logits = _head(cfg, ctx, params, h_chunk).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_chunk[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    h_chunks = jnp.moveaxis(hidden.reshape(b, n_chunks, ce_chunk, d), 1, 0)
    y_chunks = jnp.moveaxis(labels.reshape(b, n_chunks, ce_chunk), 1, 0)
    total = jax.lax.map(lambda hy: chunk_loss(*hy), (h_chunks, y_chunks))
    loss = jnp.sum(total) / (b * s)
    metrics = {"ce_loss": loss, "aux_loss": aux,
               "qat_collection": new_coll}
    return loss + aux_weight * aux, metrics


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(cfg: cfgs.ArchConfig, params: PyTree, tokens: jnp.ndarray, *,
            encoder_out: Optional[jnp.ndarray] = None,
            multi_pod: bool = False) -> jnp.ndarray:
    """Prompt pass returning last-token logits (inference-prefill shape)."""
    hidden, _, _ = forward(cfg, params, tokens, encoder_out=encoder_out,
                           multi_pod=multi_pod, return_hidden=True)
    ctx = _make_ctx(cfg, {}, 0)
    return _head(cfg, ctx, params, hidden[:, -1:])


def init_caches(cfg: cfgs.ArchConfig, batch: int, seq_len: int, *,
                int8: Optional[bool] = None, dtype=jnp.bfloat16) -> PyTree:
    """Decode-state pytree: stacked over pattern repeats + remainder list."""
    int8 = cfg.quant.int8_kv_cache if int8 is None else int8

    def unit_cache():
        return {f"b{i}_{kind}": blocks.init_block_cache(
                    kind, cfg, batch, seq_len, int8=int8, dtype=dtype)
                for i, kind in enumerate(cfg.pattern)}

    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[unit_cache() for _ in range(cfg.pattern_repeats)]) \
        if cfg.pattern_repeats > 1 else jax.tree_util.tree_map(
            lambda x: x[None], unit_cache())
    remainder = [blocks.init_block_cache(kind, cfg, batch, seq_len,
                                         int8=int8, dtype=dtype)
                 for kind in cfg.pattern_remainder]
    return {"stacked": stacked, "remainder": remainder}


def decode_step(cfg: cfgs.ArchConfig, params: PyTree, tokens: jnp.ndarray,
                caches: PyTree, pos: jnp.ndarray, *,
                encoder_out: Optional[jnp.ndarray] = None,
                multi_pod: bool = False
                ) -> Tuple[jnp.ndarray, PyTree]:
    """One decode token: tokens (B, 1), pos scalar -> (logits, new caches)."""
    ctx = _make_ctx(cfg, {}, 0)
    x = _embed(cfg, ctx, params, tokens)
    x = _batch_constraint(x, multi_pod)
    if encoder_out is not None:
        encoder_out = _run_encoder(cfg, params, ctx, encoder_out)

    def unit_fn(x, scanned):
        layer_params, layer_cache = scanned
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}_{kind}"
            x, nc, _ = blocks.apply_block(
                kind, cfg, ctx, layer_params[key], x,
                cache=layer_cache[key], pos=pos, encoder_out=encoder_out,
                name=f"unit/b{i}")
            new_cache[key] = nc
        return x, new_cache

    x, new_stacked = jax.lax.scan(unit_fn, x,
                                  (params["layers"], caches["stacked"]))
    new_remainder = []
    for i, kind in enumerate(cfg.pattern_remainder):
        x, nc, _ = blocks.apply_block(
            kind, cfg, ctx, params["remainder"][f"r{i}_{kind}"], x,
            cache=caches["remainder"][i], pos=pos, encoder_out=encoder_out,
            name=f"unit/b{i}")
        new_remainder.append(nc)

    norm = (common.rms_norm if cfg.norm == "rms" else common.layer_norm)
    x = norm(params["final_norm"], x)
    logits = _head(cfg, ctx, params, x)
    return logits, {"stacked": new_stacked, "remainder": new_remainder}
