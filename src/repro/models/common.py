"""Module substrate: params-as-pytrees with logical-axis sharding metadata.

No flax/haiku offline — we use a small spec-first module system:

* A model describes its parameters as a pytree of ``P`` leaves
  (shape + initializer + *logical axes*).
* ``init_params`` materializes the tree; ``partition_specs`` maps logical axes
  to mesh axes through a sharding-policy rule table (``tp`` / ``fsdp``), which
  is what pjit's ``in_shardings`` consumes.
* Layers are plain functions ``(params, x, ctx) -> y``; the QAT context from
  ``repro.core.fake_quant`` is threaded through every matmul site.

Logical axes used across the framework:
  "vocab", "embed", "heads", "kv", "head_dim", "mlp", "expert",
  "layers" (stacked scan axis, never sharded), "conv_*", null (None).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class P:
    """Spec of one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # None = fan-in 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(key: jax.Array, specs: PyTree, dtype=jnp.float32) -> PyTree:
    """Materialize a spec tree into arrays. Deterministic per-leaf keys."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(spec: P, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "embed":
            return (jax.random.normal(k, spec.shape) * 0.02).astype(dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(k, spec.shape) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)])


# ---------------------------------------------------------------------------
# Sharding policies: logical axis -> mesh axis
# ---------------------------------------------------------------------------

def sharding_rules(policy: str, *, multi_pod: bool = False,
                   divisible: Callable[[str], bool] = lambda a: True
                   ) -> Dict[str, Any]:
    """Rule table for a policy.

    ``tp``   — tensor parallel only: model-dim axes over 'model'.
    ``fsdp`` — tp + parameters additionally sharded over the data axis
               ("embed" dim) so optimizer state scales with 1/(data*model).
    ``divisible(axis)`` lets a config veto sharding of an axis whose size
    does not divide the mesh (e.g. whisper's 6 heads or vocab 51865).
    """
    data = ("pod", "data") if multi_pod else "data"
    rules = {
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "moe_mlp": "model",
        "expert": None,
        "embed": data if policy == "fsdp" else None,
        "head_dim": None,
        "layers": None,
        None: None,
    }
    return {k: (v if (k is None or divisible(k)) else None)
            for k, v in rules.items()}


def partition_specs(specs: PyTree, rules: Dict[str, Any]) -> PyTree:
    """Spec tree -> PartitionSpec tree for pjit in_shardings."""
    def one(spec: P) -> PartitionSpec:
        return PartitionSpec(*(rules.get(a, None) for a in spec.axes))
    return jax.tree_util.tree_map(one, specs, is_leaf=_is_spec)


def stack_specs(specs: PyTree, n: int) -> PyTree:
    """Prepend a scanned 'layers' axis of size n to every leaf spec."""
    def one(spec: P) -> P:
        return P((n,) + spec.shape, ("layers",) + spec.axes,
                 spec.init, spec.scale)
    return jax.tree_util.tree_map(one, specs, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Primitive layers (functional; QAT ctx threaded)
# ---------------------------------------------------------------------------

def dense(ctx, name: str, params: Dict[str, jnp.ndarray], x: jnp.ndarray,
          *, quant_act: bool = True) -> jnp.ndarray:
    """x @ W (+ b) with QAT weight/activation fake-quantization hooks."""
    w = ctx.weight(f"{name}/w", params["w"])
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    if quant_act:
        y = ctx.activation(f"{name}/out", y)
    return y


def dense_spec(d_in: int, d_out: int, in_axis: Optional[str],
               out_axis: Optional[str], *, bias: bool = False,
               scale: Optional[float] = None) -> Dict[str, P]:
    spec = {"w": P((d_in, d_out), (in_axis, out_axis), scale=scale)}
    if bias:
        spec["b"] = P((d_out,), (out_axis,), init="zeros")
    return spec


def rms_norm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def rms_norm_spec(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="zeros")}


def layer_norm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def layer_norm_spec(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="ones"),
            "bias": P((d,), ("embed",), init="zeros")}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    angles = angles[..., None, :]                              # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def with_constraint(x: jnp.ndarray, spec: PartitionSpec) -> jnp.ndarray:
    """Sharding constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
