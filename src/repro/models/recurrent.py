"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM/sLSTM).

TPU adaptation notes (see DESIGN.md):
* RG-LRU trains with ``jax.lax.associative_scan`` over the sequence — the
  linear recurrence h_t = a_t ⊙ h_{t-1} + b_t is associative, so the scan is
  O(log S) depth and maps onto the VPU; decode is a single-step update.
* mLSTM/sLSTM use exponentially-gated nonlinear recurrences; training runs a
  chunked ``lax.scan`` (outer scan over chunks, inner rematerialized) so the
  backward pass stores carries only at chunk boundaries instead of every
  timestep — the scan-level analogue of flash attention's recompute.
* Recurrent *state* stays fp32 even under QAT: quantizing carried state
  compounds error across timesteps (documented deviation; projections and
  activations are quantized normally).

State layout (decode "cache" for these layers):
  rglru: {"h": (B, Dr), "conv": (B, W-1, Dr)}
  mlstm: {"c": (B, H, Dh, Dh), "n": (B, H, Dh), "m": (B, H)}
  slstm: {"c": (B, H, Dh), "n": (B, H), "m": (B, H), "h": (B, H, Dh)}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import P, dense_spec

CONV_WIDTH = 4


def scan_chunked(step_fn, carry, xs, chunk: int):
    """lax.scan with jax.checkpoint'd chunks (memory-bounded backward)."""
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if length <= chunk or length % chunk != 0:
        return jax.lax.scan(step_fn, carry, xs)

    n_chunks = length // chunk
    xs_chunked = jax.tree_util.tree_map(
        lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def chunk_fn(carry, xc):
        return jax.lax.scan(step_fn, carry, xc)

    carry, ys = jax.lax.scan(chunk_fn, carry, xs_chunked)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape((length,) + y.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Depthwise causal temporal conv (griffin's conv1d, width 4)
# ---------------------------------------------------------------------------

def causal_conv1d(params, x: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """x: (B, S, C); state: (B, W-1, C) previous inputs for decode."""
    w = params["w"].astype(x.dtype)          # (W, C)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = None if x.shape[1] < width - 1 else xp[:, -(width - 1):]
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(width - 1):]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + params["b"].astype(x.dtype), new_state


def conv1d_spec(channels: int) -> Dict[str, P]:
    return {"w": P((CONV_WIDTH, channels), (None, "mlp"), scale=0.5),
            "b": P((channels,), ("mlp",), init="zeros")}


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) — arXiv:2402.19427
# ---------------------------------------------------------------------------

def rglru_spec(d_model: int) -> Dict[str, Any]:
    dr = d_model  # lru width == d_model in recurrentgemma-2b
    return {
        "wx": dense_spec(d_model, dr, "embed", "mlp"),
        "wg": dense_spec(d_model, dr, "embed", "mlp"),
        "conv": conv1d_spec(dr),
        "gate_a": dense_spec(dr, dr, "mlp", None),
        "gate_x": dense_spec(dr, dr, "mlp", None),
        "log_lambda": P((dr,), ("mlp",), init="normal", scale=0.5),
        "wo": dense_spec(dr, d_model, "mlp", "embed"),
    }


_C = 8.0  # griffin's recurrence sharpness constant


def _rglru_coeffs(ctx, params, x, name):
    """Per-timestep (a, b) of the linear recurrence h = a*h + b."""
    r = jax.nn.sigmoid(common.dense(ctx, f"{name}/gate_a", params["gate_a"],
                                    x, quant_act=False).astype(jnp.float32))
    i = jax.nn.sigmoid(common.dense(ctx, f"{name}/gate_x", params["gate_x"],
                                    x, quant_act=False).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["log_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i * x.astype(jnp.float32))
    return a, b


def rglru_block(ctx, params, x: jnp.ndarray,
                state: Optional[Dict[str, jnp.ndarray]] = None,
                name: str = "rglru"
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Griffin recurrent block: Wo(GeLU(Wg x) ⊙ RGLRU(conv1d(Wx x)))."""
    gate = jax.nn.gelu(common.dense(ctx, f"{name}/wg", params["wg"], x))
    xr = common.dense(ctx, f"{name}/wx", params["wx"], x, quant_act=False)
    xr, conv_state = causal_conv1d(params["conv"], xr,
                                   None if state is None else state["conv"])
    xr = ctx.activation(f"{name}/conv_out", xr)

    a, b = _rglru_coeffs(ctx, params, xr, name)

    if state is None:
        # Training/prefill: associative scan over the sequence axis.
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2
        a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = b_s  # h_t with h_0 = 0 ⇒ h_t == accumulated b
        new_state = None if x.shape[1] == 0 else {
            "h": h[:, -1], "conv": conv_state}
    else:
        h = a * state["h"][:, None].astype(jnp.float32) + b
        new_state = {"h": h[:, -1], "conv": conv_state}

    h = ctx.activation(f"{name}/h", h.astype(x.dtype))
    out = common.dense(ctx, f"{name}/wo", params["wo"], h * gate)
    return out, new_state


# ---------------------------------------------------------------------------
# xLSTM — arXiv:2405.04517
# ---------------------------------------------------------------------------

def mlstm_spec(d_model: int, n_heads: int, head_dim: int) -> Dict[str, Any]:
    d_inner = n_heads * head_dim
    return {
        "wq": dense_spec(d_model, d_inner, "embed", "heads"),
        "wk": dense_spec(d_model, d_inner, "embed", "heads"),
        "wv": dense_spec(d_model, d_inner, "embed", "heads"),
        "wi": dense_spec(d_model, n_heads, "embed", None, bias=True),
        "wf": dense_spec(d_model, n_heads, "embed", None, bias=True),
        "wg": dense_spec(d_model, d_inner, "embed", "heads"),
        "wo": dense_spec(d_inner, d_model, "heads", "embed"),
    }


def _mlstm_gates(ctx, params, x, name):
    i_pre = common.dense(ctx, f"{name}/wi", params["wi"], x, quant_act=False)
    f_pre = common.dense(ctx, f"{name}/wf", params["wf"], x, quant_act=False)
    return i_pre.astype(jnp.float32), f_pre.astype(jnp.float32)


def _mlstm_step(carry, inp):
    """Stabilized mLSTM recurrence (paper eq. 19-27). One timestep."""
    c, n, m = carry                      # (B,H,Dh,Dh), (B,H,Dh), (B,H)
    q, k, v, i_pre, f_pre = inp          # (B,H,Dh) x3, (B,H) x2
    log_f = -jax.nn.softplus(-f_pre)     # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = (f_g[..., None, None] * c
             + i_g[..., None, None] * v[..., :, None] * k[..., None, :])
    n_new = f_g[..., None] * n + i_g[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)),
                        jnp.exp(-m_new))
    h = jnp.einsum("bhde,bhe->bhd", c_new, q) / denom[..., None]
    return (c_new, n_new, m_new), h


def mlstm_block(ctx, params, x: jnp.ndarray, *, n_heads: int, head_dim: int,
                state: Optional[Dict[str, jnp.ndarray]] = None,
                chunk: int = 128, name: str = "mlstm"
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    b, s, d = x.shape
    def to_heads(t):
        return t.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    q = to_heads(common.dense(ctx, f"{name}/wq", params["wq"], x)) \
        * head_dim ** -0.5
    k = to_heads(common.dense(ctx, f"{name}/wk", params["wk"], x)) \
        * head_dim ** -0.5
    v = to_heads(common.dense(ctx, f"{name}/wv", params["wv"], x))
    i_pre, f_pre = _mlstm_gates(ctx, params, x, name)

    if state is None:
        c0 = jnp.zeros((b, n_heads, head_dim, head_dim), jnp.float32)
        n0 = jnp.zeros((b, n_heads, head_dim), jnp.float32)
        m0 = jnp.zeros((b, n_heads), jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    xs = jax.tree_util.tree_map(
        lambda t: jnp.moveaxis(t, 1, 0), (q, k, v, i_pre, f_pre))
    (c, n, m), hs = scan_chunked(_mlstm_step, (c0, n0, m0), xs, chunk)
    h = jnp.moveaxis(hs, 0, 1)                     # (B,S,H,Dh)
    new_state = {"c": c, "n": n, "m": m}

    gate = jax.nn.silu(common.dense(ctx, f"{name}/wg", params["wg"], x))
    h = ctx.activation(f"{name}/h", h.reshape(b, s, n_heads * head_dim)
                       .astype(x.dtype))
    out = common.dense(ctx, f"{name}/wo", params["wo"], h * gate)
    return out, new_state


def slstm_spec(d_model: int, n_heads: int, head_dim: int) -> Dict[str, Any]:
    d_inner = n_heads * head_dim
    return {
        "wz": dense_spec(d_model, d_inner, "embed", "heads"),
        "wi": dense_spec(d_model, n_heads, "embed", None, bias=True),
        "wf": dense_spec(d_model, n_heads, "embed", None, bias=True),
        "wo_gate": dense_spec(d_model, d_inner, "embed", "heads"),
        "wo": dense_spec(d_inner, d_model, "heads", "embed"),
    }


def _slstm_step(carry, inp):
    c, n, m = carry                       # (B,H,Dh), (B,H), (B,H)
    z, i_pre, f_pre = inp                 # (B,H,Dh), (B,H), (B,H)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g[..., None] * c + i_g[..., None] * jnp.tanh(z)
    n_new = f_g * n + i_g
    h = c_new / jnp.maximum(n_new, 1.0)[..., None]
    return (c_new, n_new, m_new), h


def slstm_block(ctx, params, x: jnp.ndarray, *, n_heads: int, head_dim: int,
                state: Optional[Dict[str, jnp.ndarray]] = None,
                chunk: int = 128, name: str = "slstm"
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    b, s, d = x.shape
    z = common.dense(ctx, f"{name}/wz", params["wz"], x) \
        .reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    i_pre = common.dense(ctx, f"{name}/wi", params["wi"], x,
                         quant_act=False).astype(jnp.float32)
    f_pre = common.dense(ctx, f"{name}/wf", params["wf"], x,
                         quant_act=False).astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, n_heads, head_dim), jnp.float32)
        n0 = jnp.zeros((b, n_heads), jnp.float32)
        m0 = jnp.zeros((b, n_heads), jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    xs = jax.tree_util.tree_map(
        lambda t: jnp.moveaxis(t, 1, 0), (z, i_pre, f_pre))
    (c, n, m), hs = scan_chunked(_slstm_step, (c0, n0, m0), xs, chunk)
    h = jnp.moveaxis(hs, 0, 1)
    new_state = {"c": c, "n": n, "m": m}

    gate = jax.nn.silu(common.dense(ctx, f"{name}/wo_gate", params["wo_gate"],
                                    x))
    h = ctx.activation(f"{name}/h", h.reshape(b, s, n_heads * head_dim)
                       .astype(x.dtype))
    out = common.dense(ctx, f"{name}/wo", params["wo"], h * gate)
    return out, new_state
