"""Decoder blocks: one spec/apply pair per block kind in the layer pattern.

Every block is pre-norm residual. ``apply_block`` returns
``(x, new_cache, aux)`` where ``new_cache`` is the block's decode state
(KVCache for attention kinds, recurrent state for SSM kinds, None when not
decoding) and ``aux`` the MoE load-balance loss contribution.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import attention, common, moe as moe_lib, recurrent
from repro.models.common import dense_spec


# ---------------------------------------------------------------------------
# SwiGLU / GeGLU MLP
# ---------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int) -> Dict[str, Any]:
    return {
        "wi": dense_spec(d_model, d_ff, "embed", "mlp"),
        "wg": dense_spec(d_model, d_ff, "embed", "mlp"),
        "wo": dense_spec(d_ff, d_model, "mlp", "embed"),
    }


def mlp(ctx, params, x: jnp.ndarray, activation: str = "silu",
        name: str = "mlp") -> jnp.ndarray:
    h = common.dense(ctx, f"{name}/wi", params["wi"], x, quant_act=False)
    g = common.dense(ctx, f"{name}/wg", params["wg"], x, quant_act=False)
    act = jax.nn.silu(g) if activation == "silu" else jax.nn.gelu(g)
    h = ctx.activation(f"{name}/h", h * act)
    return common.dense(ctx, f"{name}/wo", params["wo"], h)


# ---------------------------------------------------------------------------
# Block spec/apply dispatch
# ---------------------------------------------------------------------------

def _norm_spec(cfg: cfgs.ArchConfig):
    return (common.rms_norm_spec(cfg.d_model) if cfg.norm == "rms"
            else common.layer_norm_spec(cfg.d_model))


def _norm(cfg, params, x):
    return (common.rms_norm(params, x) if cfg.norm == "rms"
            else common.layer_norm(params, x))


def block_spec(kind: str, cfg: cfgs.ArchConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    spec: Dict[str, Any] = {"norm1": _norm_spec(cfg)}
    if kind in (cfgs.ATTN, cfgs.ATTN_LOCAL, cfgs.MOE, cfgs.MOE_LOCAL,
                cfgs.CROSS):
        spec["attn"] = attention.attention_spec(d, cfg.n_heads,
                                                cfg.n_kv_heads, cfg.hd)
        spec["norm2"] = _norm_spec(cfg)
        if kind == cfgs.CROSS:
            spec["cross"] = attention.attention_spec(d, cfg.n_heads,
                                                     cfg.n_kv_heads, cfg.hd)
            spec["norm_cross"] = _norm_spec(cfg)
        if kind in (cfgs.MOE, cfgs.MOE_LOCAL):
            spec["moe"] = moe_lib.moe_spec(d, f, cfg.n_experts)
        else:
            spec["mlp"] = mlp_spec(d, f)
    elif kind == cfgs.RGLRU:
        spec["rglru"] = recurrent.rglru_spec(d)
        spec["norm2"] = _norm_spec(cfg)
        spec["mlp"] = mlp_spec(d, f)
    elif kind == cfgs.MLSTM:
        spec["mlstm"] = recurrent.mlstm_spec(d, cfg.n_heads, cfg.hd)
    elif kind == cfgs.SLSTM:
        spec["slstm"] = recurrent.slstm_spec(d, cfg.n_heads, cfg.hd)
    else:
        raise ValueError(kind)
    return spec


def init_block_cache(kind: str, cfg: cfgs.ArchConfig, batch: int,
                     seq_len: int, *, int8: bool,
                     encoder_out: Optional[jnp.ndarray] = None,
                     dtype=jnp.bfloat16) -> Any:
    """Decode-state structure for one block."""
    window = cfg.long_context_window or cfg.window
    if kind in (cfgs.ATTN, cfgs.MOE, cfgs.CROSS):
        w = cfg.long_context_window
        size = min(seq_len, w) if w else seq_len
        return {"kv": attention.init_cache(batch, size, cfg.n_kv_heads,
                                           cfg.hd, int8=int8, dtype=dtype)}
    if kind in (cfgs.ATTN_LOCAL, cfgs.MOE_LOCAL):
        size = min(seq_len, window or seq_len)
        return {"kv": attention.init_cache(batch, size, cfg.n_kv_heads,
                                           cfg.hd, int8=int8, dtype=dtype)}
    if kind == cfgs.RGLRU:
        d = cfg.d_model
        return {"h": jnp.zeros((batch, d), jnp.float32),
                "conv": jnp.zeros((batch, recurrent.CONV_WIDTH - 1, d), dtype)}
    if kind == cfgs.MLSTM:
        return {"c": jnp.zeros((batch, cfg.n_heads, cfg.hd, cfg.hd),
                               jnp.float32),
                "n": jnp.zeros((batch, cfg.n_heads, cfg.hd), jnp.float32),
                "m": jnp.zeros((batch, cfg.n_heads), jnp.float32)}
    if kind == cfgs.SLSTM:
        return {"c": jnp.zeros((batch, cfg.n_heads, cfg.hd), jnp.float32),
                "n": jnp.zeros((batch, cfg.n_heads), jnp.float32),
                "m": jnp.zeros((batch, cfg.n_heads), jnp.float32)}
    raise ValueError(kind)


def apply_block(kind: str, cfg: cfgs.ArchConfig, ctx, params,
                x: jnp.ndarray, *,
                cache: Optional[Any] = None,
                pos: Optional[jnp.ndarray] = None,
                encoder_out: Optional[jnp.ndarray] = None,
                name: str = "blk") -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    local = kind in (cfgs.ATTN_LOCAL, cfgs.MOE_LOCAL)
    window = cfg.window if local else cfg.long_context_window
    # long_context_window turns full-attention layers into SWA *variants*
    # for the long_500k shape (see DESIGN.md §Arch-applicability).

    if kind in (cfgs.ATTN, cfgs.ATTN_LOCAL, cfgs.MOE, cfgs.MOE_LOCAL,
                cfgs.CROSS):
        h = _norm(cfg, params["norm1"], x)
        h, kv_cache = attention.attention_layer(
            ctx, params["attn"], h, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=True,
            window=window, softcap=cfg.softcap, rope_theta=cfg.rope_theta,
            cache=None if cache is None else cache["kv"], pos=pos,
            name=f"{name}/attn")
        x = x + h
        if kind == cfgs.CROSS:
            h = _norm(cfg, params["norm_cross"], x)
            h, _ = attention.attention_layer(
                ctx, params["cross"], h, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=False,
                rope_theta=None, kv_source=encoder_out,
                name=f"{name}/cross")
            x = x + h
        h = _norm(cfg, params["norm2"], x)
        if kind in (cfgs.MOE, cfgs.MOE_LOCAL):
            h, aux = moe_lib.moe_ffn(
                ctx, params["moe"], h, n_experts=cfg.n_experts,
                top_k=cfg.moe_top_k, capacity_factor=cfg.capacity_factor,
                activation=cfg.activation,
                quantize_router=cfg.quant.quantize_router,
                name=f"{name}/moe")
        else:
            h = mlp(ctx, params["mlp"], h, cfg.activation, name=f"{name}/mlp")
        x = x + h
        new_cache = None if cache is None else {"kv": kv_cache}

    elif kind == cfgs.RGLRU:
        h = _norm(cfg, params["norm1"], x)
        h, rec_state = recurrent.rglru_block(ctx, params["rglru"], h,
                                             state=cache, name=f"{name}/rglru")
        x = x + h
        h = _norm(cfg, params["norm2"], x)
        x = x + mlp(ctx, params["mlp"], h, cfg.activation, name=f"{name}/mlp")
        new_cache = rec_state

    elif kind == cfgs.MLSTM:
        h = _norm(cfg, params["norm1"], x)
        h, rec_state = recurrent.mlstm_block(
            ctx, params["mlstm"], h, n_heads=cfg.n_heads, head_dim=cfg.hd,
            state=cache, name=f"{name}/mlstm")
        x = x + h
        new_cache = rec_state

    elif kind == cfgs.SLSTM:
        h = _norm(cfg, params["norm1"], x)
        h, rec_state = recurrent.slstm_block(
            ctx, params["slstm"], h, n_heads=cfg.n_heads, head_dim=cfg.hd,
            state=cache, name=f"{name}/slstm")
        x = x + h
        new_cache = rec_state
    else:
        raise ValueError(kind)

    return x, new_cache, aux
