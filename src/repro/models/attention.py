"""Attention layers: GQA with RoPE, sliding windows, logit soft-capping,
cross-attention, memory-efficient chunked softmax, and decode KV caches
(fp or int8-quantized — the paper's technique applied to serving state).

Implementation notes
--------------------
* GQA is computed with grouped einsums — kv heads are never materialized at
  q-head multiplicity.
* ``chunked_attention`` is the pure-JAX flash equivalent used inside pjit
  programs (the Pallas kernel in repro.kernels is the TPU hot path; both match
  ``kernels.ref.mha_ref``): outer ``lax.map`` over query chunks with
  ``jax.checkpoint`` so the backward pass recomputes rows instead of storing
  S×T score matrices; inner ``lax.scan`` over kv chunks carries the online
  softmax state (m, l, acc).
* Decode caches for sliding-window layers are ring buffers of size
  ``window`` — a 500k-token context costs only O(window) memory on SWA layers.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import affine
from repro.kernels import ops
from repro.models import common
from repro.models.common import dense_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attention_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   *, cross: bool = False) -> Dict[str, Any]:
    """Parameter spec for one GQA attention layer's q/k/v/o projections."""
    spec = {
        "q": dense_spec(d_model, n_heads * head_dim, "embed", "heads"),
        "k": dense_spec(d_model, n_kv * head_dim, "embed", "kv"),
        "v": dense_spec(d_model, n_kv * head_dim, "embed", "kv"),
        "o": dense_spec(n_heads * head_dim, d_model, "heads", "embed"),
    }
    return spec


# ---------------------------------------------------------------------------
# Core softmax attention (grouped heads)
# ---------------------------------------------------------------------------

def _logits(q, k, scale, softcap):
    # q: (B, Sq, KV, G, Dh)  k: (B, Skv, KV, Dh) -> (B, KV, G, Sq, Skv)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _mask(sq: int, skv: int, q_offset, *, causal: bool,
          window: Optional[int], kv_positions: Optional[jnp.ndarray] = None):
    """(sq, skv) boolean mask. q absolute position = q_offset + arange(sq)."""
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = (kv_positions if kv_positions is not None
             else jnp.arange(skv))[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_positions is not None:
        mask &= k_pos >= 0  # ring-buffer slots not yet written
    return mask


def dense_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_offset: int | jnp.ndarray = 0,
                    kv_positions: Optional[jnp.ndarray] = None,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """Materialized-scores attention (small seq / decode).

    q: (B, Sq, KV, G, Dh); k/v: (B, Skv, KV, Dh) -> (B, Sq, KV, G, Dh)
    """
    b, sq, nkv, g, dh = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else dh ** -0.5
    s = _logits(q, k, scale, softcap)
    mask = _mask(sq, skv, q_offset, causal=causal, window=window,
                 kv_positions=kv_positions)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      scale: Optional[float] = None) -> jnp.ndarray:
    """Flash-equivalent attention in pure JAX (online softmax over kv chunks).

    Memory: O(q_chunk × kv_chunk) scores instead of O(S×T); backward
    recomputes each query-row block (jax.checkpoint).

    Distribution: the q-chunk axis is *vmapped* (not lax.scan'd) and
    sharding-constrained over the 'model' mesh axis — each device computes
    attention only for its own query chunks (sequence-parallel attention),
    while k/v are constrained batch-sharded/seq-replicated so the inner kv
    scan is collective-free. (A sequential map over q chunks forces GSPMD to
    all-gather the full k/v *inside* the loop: observed 2.2 TB of gathers per
    step for codeqwen prefill_32k — see EXPERIMENTS.md §Perf.)
    """
    from jax.sharding import PartitionSpec as PS

    b, sq, nkv, g, dh = q.shape
    skv = k.shape[1]
    scale_ = scale if scale is not None else dh ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv,
                                                       kv_chunk)
    n_q, n_kv = sq // q_chunk, skv // kv_chunk
    q_offset_base = skv - sq  # align query block ends to kv end

    # k/v: batch-sharded, seq-replicated — gathered ONCE per layer.
    k = common.with_constraint(k, PS("data", None, None, None))
    v = common.with_constraint(v, PS("data", None, None, None))
    k_blocks = jnp.moveaxis(k.reshape(b, n_kv, kv_chunk, nkv, dh), 1, 0)
    v_blocks = jnp.moveaxis(v.reshape(b, n_kv, kv_chunk, nkv, dh), 1, 0)

    @jax.checkpoint
    def q_row(qi, q_blk):
        # q_blk: (b, q_chunk, nkv, g, dh)
        def kv_step(carry, inp):
            m, lse, acc = carry
            kj, k_blk, v_blk = inp
            s = _logits(q_blk, k_blk, scale_, softcap)  # (b,kv,g,qc,kc)
            mask = _mask_dyn(q_chunk, kv_chunk,
                             qi * q_chunk + q_offset_base, kj * kv_chunk,
                             causal=causal, window=window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            lse_new = alpha * lse + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = alpha * acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
            return (m_new, lse_new, acc_new), None

        m0 = jnp.full((b, nkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, q_chunk, dh), jnp.float32)
        (m, lse, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(n_kv), k_blocks, v_blocks))
        lse = jnp.where(lse == 0.0, 1.0, lse)
        out = (acc / lse)                             # (b,kv,g,qc,dh)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)

    q_rows = jnp.moveaxis(q.reshape(b, n_q, q_chunk, nkv, g, dh), 1, 0)
    if n_q % 16 == 0:
        q_rows = common.with_constraint(
            q_rows, PS("model", "data", None, None, None, None))
    out = jax.vmap(q_row)(jnp.arange(n_q), q_rows)
    if n_q % 16 == 0:
        out = common.with_constraint(
            out, PS("model", "data", None, None, None, None))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, nkv, g, dh)


def _mask_dyn(sq: int, skv: int, q_start, kv_start, *, causal: bool,
              window: Optional[int]):
    q_pos = q_start + jnp.arange(sq)[:, None]
    k_pos = kv_start + jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


# ---------------------------------------------------------------------------
# KV cache (fp / int8 ring-buffer)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Decode cache for one attention layer.

    Slot layout is a ring: slot i holds the most recent position p with
    p % size == i; when ``size == full context`` this degenerates to the
    plain slot-i-holds-position-i layout, so one code path serves both
    full-context and sliding-window layers. ``positions`` tracks the absolute
    position per slot (-1 = never written) and doubles as the validity mask.
    """
    k: jnp.ndarray               # (B, T, KV, Dh)  fp  OR int8 codes
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]   # (B, T, KV, 1) per-token-per-head scales
    v_scale: Optional[jnp.ndarray]
    positions: jnp.ndarray       # (T,) absolute position per slot

    @property
    def size(self) -> int:
        """Number of cache slots (the ring length T)."""
        return self.k.shape[1]


def init_cache(batch: int, size: int, n_kv: int, head_dim: int,
               *, int8: bool, dtype=jnp.bfloat16) -> KVCache:
    """All-zero cache of ``size`` slots (int8 codes + scales, or fp)."""
    if int8:
        k = jnp.zeros((batch, size, n_kv, head_dim), jnp.int8)
        v = jnp.zeros((batch, size, n_kv, head_dim), jnp.int8)
        ks = jnp.zeros((batch, size, n_kv, 1), jnp.float32)
        vs = jnp.zeros((batch, size, n_kv, 1), jnp.float32)
    else:
        k = jnp.zeros((batch, size, n_kv, head_dim), dtype)
        v = jnp.zeros((batch, size, n_kv, head_dim), dtype)
        ks = vs = None
    return KVCache(k, v, ks, vs,
                   positions=jnp.full((size,), -1, jnp.int32))


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray) -> KVCache:
    """Write one token (B, 1, KV, Dh) at absolute position ``pos``.

    int8 caches quantize the token with the shared symmetric per-token
    quantizer ``core.affine.quantize_symmetric`` (bitwise the formula this
    module used to own privately — pinned by
    ``tests/test_seq_policy.py::test_symmetric_quantizer_matches_legacy``).
    """
    pos = jnp.asarray(pos, jnp.int32)
    slot = pos % cache.size
    if cache.k_scale is not None:
        k_codes, k_scale = affine.quantize_symmetric(k_new)
        v_codes, v_scale = affine.quantize_symmetric(v_new)
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_codes, slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_codes, slot, 1)
        ks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, k_scale, slot, 1)
        vs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, v_scale, slot, 1)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, 1)
        ks, vs = None, None
    positions = jax.lax.dynamic_update_slice_in_dim(
        cache.positions, pos[None].astype(jnp.int32), slot, 0)
    return KVCache(k, v, ks, vs, positions)


def cache_kv(cache: KVCache, dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize cache contents in compute dtype (dequantizing int8)."""
    if cache.k_scale is not None:
        k = (cache.k.astype(jnp.float32) * cache.k_scale).astype(dtype)
        v = (cache.v.astype(jnp.float32) * cache.v_scale).astype(dtype)
        return k, v
    return cache.k.astype(dtype), cache.v.astype(dtype)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + attention [+ cache])
# ---------------------------------------------------------------------------

def attention_layer(ctx, params, x: jnp.ndarray, *, n_heads: int, n_kv: int,
                    head_dim: int, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    rope_theta: Optional[float] = 10000.0,
                    positions: Optional[jnp.ndarray] = None,
                    cache: Optional[KVCache] = None,
                    pos: Optional[jnp.ndarray] = None,
                    kv_source: Optional[jnp.ndarray] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    name: str = "attn") -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """GQA attention over x (B, S, D).

    Training/prefill: cache is None; uses chunked attention for long S.
    Decode: S == 1, cache given, ``pos`` is the absolute position.
    Cross-attention: ``kv_source`` (B, T, D) supplies k/v; causal=False.
    """
    b, s, d = x.shape
    g = n_heads // n_kv
    kv_in = kv_source if kv_source is not None else x

    q = common.dense(ctx, f"{name}/q", params["q"], x, quant_act=False)
    k = common.dense(ctx, f"{name}/k", params["k"], kv_in, quant_act=False)
    v = common.dense(ctx, f"{name}/v", params["v"], kv_in, quant_act=False)
    q = ctx.activation(f"{name}/q_out", q)
    k = ctx.activation(f"{name}/k_out", k)
    v = ctx.activation(f"{name}/v_out", v)

    q = q.reshape(b, s, n_kv, g, head_dim)
    k = k.reshape(b, kv_in.shape[1], n_kv, head_dim)
    v = v.reshape(b, kv_in.shape[1], n_kv, head_dim)

    if rope_theta is not None and kv_source is None:
        if positions is None:
            positions = (jnp.arange(s)[None, :] if pos is None
                         else (pos + jnp.zeros((b, s), jnp.int32)))
        q = common.apply_rope(q.reshape(b, s, n_kv * g, head_dim), positions,
                              rope_theta).reshape(b, s, n_kv, g, head_dim)
        k = common.apply_rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None:
        assert s == 1, "decode step handles one token"
        new_cache = cache_update(cache, k, v, pos)
        if new_cache.k_scale is not None and softcap is None:
            # int8 cache: decode straight off the codes through the
            # dispatched op — no dequantized K/V materialization. Ring
            # caches (size == window) hold only in-window tokens, so the
            # op's slot-index masking needs no extra window term; plain
            # caches (slot i == position i) pass window through.
            win = None if (window is not None and cache.size == window
                           ) else window
            qh = q.reshape(b, n_kv, g, head_dim)
            kc = jnp.transpose(new_cache.k, (0, 2, 1, 3))      # (B,KV,T,Dh)
            vc = jnp.transpose(new_cache.v, (0, 2, 1, 3))
            ks = jnp.transpose(new_cache.k_scale, (0, 2, 1, 3))  # (B,KV,T,1)
            vs = jnp.transpose(new_cache.v_scale, (0, 2, 1, 3))
            out = ops.int8_cache_attention(qh, kc, ks, vc, vs, pos,
                                           window=win)
            out = out.reshape(b, 1, n_kv, g, head_dim)
        else:
            k_all, v_all = cache_kv(new_cache, x.dtype)
            out = dense_attention(
                q, k_all, v_all, causal=True, window=window, softcap=softcap,
                q_offset=pos, kv_positions=new_cache.positions)
    elif kv_source is not None:
        out = dense_attention(q, k, v, causal=False, softcap=softcap)
    else:
        # q-chunk sized so the chunk count is a multiple of the model axis
        # (16) — the vmapped q loop then shards cleanly (seq-parallel attn).
        qc = min(max(s // 16, 128), q_chunk)
        if s <= 2048 or s % qc or k.shape[1] % kv_chunk:
            out = dense_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap)
        else:
            out = chunked_attention(q, k, v, causal=causal, window=window,
                                    softcap=softcap, q_chunk=qc,
                                    kv_chunk=kv_chunk)

    out = out.reshape(b, s, n_heads * head_dim)
    out = common.dense(ctx, f"{name}/o", params["o"], out)
    return out, new_cache
