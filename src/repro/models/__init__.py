"""Model substrate: composable transformer/SSM/MoE stacks in pure JAX."""
from repro.models import attention, blocks, common, moe, recurrent, transformer

__all__ = ["attention", "blocks", "common", "moe", "recurrent", "transformer"]
