"""Optimizers (handwritten — no optax offline)."""
from repro.optim.adam import (AdamConfig, AdamState, adam_init, adam_update,
                              block_quantize, block_dequantize,
                              BlockQuantized, clip_by_global_norm,
                              global_norm)
from repro.optim import schedule, sgd

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "block_quantize", "block_dequantize", "BlockQuantized",
           "clip_by_global_norm", "global_norm", "schedule", "sgd"]
