"""SGD (+momentum) — used by the RL study's small policies."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 1e-2
    momentum: float = 0.0
    nesterov: bool = False


class SGDState(NamedTuple):
    step: jnp.ndarray
    velocity: Optional[PyTree]


def sgd_init(params: PyTree, config: SGDConfig) -> SGDState:
    vel = None
    if config.momentum:
        vel = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return SGDState(step=jnp.zeros((), jnp.int32), velocity=vel)


def sgd_update(grads: PyTree, state: SGDState, params: PyTree,
               config: SGDConfig) -> Tuple[PyTree, SGDState]:
    if config.momentum:
        vel = jax.tree_util.tree_map(
            lambda v, g: config.momentum * v + g.astype(jnp.float32),
            state.velocity, grads)
        if config.nesterov:
            upd = jax.tree_util.tree_map(
                lambda v, g: config.momentum * v + g.astype(jnp.float32),
                vel, grads)
        else:
            upd = vel
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) - config.lr * u
                          ).astype(p.dtype), params, upd)
        return new_params, SGDState(state.step + 1, vel)
    new_params = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - config.lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_params, SGDState(state.step + 1, None)
