"""Learning-rate schedules (multipliers applied to the base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def linear_warmup(warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.minimum(1.0, s / max(warmup_steps, 1))
    return fn


def warmup_cosine(warmup_steps: int, total_steps: int,
                  final_fraction: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup_steps, 1))
        frac = jnp.clip((s - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_fraction + (1 - final_fraction) * 0.5 \
            * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos
    return fn


def linear_epsilon(start: float, end: float, fraction_steps: int):
    """Epsilon-greedy exploration decay (paper's DQN hyperparameters)."""
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(fraction_steps, 1),
                        0.0, 1.0)
        return start + frac * (end - start)
    return fn
