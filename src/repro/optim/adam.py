"""Adam/AdamW with optional 8-bit (block-wise affine-quantized) moments.

No optax offline — handwritten, functional, pjit-friendly.

8-bit moments (beyond-paper, DESIGN.md §3): the paper's memory argument
(int8 fits where fp32 swaps) applied to optimizer state. Each moment tensor
is stored as int8 codes + one fp32 scale per 256-value block (bitsandbytes-
style block-wise affine quantization, using the paper's affine quantizer per
block). This cuts Adam state from 8 bytes/param to ~2.06 bytes/param, which
is what lets grok-1-314b fit a single v5e pod (see EXPERIMENTS.md §Dry-run).

The moments are dequantized, updated, and requantized inside the step —
transient fp32, persistent int8.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


# ---------------------------------------------------------------------------
# Block-wise quantized tensor
# ---------------------------------------------------------------------------

class BlockQuantized(NamedTuple):
    """Shape-preserving block-quantized tensor.

    ``codes`` has the SAME shape as the original tensor (int8), blocks run
    along the last axis; ``scales`` is ``shape[:-1] + (last // block,)``.
    Keeping the parameter's shape means codes/scales inherit the parameter's
    PartitionSpec and the dequant/update/requant pipeline is fully local —
    a flat layout forces GSPMD into involuntary full rematerialization
    (observed: 412 GB replicated moment buffers on grok-1-314b).
    """
    codes: jnp.ndarray   # int8, same shape as the source tensor
    scales: jnp.ndarray  # f32, shape[:-1] + (n_blocks_last,)
    shape: Tuple[int, ...]  # static (pytree aux)


def _block_size(last_dim: int) -> int:
    return BLOCK if last_dim % BLOCK == 0 else last_dim


def block_quantize(x: jnp.ndarray) -> BlockQuantized:
    """Symmetric per-block int8 quantization along the last axis."""
    x = x.astype(jnp.float32)
    last = x.shape[-1] if x.ndim else 1
    xb = x.reshape(x.shape[:-1] + (-1, _block_size(last))) if x.ndim else \
        x.reshape(1, 1)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scales = jnp.where(amax == 0, 1.0, amax / 127.0)
    codes = jnp.clip(jnp.round(xb / scales), -127, 127).astype(jnp.int8)
    return BlockQuantized(codes.reshape(x.shape),
                          scales[..., 0], x.shape)


def block_dequantize(q: BlockQuantized, dtype=jnp.float32) -> jnp.ndarray:
    last = q.shape[-1] if len(q.shape) else 1
    cb = q.codes.reshape(q.codes.shape[:-1] + (-1, _block_size(last))) \
        if len(q.shape) else q.codes.reshape(1, 1)
    out = cb.astype(jnp.float32) * q.scales[..., None]
    return out.reshape(q.shape).astype(dtype)


jax.tree_util.register_pytree_node(
    BlockQuantized,
    lambda q: ((q.codes, q.scales), q.shape),
    lambda shape, xs: BlockQuantized(xs[0], xs[1], shape))


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    eightbit: bool = False       # block-quantized moments
    schedule: Optional[Any] = None  # callable step -> lr multiplier


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def _maybe_quant(tree: PyTree, eightbit: bool) -> PyTree:
    if not eightbit:
        return tree
    return jax.tree_util.tree_map(block_quantize, tree)


def _maybe_dequant(tree: PyTree, eightbit: bool) -> PyTree:
    if not eightbit:
        return tree
    return jax.tree_util.tree_map(
        block_dequantize, tree,
        is_leaf=lambda x: isinstance(x, BlockQuantized))


def adam_init(params: PyTree, config: AdamConfig) -> AdamState:
    def zeros():
        # distinct arrays for m and v — sharing them breaks buffer donation
        # ("attempt to donate the same buffer twice")
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=_maybe_quant(zeros(), config.eightbit),
                     v=_maybe_quant(zeros(), config.eightbit))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype), grads
    ), norm


def adam_update(grads: PyTree, state: AdamState, params: PyTree,
                config: AdamConfig) -> Tuple[PyTree, AdamState, dict]:
    """Returns (new_params, new_state, stats). Params/m/v stay fp32."""
    stats = {}
    if config.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, config.grad_clip)
        stats["grad_norm"] = gnorm

    step = state.step + 1
    lr = config.lr
    if config.schedule is not None:
        lr = lr * config.schedule(step)
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf_update(p, m_q, v_q, g):
        mm = block_dequantize(m_q) if config.eightbit else m_q
        vv = block_dequantize(v_q) if config.eightbit else v_q
        g32 = g.astype(jnp.float32)
        mm = b1 * mm + (1 - b1) * g32
        vv = b2 * vv + (1 - b2) * jnp.square(g32)
        delta = (mm / bc1) / (jnp.sqrt(vv / bc2) + config.eps)
        if config.weight_decay:
            delta = delta + config.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        new_m = block_quantize(mm) if config.eightbit else mm
        new_v = block_quantize(vv) if config.eightbit else vv
        return new_p, new_m, new_v

    # Serialize the per-leaf updates with optimization barriers: each leaf's
    # fp32 dequant/update/requant transients (several x param-size for the
    # stacked MoE weights) then never overlap in buffer liveness. Observed on
    # grok-1-314b: ~27 GB -> ~1 leaf's working set (§Perf A5).
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    g_leaves = treedef.flatten_up_to(grads)
    new_p, new_m, new_v = [], [], []
    token = jnp.zeros((), jnp.float32)
    for p, m_q, v_q, g in zip(p_leaves, m_leaves, v_leaves, g_leaves):
        (p, m_q, v_q, g), token = jax.lax.optimization_barrier(
            ((p, m_q, v_q, g), token))
        np_, nm, nv = leaf_update(p, m_q, v_q, g)
        (np_, nm, nv), token = jax.lax.optimization_barrier(
            ((np_, nm, nv), token))
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)

    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = AdamState(step=step,
                          m=jax.tree_util.tree_unflatten(treedef, new_m),
                          v=jax.tree_util.tree_unflatten(treedef, new_v))
    return new_params, new_state, stats


# Sharding of the optimizer state under pjit: the launcher leaves the
# optimizer-state argument's in_sharding unspecified, so GSPMD propagates it
# from the parameter shardings (m/v interact with params elementwise; 8-bit
# codes/scales are flat and inherit a compatible layout). This avoids
# hand-maintaining a parallel PartitionSpec tree for quantized state.
