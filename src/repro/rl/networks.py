"""Policy/value networks with the paper's quantization hooks.

Matches QuaRL's architectures:
* Atari/pixel: 3-layer conv + FC (Appendix B: 3x Conv(128) + FC(128));
  Policies A/B/C for the mixed-precision study (Table 10).
* Deployment MLPs (Table 5): 3-layer MLPs.
* Classic control: 2x64 MLPs (stable-baselines defaults).

Every dense/conv site routes its weights and activations through the QAT
context (repro.core.fake_quant), and the same param pytrees feed
``core.ptq`` for post-training quantization — these networks ARE the paper's
experimental subjects. Conv weights use per-axis (output-channel)
quantization per the paper; dense per-tensor.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import P, init_params


# ---------------------------------------------------------------------------
# Layers (QAT-aware)
# ---------------------------------------------------------------------------

def dense(ctx, name, params, x, act=None):
    w = ctx.weight(f"{name}/w", params["w"])
    y = x @ w.astype(x.dtype) + params["b"].astype(x.dtype)
    if act is not None:
        y = act(y)
    return ctx.activation(f"{name}/out", y)


def conv2d(ctx, name, params, x, stride=1, act=jax.nn.relu):
    """x: (B, H, W, C). Per-axis weight fake-quant (paper: conv per-channel)."""
    w = params["w"]
    if ctx.config.is_qat:
        # per-output-channel fake quantization with STE.  ``ctx.enabled`` is
        # part of the context contract (every ctx implements it, recorder
        # included), so quant_delay gates the conv path like the dense path.
        from repro.core import fake_quant as fq
        wmin = jnp.minimum(jnp.min(w, axis=(0, 1, 2)), 0.0)
        wmax = jnp.maximum(jnp.max(w, axis=(0, 1, 2)), 0.0)
        w_q = fq.fake_quant(w, wmin, wmax, ctx.config.bits)
        w = jnp.where(ctx.enabled, w_q, w)
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + params["b"].astype(x.dtype)
    if act is not None:
        y = act(y)
    return ctx.activation(f"{name}/out", y)


def dense_spec(d_in, d_out, scale=None):
    return {"w": P((d_in, d_out), (None, None), scale=scale),
            "b": P((d_out,), (None,), init="zeros")}


def conv_spec(k, c_in, c_out):
    return {"w": P((k, k, c_in, c_out), (None, None, None, None),
                   scale=1.0 / math.sqrt(k * k * c_in)),
            "b": P((c_out,), (None,), init="zeros")}


# ---------------------------------------------------------------------------
# MLP backbone (classic control + deployment policies)
# ---------------------------------------------------------------------------

def mlp_spec(obs_dim: int, widths: Sequence[int], out_dim: int,
             out_scale: float = 0.01) -> Dict[str, Any]:
    spec, d = {}, obs_dim
    for i, w in enumerate(widths):
        spec[f"fc{i}"] = dense_spec(d, w)
        d = w
    spec["out"] = dense_spec(d, out_dim, scale=out_scale)
    return spec


def mlp_apply(ctx, params, x, n_hidden: int, out_act=None):
    # x: (..., obs_dim) — arbitrary leading batch dims.
    for i in range(n_hidden):
        x = dense(ctx, f"fc{i}", params[f"fc{i}"], x, act=jax.nn.relu)
    y = dense(ctx, "out", params["out"], x)
    if out_act is not None:
        y = out_act(y)
    return y


# ---------------------------------------------------------------------------
# Conv backbone (the paper's Atari policy: 3 conv + FC)
# ---------------------------------------------------------------------------

def cnn_spec(obs_shape: Tuple[int, int, int], filters: Sequence[int],
             fc_width: int, out_dim: int) -> Dict[str, Any]:
    h, w, c = obs_shape
    spec = {}
    c_in = c
    for i, f in enumerate(filters):
        spec[f"conv{i}"] = conv_spec(3, c_in, f)
        c_in = f
    flat = h * w * c_in  # stride-1 SAME convs preserve H, W
    spec["fc"] = dense_spec(flat, fc_width)
    spec["out"] = dense_spec(fc_width, out_dim, scale=0.01)
    return spec


def cnn_apply(ctx, params, x, n_convs: int):
    batch_shape = x.shape[:-3]
    x = x.reshape((-1,) + x.shape[-3:])
    for i in range(n_convs):
        x = conv2d(ctx, f"conv{i}", params[f"conv{i}"], x)
    x = x.reshape(x.shape[0], -1)
    x = dense(ctx, "fc", params["fc"], x, act=jax.nn.relu)
    y = dense(ctx, "out", params["out"], x)
    return y.reshape(batch_shape + y.shape[-1:])


# ---------------------------------------------------------------------------
# Network factory
# ---------------------------------------------------------------------------

class Network:
    """(spec, apply) pair; apply(ctx, params, obs) -> head outputs.

    ``seq_cfg`` is ``None`` for MLP/CNN nets; sequence policies carry
    their ``models.seq_policy.SeqPolicyConfig`` here so the RL layer can
    size the matching int8 KV-cache actor state (``rl.actorq``).
    """

    def __init__(self, spec: Dict[str, Any], apply_fn, out_dim: int,
                 seq_cfg=None):
        self.spec = spec
        self.apply = apply_fn
        self.out_dim = out_dim
        self.seq_cfg = seq_cfg

    def init(self, key, dtype=jnp.float32):
        return init_params(key, self.spec, dtype)


def make_network(obs_shape: Tuple[int, ...], out_dim: int,
                 hidden: Sequence[int] = (64, 64),
                 conv_filters: Optional[Sequence[int]] = None,
                 fc_width: int = 128,
                 transformer: Optional[Dict[str, Any]] = None) -> Network:
    """Network for an obs shape: 3-D -> CNN, else MLP; ``transformer``
    (a dict of ``models.seq_policy.make_seq_policy`` kwargs, possibly
    empty) selects the decoder-transformer sequence policy for 2-D
    frame-stacked obs ``(context, feat)``."""
    if transformer is not None:
        from repro.models.seq_policy import make_seq_policy
        spec, apply_fn, seq_cfg = make_seq_policy(
            tuple(obs_shape), out_dim, **transformer)
        return Network(spec, apply_fn, out_dim, seq_cfg=seq_cfg)
    if len(obs_shape) == 3:  # pixels
        filters = tuple(conv_filters or (16, 16, 16))
        spec = cnn_spec(obs_shape, filters, fc_width, out_dim)
        n = len(filters)
        return Network(spec, lambda ctx, p, x: cnn_apply(ctx, p, x, n),
                       out_dim)
    obs_dim = int(jnp.prod(jnp.asarray(obs_shape)))
    spec = mlp_spec(obs_dim, hidden, out_dim)
    nh = len(hidden)
    return Network(spec, lambda ctx, p, x: mlp_apply(ctx, p, x, nh), out_dim)
