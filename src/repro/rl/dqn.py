"""DQN (Mnih et al. 2013) with target network + replay, QAT-instrumented.

Paper hyperparameters (QuaRL Table 9) are the defaults scaled down:
lr 1e-4, buffer 10k, target update 1000, epsilon 1.0 -> 0.01 over 10% of
training, quantization delay = half of training (quant_delay).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantConfig
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.rl import actorq
from repro.rl import buffer as rb
from repro.rl import common
from repro.rl.env import Env, StatefulPolicy, batched_env, rollout
from repro.rl.networks import Network


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    lr: float = 1e-3
    gamma: float = 0.99
    buffer_size: int = 10_000
    batch_size: int = 64
    n_envs: int = 8
    rollout_steps: int = 16       # env steps per iteration (per env)
    updates_per_iter: int = 8
    target_update_every: int = 100  # in gradient updates
    eps_start: float = 1.0
    eps_end: float = 0.01
    eps_decay_updates: int = 4000
    warmup: int = 500             # transitions before learning
    quant: QuantConfig = QuantConfig.none()
    # ActorQ: "int8" computes behaviour-policy Q-values with the packed int8
    # actor (refreshed once per learner update); "int4" halves the cache
    # with byte-packed W4A8 codes; TD learning stays fp32.
    actor_backend: str = "fp32"
    kernel_backend: str = "auto"
    # calib_batch > 0: calibrate static activation scales from that many
    # rollout observations at every cache refresh, replacing the per-layer
    # dynamic range pass and enabling the single-pass fused MLP kernel
    # (rl.actorq.calibrate_actor_cache).  0 keeps dynamic quantization.
    calib_batch: int = 0
    # Replay discipline: "prioritized" samples proportionally to
    # (|td| + eps) ** priority_exponent with IS-weight correction whose
    # exponent anneals is_beta -> 1 over is_beta_anneal_updates learner
    # updates.  priority_exponent=0.0 is bitwise-uniform (static dispatch
    # onto the uniform path — see rl.buffer.use_prioritized).
    replay: str = "uniform"
    priority_exponent: float = 0.6
    is_beta: float = 0.4
    is_beta_anneal_updates: int = 4000


class DQNExtras(NamedTuple):
    target_params: Any
    replay: rb.ReplayState
    updates: jnp.ndarray


def init(key, env: Env, net: Network, cfg: DQNConfig):
    k1, k2 = jax.random.split(key)
    params = net.init(k1)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    if rb.use_prioritized(cfg.replay, cfg.priority_exponent):
        replay = rb.per_init(cfg.buffer_size, env.spec.obs_shape)
    else:
        replay = rb.replay_init(cfg.buffer_size, env.spec.obs_shape)
    # target params start equal but must not alias the online buffers:
    # the scan-fused driver donates the whole TrainState, and donation
    # rejects the same buffer appearing twice.
    target = jax.tree_util.tree_map(jnp.array, params)
    return common.TrainState(
        params=params, opt=opt, observers={},
        step=jnp.zeros((), jnp.int32),
        extras=DQNExtras(target_params=target, replay=replay,
                         updates=jnp.zeros((), jnp.int32)))


def _q_values(net, cfg, params, obs, observers, step):
    ctx = common.make_ctx(cfg.quant, observers, step)
    q = net.apply(ctx, params, obs)
    return q, ctx.merged_collection()


def make_behaviour_policy(env: Env, net: Network, cfg: DQNConfig):
    """``build(params, observers, step, updates, qparams=None) ->
    policy(_, obs, key)``.

    The behaviour (data-collection) policy closes over the params it is
    built from — in the fused loop that is the live learner state; in the
    actor–learner topologies (``rl.actor_learner``) it is the actors'
    possibly stale synced copy.  ``actor_backend="int8"`` packs those
    params into the int8 cache once per build (= once per learner update),
    the ActorQ hot path — unless the caller hands in an already-packed
    ``qparams`` cache (the actor–learner topologies carry the cache across
    iterations and repack only at sync points).

    Quantized *sequence* actors (``net.seq_cfg`` set) get an
    ``env.StatefulPolicy`` instead of a plain policy: behaviour Q-values
    come from the incremental int8 KV-cache decode
    (``actorq.quantized_seq_step``) over the per-env cache state that
    ``actorq.maybe_attach_seq_state`` rides inside the batched env state.
    """
    seq_cfg = getattr(net, "seq_cfg", None)

    def build(params, observers, step, updates, qparams=None):
        eps = common.linear_epsilon(updates, cfg.eps_start,
                                    cfg.eps_end, cfg.eps_decay_updates)
        if actorq.is_quantized(cfg.actor_backend):
            # ActorQ hot path: int cache packed once per learner update,
            # reused by every env step of the rollout scan.
            if qparams is None:
                qparams = actorq.pack_actor_params(
                    params, actorq.backend_bits(cfg.actor_backend))

            def behaviour_q(obs):
                return actorq.quantized_apply(qparams, obs,
                                              backend=cfg.kernel_backend)
        else:
            def behaviour_q(obs):
                return _q_values(net, cfg, params, obs, observers, step)[0]

        def select(q, key):
            k_rand, k_explore = jax.random.split(key)
            greedy = jnp.argmax(q, axis=-1)
            rand = jax.random.randint(k_rand, greedy.shape, 0,
                                      env.spec.n_actions)
            explore = jax.random.uniform(k_explore, greedy.shape) < eps
            return jnp.where(explore, rand, greedy).astype(jnp.int32)

        if seq_cfg is not None and actorq.is_quantized(cfg.actor_backend):
            # quantized sequence actor: incremental int8 KV-cache decode
            # over the per-env cache state riding in the env state (see
            # actorq.maybe_attach_seq_state / env.StatefulPolicy)
            def apply(_params, obs, pstate, key):
                q, pstate = actorq.quantized_seq_step(
                    qparams, obs[..., -1, :], pstate,
                    context=seq_cfg.context, backend=cfg.kernel_backend)
                return select(q, key), pstate, q
            return StatefulPolicy(apply)

        def policy(_params, obs, key):
            q = behaviour_q(obs)
            return select(q, key), q
        return policy
    return build


def make_td_update(env: Env, net: Network, cfg: DQNConfig):
    """``td_update(state, batch, replay_size, weights, reduce) ->
    (state, (loss, td_abs))``.

    One fp32 learner step on an already-sampled batch.  ``replay_size``
    gates the warmup; ``weights`` are optional per-transition
    importance-sampling weights (prioritized replay) applied to the Huber
    loss — ``None`` keeps the plain mean, bitwise-identical to the
    pre-PER update; ``reduce`` is applied to gradients/metrics before the
    optimizer (identity on a single host, ``lax.pmean`` over the actor axis
    inside a ``shard_map`` — the data-parallel learner of the actor–learner
    topology).  ``td_abs`` is the per-transition |TD error| (never
    ``reduce``-averaged: in the sharded topology each shard pushes its own
    priorities).  Sampling lives with the caller so the sharded replay of
    ``rl.actor_learner`` and the single fused buffer share this update.
    """
    adam_cfg = AdamConfig(lr=cfg.lr)

    def q_values(params, obs, observers, step):
        return _q_values(net, cfg, params, obs, observers, step)

    def td_update(state: common.TrainState, batch: rb.Transition,
                  replay_size, weights=None, reduce=lambda x: x
                  ) -> Tuple[common.TrainState, Tuple[jnp.ndarray,
                                                      jnp.ndarray]]:
        def loss_fn(params):
            q, new_obs_coll = q_values(params, batch.obs, state.observers,
                                       state.step)
            q_sel = jnp.take_along_axis(
                q, batch.action[:, None].astype(jnp.int32), axis=1)[:, 0]
            q_next, _ = q_values(state.extras.target_params, batch.next_obs,
                                 state.observers, state.step)
            target = batch.reward + cfg.gamma * (1 - batch.done) \
                * jnp.max(q_next, axis=-1)
            td = q_sel - jax.lax.stop_gradient(target)
            if weights is None:
                loss = jnp.mean(common.huber(td))
            else:
                loss = jnp.mean(weights * common.huber(td))
            return loss, (new_obs_coll, jnp.abs(td))

        (loss, (new_coll, td_abs)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, loss, new_coll = reduce(grads), reduce(loss), reduce(new_coll)
        new_params, new_opt, _ = adam_update(grads, state.opt, state.params,
                                             adam_cfg)
        updates = state.extras.updates + 1
        do_sync = (updates % cfg.target_update_every) == 0
        target = jax.tree_util.tree_map(
            lambda t, o: jnp.where(do_sync, o, t),
            state.extras.target_params, new_params)
        # learn only after warmup
        warm = replay_size >= cfg.warmup
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(warm, n, o), new_params, state.params)
        state = common.TrainState(
            params=new_params, opt=new_opt, observers=new_coll,
            step=state.step + 1,
            extras=DQNExtras(target, state.extras.replay,
                             jnp.where(warm, updates, state.extras.updates)))
        return state, (loss, td_abs)

    return td_update


def make_iteration(env: Env, net: Network, cfg: DQNConfig):
    actorq.validate_actor_backend(cfg.actor_backend)
    use_per = rb.use_prioritized(cfg.replay, cfg.priority_exponent)
    benv = actorq.maybe_attach_seq_state(
        batched_env(env, cfg.n_envs), net, cfg.actor_backend, cfg.n_envs)
    build_policy = make_behaviour_policy(env, net, cfg)
    td_update = make_td_update(env, net, cfg)

    @jax.jit
    def iteration(state: common.TrainState, env_state, obs, key):
        k_roll, k_updates = jax.random.split(key)
        policy_kw = {}
        if actorq.is_quantized(cfg.actor_backend) and cfg.calib_batch:
            # static-requant mode: hand build_policy a cache calibrated on
            # the live observations so the rollout runs the fused kernel
            policy_kw["qparams"] = actorq.make_actor_cache(
                state.params, cfg.actor_backend,
                calib_obs=actorq.calib_slice(obs, cfg.calib_batch),
                backend=cfg.kernel_backend)
        policy = build_policy(state.params, state.observers, state.step,
                              state.extras.updates, **policy_kw)
        env_state, obs, traj = rollout(
            benv, policy, state.params, env_state, obs, k_roll,
            cfg.rollout_steps)
        flat = jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj)
        add = rb.per_add if use_per else rb.replay_add_batch
        replay = add(
            state.extras.replay,
            rb.Transition(flat.obs, flat.action, flat.reward, flat.done,
                          flat.next_obs))
        state = state._replace(extras=state.extras._replace(replay=replay))

        def one_update(st, k):
            if use_per:
                return common.per_learner_step(st, k, cfg, td_update)
            batch = rb.replay_sample(st.extras.replay, k, cfg.batch_size)
            st, (loss, _) = td_update(st, batch, st.extras.replay.size)
            return st, loss
        state, losses = jax.lax.scan(
            one_update, state, jax.random.split(k_updates,
                                                cfg.updates_per_iter))
        metrics = {"loss": jnp.mean(losses),
                   "reward": jnp.sum(traj.reward) / jnp.maximum(
                       jnp.sum(traj.done), 1.0),
                   "mean_q_var": jnp.var(jax.nn.softmax(
                       traj.logits_or_value, axis=-1), axis=-1).mean()}
        return state, env_state, obs, metrics

    def act_fn(params, obs, observers=None, step=1 << 30):
        ctx = common.make_ctx(cfg.quant, observers or {}, step)
        q = net.apply(ctx, params, obs)
        return jnp.argmax(q, axis=-1).astype(jnp.int32)

    return iteration, act_fn, benv
