"""Functional environment API (pure JAX — fully jittable/vmappable).

The paper's environments (OpenAI Gym classic control, Atari, PyBullet) are
not installable offline; these are faithful pure-JAX ports of the classic
control dynamics plus a pixel Atari-proxy ("Catch") and an Air-Learning-style
navigation env (see envs/). Everything is:

  env.reset(key)            -> (state, obs)
  env.step(state, action, key) -> (state, obs, reward, done)

with auto-reset handled by ``batched_rollout`` so rollouts are a single
``lax.scan``. Observations are f32; discrete actions int32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

State = Any
Obs = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_shape: Tuple[int, ...]
    n_actions: int = 0            # discrete envs
    action_dim: int = 0           # continuous envs
    action_scale: float = 1.0     # actor outputs [-1,1] * action_scale
    max_steps: int = 500

    @property
    def continuous(self) -> bool:
        return self.action_dim > 0


class Env(NamedTuple):
    spec: EnvSpec
    reset: Callable[[jax.Array], Tuple[State, Obs]]
    step: Callable[[State, jnp.ndarray, jax.Array],
                   Tuple[State, Obs, jnp.ndarray, jnp.ndarray]]


class StepOut(NamedTuple):
    obs: jnp.ndarray
    action: jnp.ndarray
    reward: jnp.ndarray
    done: jnp.ndarray
    next_obs: jnp.ndarray
    logits_or_value: Any = None


class StatefulPolicy(NamedTuple):
    """A rollout policy that carries per-env recurrent state.

    ``apply(params, obs, pstate, key) -> (action, new_pstate, aux)`` —
    the stateful analogue of the plain ``policy_fn(params, obs, key)``.
    Pair with :func:`attach_policy_state`, which rides ``pstate`` inside
    the env state so every existing driver (rollout scan, shard_map
    topologies, checkpoint/resume) carries, shards, and restores it as
    ordinary env state; ``auto_reset_step`` then resets it per-env to the
    attach-time initial value on episode end, for free.  The int8
    KV-cache transformer actors of ``rl.actorq`` are the consumer.
    """
    apply: Callable[[Any, Obs, Any, jax.Array],
                    Tuple[jnp.ndarray, Any, Any]]


def attach_policy_state(benv: Env, pstate0: Any) -> Env:
    """Wrap a (batched) env so its state is ``(inner_state, pstate)``.

    ``reset`` returns ``pstate0`` (the batched all-reset policy state)
    alongside the inner reset; ``step`` threads ``pstate`` through
    untouched — only :func:`rollout`'s ``StatefulPolicy`` branch writes
    it.  Because ``auto_reset_step`` masks the whole state tree against a
    fresh ``reset`` on done, the policy state of a finished env resets to
    ``pstate0`` with no extra plumbing; likewise checkpointing the env
    state checkpoints the policy state verbatim.
    """
    def reset(key):
        state, obs = benv.reset(key)
        return (state, pstate0), obs

    def step(state, action, key):
        inner, ps = state
        inner, obs, reward, done = benv.step(inner, action, key)
        return (inner, ps), obs, reward, done

    return Env(spec=benv.spec, reset=reset, step=step)


def auto_reset_step(env: Env):
    """step that resets the env when done (state carries the episode)."""
    def step(state, action, key):
        k_step, k_reset = jax.random.split(key)
        new_state, obs, reward, done = env.step(state, action, k_step)
        reset_state, reset_obs = env.reset(k_reset)
        state_out = jax.tree_util.tree_map(
            lambda a, b: jnp.where(_bshape(done, a), a, b),
            reset_state, new_state)
        obs_out = jnp.where(_bshape(done, obs), reset_obs, obs)
        return state_out, obs_out, reward, done
    return step


def _bshape(done, x):
    return done.reshape(done.shape + (1,) * (x.ndim - done.ndim)) \
        if hasattr(x, "ndim") and x.ndim > done.ndim else done


def batched_env(env: Env, n: int) -> Env:
    """vmap an env over a batch dimension."""
    def reset(key):
        return jax.vmap(env.reset)(jax.random.split(key, n))

    def step(state, action, key):
        return jax.vmap(env.step)(state, action, jax.random.split(key, n))

    return Env(spec=env.spec, reset=reset, step=step)


def rollout(env: Env, policy_fn, params, state, obs, key, n_steps: int,
            auto_reset: bool = True):
    """Collect a trajectory with lax.scan.

    policy_fn(params, obs, key) -> (action, aux) — aux is carried into the
    trajectory (logits for exploration analysis, values for A2C/PPO...).
    Returns (final_state, final_obs, StepOut trajectory [n_steps, ...]).

    A ``StatefulPolicy`` ``policy_fn`` requires ``env`` to be wrapped
    with :func:`attach_policy_state`: the policy reads and writes the
    ``pstate`` half of the env state each step (the KV-cache actors).
    """
    stepper = auto_reset_step(env) if auto_reset else env.step
    stateful = isinstance(policy_fn, StatefulPolicy)

    def one(carry, key):
        state, obs = carry
        k_act, k_env = jax.random.split(key)
        if stateful:
            inner, ps = state
            action, ps, aux = policy_fn.apply(params, obs, ps, k_act)
            state = (inner, ps)
        else:
            action, aux = policy_fn(params, obs, k_act)
        state, next_obs, reward, done = stepper(state, action, k_env)
        out = StepOut(obs=obs, action=action, reward=reward, done=done,
                      next_obs=next_obs, logits_or_value=aux)
        return (state, next_obs), out

    (state, obs), traj = jax.lax.scan(one, (state, obs),
                                      jax.random.split(key, n_steps))
    return state, obs, traj


def _build_evaluation(env: Env, act_fn, max_steps: int):
    def one_episode(params, key):
        k_reset, k_run = jax.random.split(key)
        state, obs = env.reset(k_reset)

        def step_fn(carry, k):
            state, obs, done_prev, total = carry
            action = act_fn(params, obs)
            state, obs2, reward, done = env.step(state, action, k)
            total = total + reward * (1.0 - done_prev)
            done_now = jnp.maximum(done_prev, done.astype(jnp.float32))
            return (state, obs2, done_now, total), None

        (_, _, _, total), _ = jax.lax.scan(
            step_fn, (state, obs, jnp.zeros(()), jnp.zeros(())),
            jax.random.split(k_run, max_steps))
        return total

    @jax.jit
    def run(params, keys):
        return jnp.mean(jax.vmap(one_episode, in_axes=(None, 0))(params,
                                                                 keys))

    return run


@functools.lru_cache(maxsize=16)
def _cached_evaluation(env: Env, act_fn, max_steps: int):
    return _build_evaluation(env, act_fn, max_steps)


def evaluate(env: Env, act_fn, params, key, n_episodes: int,
             max_steps: int = 1000) -> jnp.ndarray:
    """Mean undiscounted episode return under a deterministic policy.

    Runs ``n_episodes`` in parallel (one vmap), each until its first done
    (rewards after the first done are masked out).  The whole evaluation
    (reset + rollout scan + masking + mean) compiles to a single XLA
    program, cached per ``(env, act_fn, max_steps)`` — callers that reuse
    one ``act_fn`` object (e.g. the periodic evals in ``loops.train``)
    compile once and dispatch once per eval thereafter.

    ``params`` is any pytree ``act_fn`` understands: fp32 network params,
    fake-quant-simulated params, or the packed int8 ``QuantizedParams`` of
    ``rl.actorq`` (deployment actors run their int8 kernels inside this same
    compiled program).
    """
    try:
        run = _cached_evaluation(env, act_fn, max_steps)
    except TypeError:        # unhashable env/act_fn: build uncached
        run = _build_evaluation(env, act_fn, max_steps)
    return run(params, jax.random.split(key, n_episodes))
