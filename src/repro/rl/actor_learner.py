"""ActorQ actor–learner topologies: int8 actor fan-out + fp32 replay learner.

The paper's headline system is a distributed training paradigm: a pool of
8-bit quantized *actors* collects experience into a replay buffer while a
full-precision *learner* samples batches and periodically broadcasts
refreshed parameters to the actors.  This module reproduces that topology on
top of the repo's replay algorithms (DQN, DDPG — the paper's DQN/D4PG
analogues) in two flavours:

* ``topology="actor-learner"`` — bulk-synchronous: one jitted iteration
  runs rollout -> replay add -> learner updates -> (cadenced) param push.
* ``topology="async"`` — the overlapped regime the paper's speedups come
  from: the actor phase and the learner phase compile to two *independent*
  jit programs with disjoint state (``make_async_actor_learner``).  Actors
  roll a chunk of rollouts into the **write slot** of a double-buffered
  replay (``buffer.DoubleBuffer``) while the learner drains the **read
  slot**; the host driver (``loops.train(topology="async")``) dispatches
  both programs back-to-back with **no** ``block_until_ready`` between
  them, swaps the slots by host-level reference exchange at sync points,
  and pushes refreshed (int8-packed) params to the actors via a snapshot
  program.  Dispatch overlap on a single host; on a device mesh both
  programs are ``shard_map``-ped over the actor axis as separate XLA
  executables.

Shared mechanics:

* **Actor fan-out** — ``num_actors`` actor replicas, each running
  ``cfg.n_envs`` environments with the behaviour policy of the underlying
  algorithm (``dqn.make_behaviour_policy`` / ``ddpg.make_behaviour_policy``).
  With ``actor_backend="int8"`` the replicas step through the W8A8 kernel
  using a packed int8 param cache that is repacked **only at sync points**
  (carried in ``ActorLearnerState.actor_cache`` under ``lax.cond`` for the
  synchronous topology; minted by the snapshot program for async) — between
  syncs the actor params are unchanged, so repacking would be pure waste.
* **Sharded replay** — each actor owns one shard (``buffer.*_sharded``;
  with ``replay="prioritized"`` every shard carries its own sum-tree);
  the learner samples ``batch_size / num_actors`` per shard and priority
  pushes stay shard-local.  Under async each *slot* of the double buffer
  is such a sharded buffer of half the total capacity.
* **Staleness contract** — measured in *learner updates*: a push refreshes
  the actors every ``sync_every`` learner updates.  The synchronous
  topology performs exactly ``updates_per_iter`` learner updates per
  iteration and pushes on iteration boundaries, so its ``sync_every``
  knob (kept in iterations for backwards compatibility) equals
  ``sync_every * updates_per_iter`` learner updates; the async driver
  takes ``sync_every`` in learner updates directly and records, per sync,
  the retiring snapshot's **actor lag** (how many learner updates it
  served for).  The first push happens after the first ``sync_every``
  period — at init the actors hold a fresh copy by construction, which is
  *not* a sync — and divergence is recorded **only at true pushes**.
* **Divergence metrics** — at every push: per actor, the mean absolute gap
  between the freshly-synced actor behaviour head (int8 under
  ``actor_backend="int8"``) and the fp32 learner head on the actors'
  current observations.  Off the hot path: ``lax.cond`` in the sync
  topology, a separately-dispatched (never-blocked-on) program in async.

Single-actor equivalence: with ``num_actors=1`` and ``sync_every=1`` (no
mesh) the synchronous topology is *bitwise identical* to the fused
``loops.train`` driver for DQN — same PRNG chain, same replay contents,
same updates — and ``topology="async"`` with ``steps_per_call=1``,
``async_barrier=True`` and ``sync_every=updates_per_iter`` reproduces the
synchronous learner trajectory bitwise (the barrier mode threads a single
replay slot actor -> learner, serializing the round by dataflow).  Both
contracts are enforced by ``tests/test_actor_learner.py`` /
``tests/test_async_actor_learner.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.rl import actorq, common, ddpg, dqn
from repro.rl import buffer as rb
from repro.rl.distributed import shard_map_compat
from repro.rl.env import Env, batched_env, rollout

ALGOS = ("dqn", "ddpg")
TOPOLOGIES = ("fused", "actor-learner", "async")


def validate_topology(topology: str) -> str:
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    return topology


@dataclasses.dataclass(frozen=True)
class ActorLearnerConfig:
    """Topology knobs (the algorithm's own config rides separately).

    ``sync_every`` is the staleness contract: under ``topology="async"``
    it counts *learner updates* between param pushes; the synchronous
    topology keeps its historical iteration cadence (one iteration =
    ``updates_per_iter`` learner updates, pushes on iteration boundaries).
    """
    num_actors: int = 2
    sync_every: int = 1


class ActorLearnerState(NamedTuple):
    """The bulk-synchronous topology's full carry.

    Checkpoint contract (``repro.checkpoint`` / ``loops.train``
    ``checkpoint_dir``): every field — the learner with its optimizer
    state and sharded replay (uniform or PER sum-trees), the stale actor
    params, the packed int8/int4 ``actor_cache`` (``core.ptq`` registers
    ``PackedTensor`` as a pytree, so the codes/scales flatten like any
    leaf) and the schedule counters — is an array leaf, so the whole
    state round-trips through ``tree_leaves``; re-running ``init`` with
    the same seed/config rebuilds the matching restore template.
    """

    learner: common.TrainState    # fp32 learner; extras.replay is sharded
    actor_params: Any             # the actors' (possibly stale) param copy
    actor_cache: Any              # packed int8 cache of actor_params
    #                               (() under actor_backend="fp32");
    #                               repacked only at sync points
    t: jnp.ndarray                # iterations completed
    divergence: jnp.ndarray       # (num_actors,) actor-vs-learner head gap


class ActorSnapshot(NamedTuple):
    """What the async actor program knows about the learner: the params
    (and their int8 cache) from the last push plus the schedule counters
    frozen at mint time.  Minted by ``AsyncPrograms.make_snapshot`` — a
    plain jit, so every leaf is a fresh buffer that never aliases the
    learner state the next learner chunk donates.

    Checkpointable like ``ActorLearnerState``: the async driver saves the
    live snapshot alongside the learner so a resumed run keeps serving
    the *same* (possibly stale) actor params until the next sync point —
    re-minting on resume would silently skip ahead of the staleness
    schedule and break the bitwise-resume contract."""
    params: Any
    cache: Any                    # packed int8 cache (() for fp32 actors)
    step: jnp.ndarray
    updates: jnp.ndarray          # learner updates landed at mint time


class AsyncPrograms(NamedTuple):
    """The async topology's program set (see ``make_async_actor_learner``).

    ``actor_chunk`` and ``learner_chunk`` are the two overlapping hot-path
    programs; ``make_snapshot`` and ``divergence`` run once per sync and
    are dispatched without ever being blocked on.
    """
    actor_chunk: Callable         # (snap, env_state, obs, wbuf, key,
    #                                *, n_chunks) -> (env_state, obs,
    #                                wbuf, {"reward"})
    learner_chunk: Callable       # (learner, key, *, n_updates)
    #                                -> (learner, {"loss"})
    make_snapshot: Callable       # learner -> ActorSnapshot
    divergence: Callable          # (learner, snap, obs) -> (num_actors,)
    act_fn: Callable              # deterministic eval policy (fp32 head)
    benv_global: Env              # num_actors * n_envs environments


class _AlgoParts(NamedTuple):
    build_policy: Callable        # (params, observers, step, updates,
    #                                cache) -> policy
    learn: Callable               # the algorithm's update part
    fp32_head: Callable           # (params, obs, observers, step) -> head
    cache_head: Callable          # (packed cache, obs) -> behaviour head
    act_fn: Callable              # deterministic eval policy


def _algo_parts(algo: str, env: Env, net, cfg) -> _AlgoParts:
    """Behaviour/learner/head builders shared by both topologies."""
    if algo == "dqn":
        _build = dqn.make_behaviour_policy(env, net, cfg)
        learn = dqn.make_td_update(env, net, cfg)

        def build_policy(params, observers, step, updates, cache):
            return _build(params, observers, step, updates, qparams=cache)

        def fp32_head(params, obs, observers, step):
            return dqn._q_values(net, cfg, params, obs, observers, step)[0]

        def cache_head(cache, obs):
            return actorq.quantized_apply(cache, obs,
                                          backend=cfg.kernel_backend)

        def act_fn(params, obs, observers=None, step=1 << 30):
            q = fp32_head(params, obs, observers or {}, jnp.asarray(step))
            return jnp.argmax(q, axis=-1).astype(jnp.int32)
    else:
        _build = ddpg.make_behaviour_policy(env, net, cfg)
        learn = ddpg.make_update(env, net, cfg)

        def build_policy(params, observers, step, updates, cache):
            return _build(params, observers, step, qparams=cache)

        def fp32_head(params, obs, observers, step):
            return ddpg._actor_out(net, cfg, params, obs, observers,
                                   step)[0]

        def cache_head(cache, obs):
            return jnp.tanh(actorq.quantized_apply(
                cache, obs, backend=cfg.kernel_backend))

        def act_fn(params, obs, observers=None, step=1 << 30):
            a = fp32_head(params, obs, observers or {}, jnp.asarray(step))
            return a * env.spec.action_scale
    return _AlgoParts(build_policy, learn, fp32_head, cache_head, act_fn)


def _validate(algo: str, cfg, al: ActorLearnerConfig, mesh, axis: str):
    if algo not in ALGOS:
        raise ValueError(f"actor-learner supports {ALGOS}, got {algo!r}")
    actorq.validate_actor_backend(cfg.actor_backend)
    if al.sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {al.sync_every}")
    n = al.num_actors
    n_dev = mesh.shape[axis] if mesh is not None else 1
    if n % n_dev:
        raise ValueError(f"num_actors {n} must divide by the mesh "
                         f"{axis!r} axis size {n_dev}")
    if cfg.batch_size % n:
        raise ValueError(f"batch_size {cfg.batch_size} must divide by "
                         f"num_actors {n}")
    return n, n_dev


def _make_to_shards(local_actors: int, envs_per_actor: int):
    """(T, local_actors * envs_per_actor, ...) rollout leaves -> per-shard
    (local_actors, T * envs_per_actor, ...) batches (actor-major)."""
    def to_shards(x):
        t_dim, trail = x.shape[0], x.shape[2:]
        y = x.reshape((t_dim, local_actors, envs_per_actor) + trail)
        y = jnp.moveaxis(y, 1, 0)
        return y.reshape((local_actors, t_dim * envs_per_actor) + trail)
    return to_shards


def _make_learner_phase(parts: _AlgoParts, cfg, use_per: bool,
                        per_actor_batch: int, local_actors: int):
    """``learner_phase(learner, key, total_size, n_updates, reduce)`` —
    the scan of per-shard sample -> fp32 update (-> priority push) steps
    shared by the synchronous core and the async learner program."""
    learn = parts.learn

    def learner_phase(learner, k_updates, total_size, n_updates, reduce):
        def one_update(st, k):
            keys_a = k[None] if local_actors == 1 \
                else jax.random.split(k, local_actors)
            if use_per:
                # same anneal schedule as the fused drivers
                # (common.per_beta, on the learner-update counter);
                # priority pushes stay per-shard, inside the shard_map —
                # the actor axis never gathers
                beta = common.per_beta(st, cfg)
                shards, idx, w = rb.per_sample_sharded(
                    st.extras.replay, keys_a, per_actor_batch, beta)
                batch = jax.tree_util.tree_map(
                    lambda x: x.reshape((-1,) + x.shape[2:]), shards)
                st, (loss, td_abs) = learn(st, batch, total_size,
                                           weights=w.reshape(-1),
                                           reduce=reduce)
                per = rb.per_update_priorities_sharded(
                    st.extras.replay, idx, td_abs.reshape(idx.shape),
                    cfg.priority_exponent)
                st = st._replace(extras=st.extras._replace(replay=per))
                return st, loss
            shards = rb.replay_sample_sharded(st.extras.replay, keys_a,
                                              per_actor_batch)
            batch = jax.tree_util.tree_map(
                lambda x: x.reshape((-1,) + x.shape[2:]), shards)
            st, (loss, _) = learn(st, batch, total_size, reduce=reduce)
            return st, loss

        learner, losses = jax.lax.scan(
            one_update, learner, jax.random.split(k_updates, n_updates))
        return learner, losses
    return learner_phase


def _make_divergence(parts: _AlgoParts, int8: bool, n_actors: int,
                     envs_per_actor: int, obs_shape):
    """``divergence(learner, actor_params, cache, obs) -> (n_actors,)`` —
    per-actor mean-abs gap between the actors' behaviour head (the packed
    cache under int8, the stale params otherwise) and the live fp32
    learner head, shared by both topologies."""
    def divergence(learner, actor_params, cache, obs):
        obs_a = obs.reshape((n_actors, envs_per_actor) + obs_shape)

        def one(o):
            fresh = parts.fp32_head(learner.params, o, learner.observers,
                                    learner.step)
            if int8:
                behaved = parts.cache_head(cache, o)
            else:
                behaved = parts.fp32_head(actor_params, o,
                                          learner.observers, learner.step)
            return jnp.mean(jnp.abs(behaved - fresh))
        return jax.vmap(one)(obs_a)
    return divergence


def _sharded_init(algo: str, env: Env, cfg):
    """Per-discipline sharded slot initializer for one algorithm."""
    init_sharded = rb.per_init_sharded \
        if rb.use_prioritized(cfg.replay, cfg.priority_exponent) \
        else rb.replay_init_sharded

    def make_slot(n_shards: int, capacity: int):
        if algo == "ddpg":
            return init_sharded(n_shards, capacity, env.spec.obs_shape,
                                action_shape=(env.spec.action_dim,),
                                action_dtype=jnp.float32)
        return init_sharded(n_shards, capacity, env.spec.obs_shape)
    return make_slot


def init(key, env: Env, net, algo: str, cfg, al: ActorLearnerConfig
         ) -> ActorLearnerState:
    """Learner state + actor copy (+ int8 cache) + sharded replay.

    ``net``/``cfg`` are the underlying algorithm's network(s) and config
    (``dqn.DQNConfig`` / ``ddpg.DDPGConfig``).  The algorithm's fused
    replay is swapped for the sharded layout (total capacity conserved:
    ``buffer_size / num_actors`` per shard).  The actor copy is a real
    copy, not an alias — the scan-fused driver donates the whole state and
    donation rejects one buffer appearing twice.
    """
    if algo not in ALGOS:
        raise ValueError(f"actor-learner supports {ALGOS}, got {algo!r}")
    n = al.num_actors
    if n < 1 or cfg.buffer_size % n:
        raise ValueError(f"buffer_size {cfg.buffer_size} must divide by "
                         f"num_actors {n}")
    mod = {"dqn": dqn, "ddpg": ddpg}[algo]
    state = mod.init(key, env, net, cfg)
    sharded = _sharded_init(algo, env, cfg)(n, cfg.buffer_size // n)
    state = state._replace(extras=state.extras._replace(replay=sharded))
    actor_params = jax.tree_util.tree_map(jnp.array, state.params)
    # the packed cache keeps fp32 leaves (biases) by reference — copy them
    # so the scan-fused driver's donated state holds no buffer twice.
    # calib_batch: the t=0 cache calibrates from fresh env-reset
    # observations (no rollout data exists yet); every later refresh
    # recalibrates from the live actor observations at the sync point.
    cache = ()
    if actorq.is_quantized(cfg.actor_backend):
        calib_obs = None
        if cfg.calib_batch:
            _, calib_obs = batched_env(env, max(cfg.calib_batch, 1)).reset(
                jax.random.fold_in(key, 0x5CA1E))
        cache = jax.tree_util.tree_map(
            jnp.array, actorq.make_actor_cache(
                actor_params, cfg.actor_backend, calib_obs=calib_obs,
                backend=cfg.kernel_backend))
    return ActorLearnerState(
        learner=state, actor_params=actor_params, actor_cache=cache,
        t=jnp.zeros((), jnp.int32),
        divergence=jnp.zeros((al.num_actors,), jnp.float32))


def init_async(key, env: Env, net, algo: str, cfg, al: ActorLearnerConfig,
               *, double: bool = True):
    """``(learner_state, write_slot)`` for the async topology.

    The learner state carries the **read slot** in ``extras.replay``; the
    returned ``write_slot`` is the actors' independent slot (each of
    capacity ``buffer_size / (2 * num_actors)`` per shard, conserving the
    total).  With ``double=False`` (the ``async_barrier`` equivalence
    mode) there is a single slot of the synchronous topology's capacity
    and ``write_slot`` is ``None`` — the driver threads
    ``learner.extras.replay`` through the actor program instead.
    """
    if algo not in ALGOS:
        raise ValueError(f"actor-learner supports {ALGOS}, got {algo!r}")
    n = al.num_actors
    slots = 2 if double else 1
    if n < 1 or cfg.buffer_size % (n * slots):
        raise ValueError(
            f"buffer_size {cfg.buffer_size} must divide by num_actors x "
            f"slots = {n} x {slots} (double-buffered async replay)")
    mod = {"dqn": dqn, "ddpg": ddpg}[algo]
    state = mod.init(key, env, net, cfg)
    make_slot = _sharded_init(algo, env, cfg)
    cap = cfg.buffer_size // (n * slots)
    if double:
        db = rb.double_buffer_init(make_slot, n, cap)
        read, write = db.read, db.write
    else:
        read, write = make_slot(n, cap), None
    state = state._replace(extras=state.extras._replace(replay=read))
    return state, write


def swap_read_slot(learner: common.TrainState, wbuf):
    """Sync-point slot swap for the async topology.

    The learner carries the read slot in ``extras.replay``; this applies
    ``buffer.double_buffer_swap`` to the (read, write) pair — the freshly
    written slot becomes the learner's next read slot, the drained slot
    becomes the actors' next write slot.  Pure host-level reference
    exchange between (possibly in-flight) futures: no device op, no
    synchronization.  Returns ``(learner, wbuf)`` with the roles traded.
    """
    db = rb.double_buffer_swap(
        rb.DoubleBuffer(read=learner.extras.replay, write=wbuf))
    learner = learner._replace(
        extras=learner.extras._replace(replay=db.read))
    return learner, db.write


def with_cache(state: ActorLearnerState, cache) -> ActorLearnerState:
    """Swap the packed actor cache — the resilience corruption/repair seam.

    ``repro.resilience`` targets the in-state cache for ``bitflip_push``
    faults (and restores a verified re-mint after a guard trips) through
    this helper rather than reaching into the NamedTuple, so the state
    shape stays a private detail of this module.
    """
    return state._replace(actor_cache=cache)


def remint_cache(state: ActorLearnerState, actor_backend: str, *,
                 kernel_backend: str = "auto"):
    """Deterministically re-mint the packed cache from the stale params.

    The integrity reference for ``repro.resilience.guards``: under
    ``calib_batch == 0`` the in-jit sync-point repack is a pure function
    of ``state.actor_params``, so a host-side re-mint reproduces it
    bitwise (the repo's standing eager-vs-jit CPU parity anchor) and a
    CRC mismatch against the carried cache means corruption, not drift.
    Returns ``()`` untouched for fp32 actors.  With calibration enabled
    the repack consumes live rollout observations that no longer exist
    host-side, so there is no deterministic reference — callers skip
    verification in that regime (``loops._guard_round``).
    """
    if state.actor_cache == () or not actorq.is_quantized(actor_backend):
        return ()
    return actorq.make_actor_cache(state.actor_params, actor_backend,
                                   backend=kernel_backend)


def _state_specs(state: ActorLearnerState, axis: str):
    """Partition specs for the state pytree: replay + divergence live on the
    actor axis, everything else (learner params/opt, actor copy + cache)
    replicated.
    """
    def one(path, leaf):
        names = {getattr(entry, "name", None) for entry in path}
        sharded = "replay" in names or "divergence" in names
        return P(axis) if sharded else P()
    return jax.tree_util.tree_map_with_path(one, state)


def _learner_specs(learner: common.TrainState, axis: str):
    """Partition specs for a bare learner ``TrainState``: the (read-slot)
    replay is sharded over the actor axis, everything else replicated."""
    def one(path, leaf):
        names = {getattr(entry, "name", None) for entry in path}
        return P(axis) if "replay" in names else P()
    return jax.tree_util.tree_map_with_path(one, learner)


def make_actor_learner(algo: str, env: Env, net, cfg,
                       al: ActorLearnerConfig, mesh=None,
                       axis: str = "actor"):
    """Returns ``(iteration, act_fn, benv_global)`` — the bulk-synchronous
    topology.

    ``iteration(state, env_state, obs, key) -> (state, env_state, obs,
    metrics)`` — the same contract as the fused algorithms, so the
    scan-fused driver (``loops.make_scan_iteration``) and ``loops.train``
    drive it unchanged.  ``benv_global`` batches
    ``num_actors * cfg.n_envs`` environments (actor-major layout).

    With ``mesh`` given, the actor axis is ``shard_map``-ped over
    ``mesh.shape[axis]`` devices (``num_actors`` must divide by it; each
    device runs ``num_actors / n_dev`` replicas) and learner gradients are
    ``pmean``-averaged.  Without a mesh the replicas run as one vectorized
    batch on the local device.
    """
    use_per = rb.use_prioritized(cfg.replay, cfg.priority_exponent)
    n, n_dev = _validate(algo, cfg, al, mesh, axis)
    local_actors = n // n_dev
    envs_per_actor = cfg.n_envs
    per_actor_batch = cfg.batch_size // n
    # sequence nets with a quantized backend carry per-env KV-cache actor
    # state inside the env state (local and global wraps must agree so the
    # shard_map P(axis) specs see the same batch-leading tree structure)
    benv_local = actorq.maybe_attach_seq_state(
        batched_env(env, local_actors * envs_per_actor), net,
        cfg.actor_backend, local_actors * envs_per_actor)
    benv_global = actorq.maybe_attach_seq_state(
        batched_env(env, n * envs_per_actor), net, cfg.actor_backend,
        n * envs_per_actor)
    obs_shape = tuple(env.spec.obs_shape)
    int8 = actorq.is_quantized(cfg.actor_backend)

    parts = _algo_parts(algo, env, net, cfg)
    learner_phase = _make_learner_phase(parts, cfg, use_per,
                                        per_actor_batch, local_actors)
    to_shards = _make_to_shards(local_actors, envs_per_actor)
    add_sharded = rb.per_add_sharded if use_per else rb.replay_add_sharded

    divergence = _make_divergence(parts, int8, local_actors,
                                  envs_per_actor, obs_shape)

    def core(state: ActorLearnerState, env_state, obs, key, axis_name):
        if axis_name is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
            reduce = functools.partial(jax.lax.pmean, axis_name=axis_name)
        else:
            def reduce(x):
                return x
        learner, actor_params = state.learner, state.actor_params
        k_roll, k_updates = jax.random.split(key)

        # --- actor phase: stale-param rollouts into the local shards -----
        # (int8: the cache packed at the last sync, carried in state)
        policy = parts.build_policy(actor_params, learner.observers,
                                    learner.step, learner.extras.updates,
                                    state.actor_cache if int8 else None)
        env_state, obs, traj = rollout(
            benv_local, policy, actor_params, env_state, obs, k_roll,
            cfg.rollout_steps)

        flat = jax.tree_util.tree_map(to_shards, traj)
        replay = add_sharded(
            learner.extras.replay,
            rb.Transition(flat.obs, flat.action, flat.reward, flat.done,
                          flat.next_obs))
        learner = learner._replace(
            extras=learner.extras._replace(replay=replay))
        total_size = rb.replay_total_size(replay)
        if axis_name is not None:
            total_size = jax.lax.psum(total_size, axis_name)

        # --- learner phase: per-shard sampling, fp32 updates -------------
        learner, losses = learner_phase(learner, k_updates, total_size,
                                        cfg.updates_per_iter, reduce)

        # --- sync phase: staleness contract + divergence metric -----------
        # first push at t == sync_every (t=0 is init, where the actors hold
        # a fresh copy by construction — not a sync, and not a divergence
        # sample); between pushes actors run the stale params + stale cache
        t = state.t + 1
        do_sync = (t % al.sync_every) == 0
        actor_params = jax.tree_util.tree_map(
            lambda a, p: jnp.where(do_sync, p, a), actor_params,
            learner.params)
        if int8:
            # repack the int cache only at true pushes — between syncs the
            # actor params are unchanged and the cache is bitwise-stable.
            # calib_batch: the repack also refreshes the static activation
            # scales from the actors' current observations, so the fused
            # kernel's requant ranges track the data distribution at the
            # same cadence as the params.
            def repack(p):
                calib_obs = None
                if cfg.calib_batch:
                    # the cache is carried replicated over the actor axis
                    # (P() in _state_specs): on a mesh, gather the
                    # calibration batch so every device derives identical
                    # scales (collective only inside the sync branch)
                    calib_obs = obs if axis_name is None else \
                        jax.lax.all_gather(obs, axis_name, axis=0,
                                           tiled=True)
                    calib_obs = actorq.calib_slice(calib_obs,
                                                   cfg.calib_batch)
                return actorq.make_actor_cache(
                    p, cfg.actor_backend, calib_obs=calib_obs,
                    backend=cfg.kernel_backend)

            cache = jax.lax.cond(
                do_sync,
                repack,
                lambda _: state.actor_cache,
                actor_params)
        else:
            cache = state.actor_cache
        # divergence is recorded at sync points only (lax.cond keeps the
        # extra head passes off the non-sync iterations); between syncs the
        # last recorded value carries through
        div = jax.lax.cond(
            do_sync,
            lambda args: divergence(*args),
            lambda args: state.divergence,
            (learner, actor_params, cache, obs))

        reward = jnp.sum(traj.reward) / jnp.maximum(jnp.sum(traj.done),
                                                    1.0)
        loss = jnp.mean(losses)
        if axis_name is not None:
            reward = jax.lax.pmean(reward, axis_name)
            loss = jax.lax.pmean(loss, axis_name)
        metrics = {"loss": loss, "reward": reward, "divergence": div,
                   "synced": do_sync}
        new_state = ActorLearnerState(learner, actor_params, cache, t, div)
        return new_state, env_state, obs, metrics

    if mesh is None:
        @jax.jit
        def iteration(state, env_state, obs, key):
            return core(state, env_state, obs, key, None)
    else:
        @jax.jit
        def iteration(state, env_state, obs, key):
            specs = _state_specs(state, axis)
            metric_specs = {"loss": P(), "reward": P(),
                            "divergence": P(axis), "synced": P()}
            sharded = shard_map_compat(
                functools.partial(core, axis_name=axis), mesh,
                in_specs=(specs, P(axis), P(axis), P()),
                out_specs=(specs, P(axis), P(axis), metric_specs))
            return sharded(state, env_state, obs, key)

    return iteration, parts.act_fn, benv_global


def make_async_actor_learner(algo: str, env: Env, net, cfg,
                             al: ActorLearnerConfig, mesh=None,
                             axis: str = "actor") -> AsyncPrograms:
    """The async topology's program set (``topology="async"``).

    Two independent hot-path programs with disjoint state:

    * ``actor_chunk(snap, env_state, obs, wbuf, key, *, n_chunks)`` —
      ``n_chunks`` rollouts of ``cfg.rollout_steps`` with the snapshot's
      (stale, int8-packed) params, appended to the write slot.  Donates
      ``(env_state, obs, wbuf)``.
    * ``learner_chunk(learner, key, *, n_updates)`` — ``n_updates``
      per-shard sample -> fp32 update (-> priority push) steps against the
      read slot carried in ``learner.extras.replay``.  Donates the learner
      state.

    Because the two programs share no buffers, the host can dispatch both
    for a round and immediately continue — JAX's async dispatch queues
    them with no ``block_until_ready`` barrier; the only cross-program
    edges are the host-level slot swap and the param snapshot at sync
    points.  ``make_snapshot`` packs the int8 cache (the only repack per
    sync) and, being a plain jit, returns fresh buffers that never alias
    the donated learner state.  With ``mesh``, both programs are
    ``shard_map``-ped over the actor axis (learner grads pmean-averaged;
    the slots' shard axis partitioned) as two separate XLA executables.
    """
    use_per = rb.use_prioritized(cfg.replay, cfg.priority_exponent)
    n, n_dev = _validate(algo, cfg, al, mesh, axis)
    local_actors = n // n_dev
    envs_per_actor = cfg.n_envs
    per_actor_batch = cfg.batch_size // n
    benv_local = actorq.maybe_attach_seq_state(
        batched_env(env, local_actors * envs_per_actor), net,
        cfg.actor_backend, local_actors * envs_per_actor)
    benv_global = actorq.maybe_attach_seq_state(
        batched_env(env, n * envs_per_actor), net, cfg.actor_backend,
        n * envs_per_actor)
    obs_shape = tuple(env.spec.obs_shape)
    int8 = actorq.is_quantized(cfg.actor_backend)

    parts = _algo_parts(algo, env, net, cfg)
    learner_phase = _make_learner_phase(parts, cfg, use_per,
                                        per_actor_batch, local_actors)
    to_shards = _make_to_shards(local_actors, envs_per_actor)
    add_sharded = rb.per_add_sharded if use_per else rb.replay_add_sharded

    @jax.jit
    def make_snapshot(learner: common.TrainState,
                      obs=None) -> ActorSnapshot:
        """Param push: mint the actors' next (packed) snapshot.

        ``obs`` — the actors' current observations — is only consumed
        under ``calib_batch > 0``, where each push also recalibrates the
        cache's static activation scales (the PR-4 repack path carrying
        the PR-5 static-requant contract); the driver passes it
        unconditionally, the equivalence-anchor cadence is unchanged.
        """
        cache = ()
        if int8:
            calib_obs = None
            if cfg.calib_batch:
                if obs is None:
                    raise ValueError(
                        "calib_batch > 0 needs the actors' observations "
                        "at every snapshot — pass make_snapshot(learner, "
                        "obs)")
                calib_obs = actorq.calib_slice(obs, cfg.calib_batch)
            cache = actorq.make_actor_cache(
                learner.params, cfg.actor_backend, calib_obs=calib_obs,
                backend=cfg.kernel_backend)
        return ActorSnapshot(params=learner.params, cache=cache,
                             step=learner.step,
                             updates=learner.extras.updates)

    def actor_core(snap, env_state, obs, wbuf, key, n_chunks, axis_name):
        if axis_name is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
        policy = parts.build_policy(snap.params, {}, snap.step,
                                    snap.updates,
                                    snap.cache if int8 else None)

        def body(carry, k):
            env_state, obs, wbuf = carry
            env_state, obs, traj = rollout(
                benv_local, policy, snap.params, env_state, obs, k,
                cfg.rollout_steps)
            flat = jax.tree_util.tree_map(to_shards, traj)
            wbuf = add_sharded(
                wbuf, rb.Transition(flat.obs, flat.action, flat.reward,
                                    flat.done, flat.next_obs))
            r = jnp.sum(traj.reward) / jnp.maximum(jnp.sum(traj.done), 1.0)
            return (env_state, obs, wbuf), r

        keys = key[None] if n_chunks == 1 \
            else jax.random.split(key, n_chunks)
        (env_state, obs, wbuf), rewards = jax.lax.scan(
            body, (env_state, obs, wbuf), keys)
        reward = jnp.mean(rewards)
        if axis_name is not None:
            reward = jax.lax.pmean(reward, axis_name)
        return env_state, obs, wbuf, {"reward": reward}

    def learner_core(learner, key, n_updates, axis_name):
        if axis_name is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
            reduce = functools.partial(jax.lax.pmean, axis_name=axis_name)
        else:
            def reduce(x):
                return x
        total_size = rb.replay_total_size(learner.extras.replay)
        if axis_name is not None:
            total_size = jax.lax.psum(total_size, axis_name)
        learner, losses = learner_phase(learner, key, total_size,
                                        n_updates, reduce)
        loss = jnp.mean(losses)
        if axis_name is not None:
            loss = jax.lax.pmean(loss, axis_name)
        return learner, {"loss": loss}

    if mesh is None:
        @functools.partial(jax.jit, static_argnames=("n_chunks",),
                           donate_argnums=(1, 2, 3))
        def actor_chunk(snap, env_state, obs, wbuf, key, *, n_chunks):
            return actor_core(snap, env_state, obs, wbuf, key, n_chunks,
                              None)

        @functools.partial(jax.jit, static_argnames=("n_updates",),
                           donate_argnums=(0,))
        def learner_chunk(learner, key, *, n_updates):
            return learner_core(learner, key, n_updates, None)
    else:
        @functools.partial(jax.jit, static_argnames=("n_chunks",),
                           donate_argnums=(1, 2, 3))
        def actor_chunk(snap, env_state, obs, wbuf, key, *, n_chunks):
            sharded = shard_map_compat(
                functools.partial(actor_core, n_chunks=n_chunks,
                                  axis_name=axis),
                mesh,
                in_specs=(P(), P(axis), P(axis), P(axis), P()),
                out_specs=(P(axis), P(axis), P(axis), {"reward": P()}))
            return sharded(snap, env_state, obs, wbuf, key)

        @functools.partial(jax.jit, static_argnames=("n_updates",),
                           donate_argnums=(0,))
        def learner_chunk(learner, key, *, n_updates):
            specs = _learner_specs(learner, axis)
            sharded = shard_map_compat(
                functools.partial(learner_core, n_updates=n_updates,
                                  axis_name=axis),
                mesh,
                in_specs=(specs, P()),
                out_specs=(specs, {"loss": P()}))
            return sharded(learner, key)

    _div = _make_divergence(parts, int8, n, envs_per_actor, obs_shape)

    @jax.jit
    def divergence(learner, snap: ActorSnapshot, obs):
        """(num_actors,) mean-abs behaviour-head gap of a fresh snapshot
        vs the live learner head — the per-sync divergence record (pure
        int8-vs-fp32 quantization gap right after a push)."""
        return _div(learner, snap.params, snap.cache, obs)

    return AsyncPrograms(actor_chunk=actor_chunk,
                         learner_chunk=learner_chunk,
                         make_snapshot=make_snapshot,
                         divergence=divergence,
                         act_fn=parts.act_fn,
                         benv_global=benv_global)
