"""ActorQ actor–learner topology: int8 actor fan-out + fp32 replay learner.

The paper's headline system is a distributed training paradigm: a pool of
8-bit quantized *actors* collects experience into a replay buffer while a
full-precision *learner* samples batches and periodically broadcasts
refreshed parameters to the actors.  This module reproduces that topology on
top of the repo's replay algorithms (DQN, DDPG — the paper's DQN/D4PG
analogues):

* **Actor fan-out** — ``num_actors`` actor replicas, each running
  ``cfg.n_envs`` environments with the behaviour policy of the underlying
  algorithm (``dqn.make_behaviour_policy`` / ``ddpg.make_behaviour_policy``).
  With ``actor_backend="int8"`` every replica packs the synced params into
  an int8 cache once per iteration and steps through the W8A8 kernel — the
  ActorQ hot path.  On a device mesh the actor axis is ``shard_map``-ped
  (generalizing ``rl.distributed``); on a single host the replicas are one
  vectorized env batch (same math, no collectives).
* **Sharded replay** — each actor owns one shard of the replay buffer
  (``buffer.replay_init_sharded``; per-shard capacity =
  ``buffer_size / num_actors``) and writes only its own shard.  With
  ``replay="prioritized"`` every shard carries its own sum-tree
  (``buffer.per_init_sharded``): the learner samples
  priority-proportionally per shard with IS-weight correction and pushes
  refreshed |TD| priorities back to each shard after every update — all
  inside the shard_map, so the actor axis never gathers.
* **fp32 learner** — samples ``batch_size / num_actors`` transitions per
  shard, concatenates, and applies the algorithm's TD/actor-critic update
  (``dqn.make_td_update`` / ``ddpg.make_update``).  Under ``shard_map`` the
  gradients are ``pmean``-averaged across the actor axis — synchronous
  data-parallel learning, every replica holds identical learner state.
* **Staleness knob** — the learner pushes refreshed params to the actors
  only every ``sync_every`` iterations; between syncs the actors run stale
  params, exactly the decoupling the paper exploits for throughput.
* **Divergence metrics** — at every sync point the topology records, per
  actor, the mean absolute gap between the freshly-synced actor behaviour
  head and the fp32 learner head on that actor's current observations
  (with ``actor_backend="int8"`` this is the pure int8-vs-fp32
  quantization divergence; with ``"fp32"`` it is identically zero).  The
  last recorded value carries through non-sync iterations, keeping the
  metric off the rollout hot path.

Single-actor equivalence: with ``num_actors=1`` and ``sync_every=1`` (no
mesh) the topology is *bitwise identical* to the fused ``loops.train``
driver for DQN — same PRNG chain, same replay contents, same updates —
which is the parity contract ``tests/test_actor_learner.py`` enforces.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.rl import actorq, common, ddpg, dqn
from repro.rl import buffer as rb
from repro.rl.distributed import shard_map_compat
from repro.rl.env import Env, batched_env, rollout

ALGOS = ("dqn", "ddpg")
TOPOLOGIES = ("fused", "actor-learner")


def validate_topology(topology: str) -> str:
    if topology not in TOPOLOGIES:
        raise ValueError(f"topology must be one of {TOPOLOGIES}, "
                         f"got {topology!r}")
    return topology


@dataclasses.dataclass(frozen=True)
class ActorLearnerConfig:
    """Topology knobs (the algorithm's own config rides separately)."""
    num_actors: int = 2
    sync_every: int = 1           # learner->actor param push cadence (iters)


class ActorLearnerState(NamedTuple):
    learner: common.TrainState    # fp32 learner; extras.replay is sharded
    actor_params: Any             # the actors' (possibly stale) param copy
    t: jnp.ndarray                # iterations completed
    divergence: jnp.ndarray       # (num_actors,) actor-vs-learner head gap


def init(key, env: Env, net, algo: str, cfg, al: ActorLearnerConfig
         ) -> ActorLearnerState:
    """Learner state + actor copy + sharded replay.

    ``net``/``cfg`` are the underlying algorithm's network(s) and config
    (``dqn.DQNConfig`` / ``ddpg.DDPGConfig``).  The algorithm's fused
    replay is swapped for the sharded layout (total capacity conserved:
    ``buffer_size / num_actors`` per shard).  The actor copy is a real
    copy, not an alias — the scan-fused driver donates the whole state and
    donation rejects one buffer appearing twice.
    """
    if algo not in ALGOS:
        raise ValueError(f"actor-learner supports {ALGOS}, got {algo!r}")
    n = al.num_actors
    if n < 1 or cfg.buffer_size % n:
        raise ValueError(f"buffer_size {cfg.buffer_size} must divide by "
                         f"num_actors {n}")
    mod = {"dqn": dqn, "ddpg": ddpg}[algo]
    state = mod.init(key, env, net, cfg)
    init_sharded = rb.per_init_sharded \
        if rb.use_prioritized(cfg.replay, cfg.priority_exponent) \
        else rb.replay_init_sharded
    if algo == "ddpg":
        sharded = init_sharded(
            n, cfg.buffer_size // n, env.spec.obs_shape,
            action_shape=(env.spec.action_dim,), action_dtype=jnp.float32)
    else:
        sharded = init_sharded(n, cfg.buffer_size // n,
                               env.spec.obs_shape)
    state = state._replace(extras=state.extras._replace(replay=sharded))
    actor_params = jax.tree_util.tree_map(jnp.array, state.params)
    return ActorLearnerState(
        learner=state, actor_params=actor_params,
        t=jnp.zeros((), jnp.int32),
        divergence=jnp.zeros((al.num_actors,), jnp.float32))


def _state_specs(state: ActorLearnerState, axis: str):
    """Partition specs for the state pytree: replay + divergence live on the
    actor axis, everything else (learner params/opt, actor copy) replicated.
    """
    def one(path, leaf):
        names = {getattr(entry, "name", None) for entry in path}
        sharded = "replay" in names or "divergence" in names
        return P(axis) if sharded else P()
    return jax.tree_util.tree_map_with_path(one, state)


def make_actor_learner(algo: str, env: Env, net, cfg,
                       al: ActorLearnerConfig, mesh=None,
                       axis: str = "actor"):
    """Returns ``(iteration, act_fn, benv_global)``.

    ``iteration(state, env_state, obs, key) -> (state, env_state, obs,
    metrics)`` — the same contract as the fused algorithms, so the
    scan-fused driver (``loops.make_scan_iteration``) and ``loops.train``
    drive it unchanged.  ``benv_global`` batches
    ``num_actors * cfg.n_envs`` environments (actor-major layout).

    With ``mesh`` given, the actor axis is ``shard_map``-ped over
    ``mesh.shape[axis]`` devices (``num_actors`` must divide by it; each
    device runs ``num_actors / n_dev`` replicas) and learner gradients are
    ``pmean``-averaged.  Without a mesh the replicas run as one vectorized
    batch on the local device.
    """
    if algo not in ALGOS:
        raise ValueError(f"actor-learner supports {ALGOS}, got {algo!r}")
    actorq.validate_actor_backend(cfg.actor_backend)
    use_per = rb.use_prioritized(cfg.replay, cfg.priority_exponent)
    if al.sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {al.sync_every}")
    n = al.num_actors
    n_dev = mesh.shape[axis] if mesh is not None else 1
    if n % n_dev:
        raise ValueError(f"num_actors {n} must divide by the mesh "
                         f"{axis!r} axis size {n_dev}")
    local_actors = n // n_dev
    envs_per_actor = cfg.n_envs
    if cfg.batch_size % n:
        raise ValueError(f"batch_size {cfg.batch_size} must divide by "
                         f"num_actors {n}")
    per_actor_batch = cfg.batch_size // n
    benv_local = batched_env(env, local_actors * envs_per_actor)
    benv_global = batched_env(env, n * envs_per_actor)
    obs_shape = tuple(env.spec.obs_shape)

    if algo == "dqn":
        _build = dqn.make_behaviour_policy(env, net, cfg)
        learn = dqn.make_td_update(env, net, cfg)

        def build_policy(learner, actor_params):
            return _build(actor_params, learner.observers, learner.step,
                          learner.extras.updates)

        def fp32_head(params, obs, observers, step):
            return dqn._q_values(net, cfg, params, obs, observers, step)[0]

        def actor_head(params, obs):
            qp = actorq.pack_actor_params(params)
            return actorq.quantized_apply(qp, obs,
                                          backend=cfg.kernel_backend)
    else:
        _build = ddpg.make_behaviour_policy(env, net, cfg)
        learn = ddpg.make_update(env, net, cfg)

        def build_policy(learner, actor_params):
            return _build(actor_params, learner.observers, learner.step)

        def fp32_head(params, obs, observers, step):
            return ddpg._actor_out(net, cfg, params, obs, observers,
                                   step)[0]

        def actor_head(params, obs):
            qp = actorq.pack_actor_params(params)
            return jnp.tanh(actorq.quantized_apply(
                qp, obs, backend=cfg.kernel_backend))

    def divergence(learner, actor_params, obs):
        """(local_actors,) mean-abs behaviour-head gap, per actor."""
        obs_a = obs.reshape((local_actors, envs_per_actor) + obs_shape)

        def one(o):
            fresh = fp32_head(learner.params, o, learner.observers,
                              learner.step)
            if cfg.actor_backend == "int8":
                behaved = actor_head(actor_params, o)
            else:
                behaved = fp32_head(actor_params, o, learner.observers,
                                    learner.step)
            return jnp.mean(jnp.abs(behaved - fresh))
        return jax.vmap(one)(obs_a)

    def core(state: ActorLearnerState, env_state, obs, key, axis_name):
        if axis_name is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
            reduce = functools.partial(jax.lax.pmean, axis_name=axis_name)
        else:
            def reduce(x):
                return x
        learner, actor_params = state.learner, state.actor_params
        k_roll, k_updates = jax.random.split(key)

        # --- actor phase: stale-param rollouts into the local shards -----
        policy = build_policy(learner, actor_params)
        env_state, obs, traj = rollout(
            benv_local, policy, actor_params, env_state, obs, k_roll,
            cfg.rollout_steps)

        def to_shards(x):
            t_dim, trail = x.shape[0], x.shape[2:]
            y = x.reshape((t_dim, local_actors, envs_per_actor) + trail)
            y = jnp.moveaxis(y, 1, 0)
            return y.reshape((local_actors, t_dim * envs_per_actor) + trail)
        flat = jax.tree_util.tree_map(to_shards, traj)
        add_sharded = rb.per_add_sharded if use_per \
            else rb.replay_add_sharded
        replay = add_sharded(
            learner.extras.replay,
            rb.Transition(flat.obs, flat.action, flat.reward, flat.done,
                          flat.next_obs))
        learner = learner._replace(
            extras=learner.extras._replace(replay=replay))
        total_size = rb.replay_total_size(replay)
        if axis_name is not None:
            total_size = jax.lax.psum(total_size, axis_name)

        # --- learner phase: per-shard sampling, fp32 updates -------------
        def one_update(st, k):
            keys_a = k[None] if local_actors == 1 \
                else jax.random.split(k, local_actors)
            if use_per:
                # same anneal schedule as the fused drivers
                # (common.per_beta); priority pushes stay per-shard,
                # inside the shard_map — the actor axis never gathers
                beta = common.per_beta(st, cfg)
                shards, idx, w = rb.per_sample_sharded(
                    st.extras.replay, keys_a, per_actor_batch, beta)
                batch = jax.tree_util.tree_map(
                    lambda x: x.reshape((-1,) + x.shape[2:]), shards)
                st, (loss, td_abs) = learn(st, batch, total_size,
                                           weights=w.reshape(-1),
                                           reduce=reduce)
                per = rb.per_update_priorities_sharded(
                    st.extras.replay, idx, td_abs.reshape(idx.shape),
                    cfg.priority_exponent)
                st = st._replace(extras=st.extras._replace(replay=per))
                return st, loss
            shards = rb.replay_sample_sharded(st.extras.replay, keys_a,
                                              per_actor_batch)
            batch = jax.tree_util.tree_map(
                lambda x: x.reshape((-1,) + x.shape[2:]), shards)
            st, (loss, _) = learn(st, batch, total_size, reduce=reduce)
            return st, loss

        learner, losses = jax.lax.scan(
            one_update, learner,
            jax.random.split(k_updates, cfg.updates_per_iter))

        # --- sync phase: staleness knob + divergence metric ---------------
        t = state.t + 1
        do_sync = (t % al.sync_every) == 0
        actor_params = jax.tree_util.tree_map(
            lambda a, p: jnp.where(do_sync, p, a), actor_params,
            learner.params)
        # divergence is recorded at sync points only (lax.cond keeps the
        # extra head passes + int8 re-pack off the non-sync iterations);
        # between syncs the last recorded value carries through
        div = jax.lax.cond(
            do_sync,
            lambda args: divergence(*args),
            lambda args: state.divergence,
            (learner, actor_params, obs))

        reward = jnp.sum(traj.reward) / jnp.maximum(jnp.sum(traj.done),
                                                    1.0)
        loss = jnp.mean(losses)
        if axis_name is not None:
            reward = jax.lax.pmean(reward, axis_name)
            loss = jax.lax.pmean(loss, axis_name)
        metrics = {"loss": loss, "reward": reward, "divergence": div}
        new_state = ActorLearnerState(learner, actor_params, t, div)
        return new_state, env_state, obs, metrics

    if mesh is None:
        @jax.jit
        def iteration(state, env_state, obs, key):
            return core(state, env_state, obs, key, None)
    else:
        @jax.jit
        def iteration(state, env_state, obs, key):
            specs = _state_specs(state, axis)
            metric_specs = {"loss": P(), "reward": P(),
                            "divergence": P(axis)}
            sharded = shard_map_compat(
                functools.partial(core, axis_name=axis), mesh,
                in_specs=(specs, P(axis), P(axis), P()),
                out_specs=(specs, P(axis), P(axis), metric_specs))
            return sharded(state, env_state, obs, key)

    if algo == "dqn":
        def act_fn(params, obs, observers=None, step=1 << 30):
            q = fp32_head(params, obs, observers or {},
                          jnp.asarray(step))
            return jnp.argmax(q, axis=-1).astype(jnp.int32)
    else:
        def act_fn(params, obs, observers=None, step=1 << 30):
            a = fp32_head(params, obs, observers or {}, jnp.asarray(step))
            return a * env.spec.action_scale

    return iteration, act_fn, benv_global
