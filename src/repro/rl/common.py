"""Shared RL plumbing: train-state, QAT context wiring, eval helpers."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fake_quant, ptq
from repro.core.qconfig import QuantConfig
from repro.optim.adam import AdamState
from repro.rl import buffer as rb


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    observers: Dict[str, fake_quant.ObserverState]
    step: jnp.ndarray
    extras: Any = ()       # algo-specific (target params, noise scale, ...)


def make_ctx(quant: QuantConfig, observers, step):
    return fake_quant.make_context(quant, observers, step)


class PrefixCtx:
    """Namespaces a QAT context (e.g. DDPG actor vs critic observer sites)."""

    def __init__(self, ctx, prefix: str):
        self._ctx = ctx
        self._prefix = prefix

    @property
    def config(self):
        return self._ctx.config

    @property
    def enabled(self):
        # ``enabled`` is a required part of the ctx contract — no fallback.
        return self._ctx.enabled

    def weight(self, name, w):
        return self._ctx.weight(self._prefix + name, w)

    def activation(self, name, x):
        return self._ctx.activation(self._prefix + name, x)

    def merged_collection(self):
        return self._ctx.merged_collection()


def eval_params(params: Any, quant: QuantConfig) -> Any:
    """Apply Algorithm 1/2's evaluation-time quantization to the params.

    PTQ: quantize-dequantize the trained weights.
    QAT: the same fake-quant map with the final (frozen) weight ranges —
    evaluation runs the quantized policy, matching the paper's Eval(Q(M)).
    """
    if quant.is_ptq:
        return ptq.ptq_simulate(params, quant)
    if quant.is_qat:
        def one(path, leaf):
            if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                from repro.core import affine
                return affine.ptq_tensor(leaf, quant.bits,
                                         axis=leaf.ndim - 1
                                         if leaf.ndim == 4 else None)
            return leaf
        return jax.tree_util.tree_map_with_path(one, params)
    return params


def per_beta(state: TrainState, cfg) -> jnp.ndarray:
    """IS-correction exponent for this learner step.

    Anneals ``cfg.is_beta -> 1`` linearly over ``is_beta_anneal_updates``
    counted on ``state.extras.updates`` — the *learner-update* counter both
    DQN and DDPG carry in their extras, which advances only when an update
    actually lands (warmup steps, whose parameter updates are discarded,
    do not move the schedule).  Counting real updates makes the schedule
    driver-independent: the fused per-step loop, the scan-fused driver
    (``steps_per_call > 1``) and both actor–learner topologies all reach
    ``beta == 1.0`` at exactly ``is_beta_anneal_updates`` learner updates.
    (``state.step``, the unconditional per-call counter, would instead
    anneal on attempted calls — warmup- and chunking-dependent.)
    """
    return linear_epsilon(state.extras.updates, cfg.is_beta, 1.0,
                          cfg.is_beta_anneal_updates)


def per_learner_step(state: TrainState, key, cfg, update_fn):
    """One prioritized learner step on the single (fused) buffer.

    The shared sample -> weighted update -> priority-push protocol used by
    both fused replay algorithms: anneal beta (``per_beta``), draw a
    priority-proportional batch with IS weights, run the algorithm's
    update, and push the refreshed per-transition |TD| back into the
    sum-tree.  (The actor–learner topology runs the same protocol with the
    ``*_sharded`` buffer ops — see ``rl.actor_learner``.)
    """
    beta = per_beta(state, cfg)
    batch, idx, w = rb.per_sample(state.extras.replay, key,
                                  cfg.batch_size, beta)
    state, (loss, td_abs) = update_fn(
        state, batch, state.extras.replay.replay.size, weights=w)
    per = rb.per_update_priorities(state.extras.replay, idx, td_abs,
                                   cfg.priority_exponent)
    return state._replace(
        extras=state.extras._replace(replay=per)), loss


def linear_epsilon(step, start: float, end: float, decay_steps: int):
    frac = jnp.clip(step.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
    return start + frac * (end - start)


def soft_update(target, online, tau: float):
    return jax.tree_util.tree_map(
        lambda t, o: (1 - tau) * t + tau * o, target, online)


def huber(x, delta: float = 1.0):
    a = jnp.abs(x)
    return jnp.where(a <= delta, 0.5 * x * x, delta * (a - 0.5 * delta))
