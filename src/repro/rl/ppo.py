"""PPO (Schulman et al. 2017): GAE + clipped surrogate, minibatch epochs.

``actor_backend="int8"`` collects rollouts with the packed int8 actor
(``rl.actorq``): behaviour logits/values/log-probs all come from the
quantized head, so the clipped-surrogate importance ratio corrects for the
int8/fp32 gap exactly as it corrects for ordinary policy lag.  The learner
(minibatch epochs) stays fp32 — the paper's ActorQ split.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantConfig
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.rl import actorq, common
from repro.rl.env import Env, batched_env, rollout
from repro.rl.networks import Network


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    n_envs: int = 16
    n_steps: int = 64
    epochs: int = 4
    n_minibatches: int = 4
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    quant: QuantConfig = QuantConfig.none()
    # ActorQ: "int8" samples rollout actions (and behaviour logp/values)
    # from the packed int8 actor ("int4" = byte-packed W4A8); the
    # minibatch learner stays fp32.
    actor_backend: str = "fp32"
    kernel_backend: str = "auto"
    # calib_batch > 0: static activation scales -> fused MLP kernel
    # (see DQNConfig.calib_batch).  0 keeps dynamic quantization.
    calib_batch: int = 0


def init(key, env: Env, net: Network, cfg: PPOConfig):
    params = net.init(key)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    return common.TrainState(params=params, opt=opt, observers={},
                             step=jnp.zeros((), jnp.int32), extras=())


def gae(rewards, dones, values, last_value, gamma, lam):
    """values: (T, B); returns (advantages, returns)."""
    def step(carry, inp):
        adv, next_value = carry
        reward, done, value = inp
        delta = reward + gamma * next_value * (1 - done) - value
        adv = delta + gamma * lam * (1 - done) * adv
        return (adv, value), adv
    (_, _), advs = jax.lax.scan(
        step, (jnp.zeros_like(last_value), last_value),
        (rewards, dones, values), reverse=True)
    return advs, advs + values


def make_iteration(env: Env, net: Network, cfg: PPOConfig):
    actorq.validate_actor_backend(cfg.actor_backend)
    benv = batched_env(env, cfg.n_envs)
    adam_cfg = AdamConfig(lr=cfg.lr)
    n_act = env.spec.n_actions

    def heads(params, obs, observers, step):
        ctx = common.make_ctx(cfg.quant, observers, step)
        out = net.apply(ctx, params, obs)
        return out[..., :n_act], out[..., n_act], ctx.merged_collection()

    def sample_head(logits, k):
        action = jax.random.categorical(k, logits)
        logp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                   action[..., None], axis=-1)[..., 0]
        return action.astype(jnp.int32), logp

    @jax.jit
    def iteration(state: common.TrainState, env_state, obs, key):
        k_roll, k_perm = jax.random.split(key)

        if actorq.is_quantized(cfg.actor_backend):
            # ActorQ hot path: pack once per learner update; every env step
            # of the rollout scan reuses the int cache.  Behaviour logp and
            # bootstrap values come from the quantized head so the clipped
            # ratio sees the true behaviour distribution.
            qparams = actorq.make_actor_cache(
                state.params, cfg.actor_backend,
                calib_obs=actorq.calib_slice(obs, cfg.calib_batch)
                if cfg.calib_batch else None,
                backend=cfg.kernel_backend)

            def policy(params, obs, k):
                out = actorq.quantized_apply(qparams, obs,
                                             backend=cfg.kernel_backend)
                logits, value = out[..., :n_act], out[..., n_act]
                action, logp = sample_head(logits, k)
                return action, (logits, value, logp)
        else:
            def policy(params, obs, k):
                logits, value, _ = heads(params, obs, state.observers,
                                         state.step)
                action, logp = sample_head(logits, k)
                return action, (logits, value, logp)

        env_state, last_obs, traj = rollout(
            benv, policy, state.params, env_state, obs, k_roll, cfg.n_steps)
        logits_b, values_b, logp_b = traj.logits_or_value
        if actorq.is_quantized(cfg.actor_backend):
            # bootstrap from the same (quantized) behaviour value head as
            # the per-step values so GAE sees one consistent value function
            last_value = actorq.quantized_apply(
                qparams, last_obs, backend=cfg.kernel_backend)[..., n_act]
        else:
            _, last_value, _ = heads(state.params, last_obs,
                                     state.observers, state.step)
        advs, returns = gae(traj.reward, traj.done, values_b,
                            last_value, cfg.gamma, cfg.gae_lambda)
        advs_n = (advs - advs.mean()) / (advs.std() + 1e-8)

        # flatten (T, B) -> (T*B,)
        def flat(x):
            return x.reshape((-1,) + x.shape[2:])
        data = dict(obs=flat(traj.obs), action=flat(traj.action),
                    logp=flat(logp_b), adv=flat(advs_n),
                    ret=flat(returns))
        n_data = data["adv"].shape[0]
        mb = n_data // cfg.n_minibatches

        def epoch(carry, k):
            params, opt, observers = carry
            perm = jax.random.permutation(k, n_data)

            def minibatch(carry, idx):
                params, opt, observers = carry
                mb_data = {k2: v[idx] for k2, v in data.items()}

                def loss_fn(p):
                    logits, values, new_coll = heads(p, mb_data["obs"],
                                                     observers, state.step)
                    logp = jnp.take_along_axis(
                        jax.nn.log_softmax(logits, -1),
                        mb_data["action"][..., None], axis=-1)[..., 0]
                    ratio = jnp.exp(logp - mb_data["logp"])
                    clipped = jnp.clip(ratio, 1 - cfg.clip_eps,
                                       1 + cfg.clip_eps)
                    pg = -jnp.minimum(ratio * mb_data["adv"],
                                      clipped * mb_data["adv"]).mean()
                    v_loss = jnp.square(values - mb_data["ret"]).mean()
                    p_ = jax.nn.softmax(logits, -1)
                    ent = -jnp.sum(
                        p_ * jax.nn.log_softmax(logits, -1), -1).mean()
                    return pg + cfg.value_coef * v_loss \
                        - cfg.entropy_coef * ent, new_coll

                (loss, new_coll), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params)
                params, opt, _ = adam_update(grads, opt, params, adam_cfg)
                return (params, opt, new_coll), loss

            idxs = perm[:mb * cfg.n_minibatches].reshape(
                cfg.n_minibatches, mb)
            carry, losses = jax.lax.scan(minibatch,
                                         (params, opt, observers), idxs)
            return carry, jnp.mean(losses)

        (params, opt, observers), losses = jax.lax.scan(
            epoch, (state.params, state.opt, state.observers),
            jax.random.split(k_perm, cfg.epochs))
        state = common.TrainState(params, opt, observers, state.step + 1, ())
        metrics = {"loss": jnp.mean(losses),
                   "reward": jnp.sum(traj.reward) / jnp.maximum(
                       jnp.sum(traj.done), 1.0),
                   "action_dist_variance": jnp.var(
                       jax.nn.softmax(logits_b, -1), -1).mean()}
        return state, env_state, last_obs, metrics

    def act_fn(params, obs, observers=None, step=1 << 30):
        ctx = common.make_ctx(cfg.quant, observers or {}, step)
        out = net.apply(ctx, params, obs)
        return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)

    return iteration, act_fn, benv
