"""DDPG (Lillicrap et al. 2015): deterministic actor-critic, replay,
soft target updates, Gaussian exploration noise (modern replacement for the
original OU noise — documented deviation)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantConfig
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.rl import actorq
from repro.rl import buffer as rb
from repro.rl import common
from repro.rl.env import Env, batched_env, rollout
from repro.rl.networks import Network


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.01
    buffer_size: int = 50_000
    batch_size: int = 128
    n_envs: int = 8
    rollout_steps: int = 8
    updates_per_iter: int = 8
    noise_sigma: float = 0.2
    warmup: int = 1000
    quant: QuantConfig = QuantConfig.none()
    # ActorQ: "int8" runs rollout data collection (the exploration policy's
    # mu head) through the packed int8 actor ("int4" = byte-packed W4A8,
    # half the cache); the critic and both gradient paths stay fp32 — the
    # paper's D4PG-style ActorQ split.
    actor_backend: str = "fp32"
    kernel_backend: str = "auto"
    # calib_batch > 0: static activation scales from that many rollout
    # observations at each cache refresh -> single-pass fused MLP kernel
    # (see DQNConfig.calib_batch).  0 keeps dynamic quantization.
    calib_batch: int = 0
    # Replay discipline (see rl.buffer): priorities come from the critic's
    # per-transition |TD error| — the paper's prioritized D4PG analogue.
    # priority_exponent=0.0 is bitwise-uniform (static dispatch).
    replay: str = "uniform"
    priority_exponent: float = 0.6
    is_beta: float = 0.4
    is_beta_anneal_updates: int = 4000


class DDPGExtras(NamedTuple):
    critic_params: Any
    target_actor: Any
    target_critic: Any
    critic_opt: AdamState
    replay: rb.ReplayState
    # learner updates that actually landed (warmup-discarded calls excluded);
    # drives the IS-beta anneal (common.per_beta) and the async staleness
    # accounting — the counter every replay algorithm's extras must carry
    updates: jnp.ndarray


class DDPGNets(NamedTuple):
    actor: Network
    critic: Network


def make_nets(env: Env, hidden=(64, 64)) -> DDPGNets:
    from repro.rl.networks import make_network
    obs_dim = int(jnp.prod(jnp.asarray(env.spec.obs_shape)))
    a_dim = env.spec.action_dim
    actor = make_network(env.spec.obs_shape, a_dim, hidden=hidden)
    critic = make_network((obs_dim + a_dim,), 1, hidden=hidden)
    return DDPGNets(actor, critic)


def init(key, env: Env, nets: DDPGNets, cfg: DDPGConfig):
    k1, k2 = jax.random.split(key)
    actor_params = nets.actor.init(k1)
    critic_params = nets.critic.init(k2)
    opt = adam_init(actor_params, AdamConfig(lr=cfg.actor_lr))
    copt = adam_init(critic_params, AdamConfig(lr=cfg.critic_lr))
    replay_init = rb.per_init \
        if rb.use_prioritized(cfg.replay, cfg.priority_exponent) \
        else rb.replay_init
    replay = replay_init(cfg.buffer_size, env.spec.obs_shape,
                         action_shape=(env.spec.action_dim,),
                         action_dtype=jnp.float32)
    # copies, not aliases: the scan-fused driver donates the TrainState and
    # donation rejects the same buffer appearing twice
    target_actor = jax.tree_util.tree_map(jnp.array, actor_params)
    target_critic = jax.tree_util.tree_map(jnp.array, critic_params)
    return common.TrainState(
        params=actor_params, opt=opt, observers={},
        step=jnp.zeros((), jnp.int32),
        extras=DDPGExtras(critic_params, target_actor, target_critic,
                          copt, replay, jnp.zeros((), jnp.int32)))


def _actor_out(nets, cfg, params, obs, observers, step):
    base = common.make_ctx(cfg.quant, observers, step)
    ctx = common.PrefixCtx(base, "actor/")
    return jnp.tanh(nets.actor.apply(ctx, params, obs)), \
        base.merged_collection()


def make_behaviour_policy(env: Env, nets: DDPGNets, cfg: DDPGConfig):
    """``build(params, observers, step, qparams=None) -> policy(_, obs, key)``.

    Gaussian-noise exploration over the deterministic actor.  With
    ``actor_backend="int8"`` the mu head runs through the packed int8 actor
    (one pack per build = per learner update, or the caller's carried
    ``qparams`` cache — see ``dqn.make_behaviour_policy``); noise/clip/scale
    stay fp32.
    """
    scale = env.spec.action_scale

    def build(params, observers, step, qparams=None):
        if actorq.is_quantized(cfg.actor_backend):
            if qparams is None:
                qparams = actorq.pack_actor_params(
                    params, actorq.backend_bits(cfg.actor_backend))

            def mu_fn(obs):
                mu = actorq.quantized_apply(qparams, obs,
                                            backend=cfg.kernel_backend)
                return jnp.tanh(mu)
        else:
            def mu_fn(obs):
                return _actor_out(nets, cfg, params, obs, observers,
                                  step)[0]

        def policy(_params, obs, k):
            a = mu_fn(obs)
            noise = cfg.noise_sigma * jax.random.normal(k, a.shape)
            return jnp.clip(a + noise, -1.0, 1.0) * scale, a
        return policy
    return build


def make_update(env: Env, nets: DDPGNets, cfg: DDPGConfig):
    """``update(state, batch, replay_size, weights, reduce) ->
    (state, (loss, td_abs))``.

    One critic + actor learner step on an already-sampled batch; ``reduce``
    (identity / ``lax.pmean``) is applied to each gradient before its Adam
    update so the same function serves the fused loop and the data-parallel
    learner of the actor–learner topology.  ``weights`` are optional
    per-transition IS weights (prioritized replay) applied to the *critic*
    loss — the TD-learning half, where the sampling bias matters; the
    actor's deterministic-policy-gradient term stays an unweighted mean
    (standard prioritized-D4PG practice).  ``td_abs`` is the critic's
    per-transition |TD error| (never ``reduce``-averaged — priorities are
    shard-local in the actor–learner topology).
    """
    a_cfg = AdamConfig(lr=cfg.actor_lr)
    c_cfg = AdamConfig(lr=cfg.critic_lr)

    def actor_out(params, obs, observers, step):
        return _actor_out(nets, cfg, params, obs, observers, step)

    def critic_out(params, obs, action, observers, step):
        base = common.make_ctx(cfg.quant, observers, step)
        ctx = common.PrefixCtx(base, "critic/")
        x = jnp.concatenate(
            [obs.reshape(obs.shape[:-len(env.spec.obs_shape)] + (-1,)),
             action], axis=-1)
        return nets.critic.apply(ctx, params, x)[..., 0], \
            base.merged_collection()

    def update(state: common.TrainState, batch: rb.Transition,
               replay_size, weights=None, reduce=lambda x: x):
        ex = state.extras

        def critic_loss(cp):
            next_a, _ = actor_out(ex.target_actor, batch.next_obs,
                                  state.observers, state.step)
            q_next, _ = critic_out(ex.target_critic, batch.next_obs, next_a,
                                   state.observers, state.step)
            target = batch.reward + cfg.gamma * (1 - batch.done) * q_next
            q, new_coll = critic_out(cp, batch.obs, batch.action,
                                     state.observers, state.step)
            td = q - jax.lax.stop_gradient(target)
            if weights is None:
                loss = jnp.mean(jnp.square(td))
            else:
                loss = jnp.mean(weights * jnp.square(td))
            return loss, (new_coll, jnp.abs(td))

        (closs, (new_coll, td_abs)), cgrads = jax.value_and_grad(
            critic_loss, has_aux=True)(ex.critic_params)
        cgrads, closs, new_coll = reduce(cgrads), reduce(closs), \
            reduce(new_coll)
        critic_params, critic_opt, _ = adam_update(
            cgrads, ex.critic_opt, ex.critic_params, c_cfg)

        def actor_loss(ap):
            a, coll2 = actor_out(ap, batch.obs, new_coll, state.step)
            q, _ = critic_out(critic_params, batch.obs,
                              a * env.spec.action_scale, new_coll,
                              state.step)
            return -jnp.mean(q), coll2

        (aloss, new_coll2), agrads = jax.value_and_grad(
            actor_loss, has_aux=True)(state.params)
        agrads, aloss, new_coll2 = reduce(agrads), reduce(aloss), \
            reduce(new_coll2)
        actor_params, actor_opt, _ = adam_update(
            agrads, state.opt, state.params, a_cfg)

        warm = replay_size >= cfg.warmup
        actor_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(warm, n, o), actor_params, state.params)
        critic_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(warm, n, o), critic_params,
            ex.critic_params)

        target_actor = common.soft_update(ex.target_actor, actor_params,
                                          cfg.tau)
        target_critic = common.soft_update(ex.target_critic, critic_params,
                                           cfg.tau)
        state = common.TrainState(
            actor_params, actor_opt, new_coll2, state.step + 1,
            DDPGExtras(critic_params, target_actor, target_critic,
                       critic_opt, ex.replay,
                       jnp.where(warm, ex.updates + 1, ex.updates)))
        return state, (closs + aloss, td_abs)

    return update


def make_iteration(env: Env, nets: DDPGNets, cfg: DDPGConfig):
    actorq.validate_actor_backend(cfg.actor_backend)
    use_per = rb.use_prioritized(cfg.replay, cfg.priority_exponent)
    benv = batched_env(env, cfg.n_envs)
    build_policy = make_behaviour_policy(env, nets, cfg)
    update = make_update(env, nets, cfg)

    @jax.jit
    def iteration(state: common.TrainState, env_state, obs, key):
        k_roll, k_up = jax.random.split(key)
        policy_kw = {}
        if actorq.is_quantized(cfg.actor_backend) and cfg.calib_batch:
            # static-requant mode (see dqn.make_iteration)
            policy_kw["qparams"] = actorq.make_actor_cache(
                state.params, cfg.actor_backend,
                calib_obs=actorq.calib_slice(obs, cfg.calib_batch),
                backend=cfg.kernel_backend)
        policy = build_policy(state.params, state.observers, state.step,
                              **policy_kw)
        env_state, obs, traj = rollout(benv, policy, state.params,
                                       env_state, obs, k_roll,
                                       cfg.rollout_steps)
        flat = jax.tree_util.tree_map(
            lambda x: x.reshape((-1,) + x.shape[2:]), traj)
        add = rb.per_add if use_per else rb.replay_add_batch
        replay = add(
            state.extras.replay,
            rb.Transition(flat.obs, flat.action, flat.reward, flat.done,
                          flat.next_obs))
        state = state._replace(extras=state.extras._replace(replay=replay))

        def one_update(st, k):
            if use_per:
                return common.per_learner_step(st, k, cfg, update)
            batch = rb.replay_sample(st.extras.replay, k, cfg.batch_size)
            st, (loss, _) = update(st, batch, st.extras.replay.size)
            return st, loss
        state, losses = jax.lax.scan(
            one_update, state, jax.random.split(k_up, cfg.updates_per_iter))
        metrics = {"loss": jnp.mean(losses),
                   "reward": jnp.sum(traj.reward) / jnp.maximum(
                       jnp.sum(traj.done), 1.0)}
        return state, env_state, obs, metrics

    def act_fn(params, obs, observers=None, step=1 << 30):
        ctx = common.make_ctx(cfg.quant, observers or {}, step)
        return jnp.tanh(nets.actor.apply(ctx, params, obs)) \
            * env.spec.action_scale

    return iteration, act_fn, benv
