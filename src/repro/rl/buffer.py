"""Replay buffers — fixed-size circular arrays, fully jittable.

Two sampling disciplines:

* **uniform** — ``replay_init`` / ``replay_add_batch`` / ``replay_sample``:
  every written transition is equally likely;
* **prioritized** (PER, Schaul et al. 2015) — ``per_init`` / ``per_add`` /
  ``per_sample`` / ``per_update_priorities``: transitions are drawn with
  probability proportional to ``(|td_error| + eps) ** alpha`` held in a
  fully-JAX sum-tree (O(log n) update/sample via ``lax.fori_loop`` over the
  static tree depth), with importance-sampling weight correction
  (``beta``-annealed by the caller).  ``alpha=0`` is *defined* as uniform:
  the wiring layers (``rl.dqn`` / ``rl.ddpg`` / ``rl.actor_learner``)
  statically dispatch ``priority_exponent=0.0`` onto the uniform code path,
  so it is bitwise-identical to ``replay="uniform"`` — the same
  by-construction contract style as ``num_actors=1, sync_every=1`` vs the
  fused driver.

Two layouts, orthogonal to the discipline:

* single buffer (leading dim = capacity), used by the fused DQN/DDPG loops;
* sharded buffer — the ``*_sharded`` variants stack ``n_shards`` independent
  circular buffers (for PER: independent sum-trees) along a new leading
  axis (leading dims = ``(n_shards, capacity)``), one shard per actor
  replica in the actor–learner topology (``rl.actor_learner``).  The shard
  axis is what the device mesh partitions: each actor writes only its own
  shard, the learner samples per-shard and concatenates, and priority
  pushes stay shard-local (no gather across the actor axis).
  ``replay_stack`` / ``replay_unstack`` (and ``per_stack`` /
  ``per_unstack``) round-trip between the two layouts.

Plus the **double-buffer layout** for the async actor–learner topology
(``DoubleBuffer``): two *independent* sharded buffers — a write slot the
actors fill and a read slot the learner drains — swapped at sync points.
The two slots are deliberately separate pytrees (NOT stacked on a new
axis): the async driver carries the write slot through the actor jit
program and the read slot through the learner jit program, so the two
dispatch chains share no buffers and the runtime is free to overlap them.
``double_buffer_swap`` is a host-level reference exchange — no device op,
no synchronization.
"""
from typing import Any, List, NamedTuple

import jax
import jax.numpy as jnp

REPLAY_MODES = ("uniform", "prioritized")


def validate_replay(replay: str) -> str:
    if replay not in REPLAY_MODES:
        raise ValueError(f"replay must be one of {REPLAY_MODES}, "
                         f"got {replay!r}")
    return replay


def use_prioritized(replay: str, priority_exponent: float) -> bool:
    """Static dispatch: does this (replay, alpha) pair need the sum-tree?

    ``priority_exponent=0.0`` makes every priority ``p**0 == 1`` — exact
    uniform sampling — so it routes onto the uniform code path wholesale,
    which is what makes the ``alpha=0`` parity contract *bitwise* (the PRNG
    consumption patterns of the two samplers differ; equal masses alone
    would only give distributional equality).
    """
    validate_replay(replay)
    if replay != "prioritized":
        return False
    if priority_exponent < 0.0:
        raise ValueError(f"priority_exponent must be >= 0, "
                         f"got {priority_exponent}")
    return priority_exponent != 0.0


class Transition(NamedTuple):
    obs: jnp.ndarray
    action: jnp.ndarray
    reward: jnp.ndarray
    done: jnp.ndarray
    next_obs: jnp.ndarray


class ReplayState(NamedTuple):
    data: Transition          # leading dim = capacity
    index: jnp.ndarray        # next write slot
    size: jnp.ndarray         # valid entries


def replay_init(capacity: int, obs_shape, action_shape=(),
                action_dtype=jnp.int32) -> ReplayState:
    data = Transition(
        obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32),
        action=jnp.zeros((capacity,) + tuple(action_shape), action_dtype),
        reward=jnp.zeros((capacity,), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        next_obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32))
    return ReplayState(data, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))


def replay_add_batch(state: ReplayState, batch: Transition) -> ReplayState:
    """Add a batch (N, ...) of transitions at the circular cursor."""
    capacity = state.data.reward.shape[0]
    n = batch.reward.shape[0]
    idx = (state.index + jnp.arange(n)) % capacity

    data = jax.tree_util.tree_map(
        lambda buf, x: buf.at[idx].set(x), state.data, batch)
    return ReplayState(data, (state.index + n) % capacity,
                       jnp.minimum(state.size + n, capacity))


def replay_sample(state: ReplayState, key: jax.Array, batch_size: int
                  ) -> Transition:
    """Uniform sample of ``batch_size`` transitions.

    Contract: sampling is **with replacement** — a batch may contain
    duplicate indices, and at small fill (``size < batch_size``) it
    certainly will.  Indices are always restricted to the *written* prefix
    ``[0, size)`` of the circular buffer, so a partially-filled buffer
    never yields garbage (all-zero) transitions; the degenerate empty
    buffer (``size == 0``) returns slot 0, whose contents the algorithms'
    ``warmup`` gate discards.
    """
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda buf: buf[idx], state.data)


# ---------------------------------------------------------------------------
# Sharded layout (actor-learner: one shard per actor replica)
# ---------------------------------------------------------------------------

def replay_init_sharded(n_shards: int, capacity: int, obs_shape,
                        action_shape=(), action_dtype=jnp.int32
                        ) -> ReplayState:
    """``n_shards`` independent circular buffers stacked on a leading axis."""
    one = replay_init(capacity, obs_shape, action_shape, action_dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def replay_add_sharded(state: ReplayState, batch: Transition) -> ReplayState:
    """Per-shard batched add: ``batch`` leaves are (n_shards, N, ...)."""
    return jax.vmap(replay_add_batch)(state, batch)


def replay_sample_sharded(state: ReplayState, keys: jax.Array,
                          per_shard: int) -> Transition:
    """Sample ``per_shard`` transitions from every shard.

    ``keys`` is one PRNG key per shard (n_shards, 2); the result leaves are
    (n_shards, per_shard, ...) — reshape to (n_shards * per_shard, ...) for
    a single learner batch.
    """
    return jax.vmap(replay_sample, in_axes=(0, 0, None))(state, keys,
                                                         per_shard)


def replay_total_size(state) -> jnp.ndarray:
    """Total valid entries across shards (scalar for a single buffer).

    Accepts either layout discipline (``ReplayState`` or
    ``PrioritizedReplayState``).
    """
    if isinstance(state, PrioritizedReplayState):
        return jnp.sum(state.replay.size)
    return jnp.sum(state.size)


def replay_stack(states: List[ReplayState]) -> ReplayState:
    """Stack independent buffers into the sharded layout."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def replay_unstack(state: ReplayState) -> List[ReplayState]:
    """Inverse of ``replay_stack`` — split the shard axis back out."""
    n = state.size.shape[0]
    return [jax.tree_util.tree_map(lambda x: x[i], state) for i in range(n)]


# ---------------------------------------------------------------------------
# Double-buffer layout (async actor-learner: write slot / read slot)
# ---------------------------------------------------------------------------

class DoubleBuffer(NamedTuple):
    """Two independent buffer pytrees: actors fill ``write``, the learner
    drains ``read``.

    Both slots keep the circular/sharded semantics of whatever discipline
    they hold (``ReplayState`` or ``PrioritizedReplayState``, single or
    sharded layout).  Invariants the async driver relies on:

    * the slots never share a single array — they are created by two
      separate ``*_init`` calls, so the actor program (which consumes and
      donates ``write``) and the learner program (which consumes and
      donates ``read`` inside the learner state) have disjoint buffer
      sets and therefore no cross-program data dependency within a sync
      period;
    * ``double_buffer_swap`` exchanges the *references* on the host — the
      freshly-written slot becomes the learner's next read slot and the
      drained slot becomes the actors' next write slot.  It dispatches no
      device work and never blocks, so it is safe to call between two
      in-flight jit programs (the swap just rewires which futures feed
      which next dispatch);
    * each slot holds half the total replay capacity, so transitions
      written during one sync period become sampleable in the next —
      one-period data latency is the price of the overlap.
    """
    read: Any
    write: Any


def double_buffer_init(init_fn, n_shards: int, capacity: int, *args,
                       **kwargs) -> DoubleBuffer:
    """Two independent slots of ``capacity`` each via ``init_fn``
    (``replay_init_sharded`` / ``per_init_sharded``)."""
    return DoubleBuffer(read=init_fn(n_shards, capacity, *args, **kwargs),
                        write=init_fn(n_shards, capacity, *args, **kwargs))


def double_buffer_swap(db: DoubleBuffer) -> DoubleBuffer:
    """Host-level reference exchange (see ``DoubleBuffer``); free."""
    return DoubleBuffer(read=db.write, write=db.read)


def double_buffer_total_size(db: DoubleBuffer) -> jnp.ndarray:
    """Valid entries across both slots (and all shards)."""
    return replay_total_size(db.read) + replay_total_size(db.write)


# ---------------------------------------------------------------------------
# Prioritized replay (PER): sum-tree + importance-sampling weights
# ---------------------------------------------------------------------------

_PRIORITY_EPS = 1e-6       # |td| -> priority floor (no zero-mass slots)
_MASS_EPS = 1e-12          # guards 0/0 before the first write


class PrioritizedReplayState(NamedTuple):
    """Circular buffer + a sum-tree over per-slot priorities.

    ``tree`` is a flat binary heap of shape ``(2 * tree_size,)`` with
    ``tree_size = next_pow2(capacity)``: leaf ``i`` lives at
    ``tree_size + i``, internal node ``k`` holds ``tree[2k] + tree[2k+1]``,
    the total priority mass is the root ``tree[1]`` (slot 0 is unused).
    Leaves hold already-exponentiated priorities
    ``(|td| + eps) ** alpha``; unwritten slots hold 0 so they carry no
    sampling mass.  ``max_priority`` is the running max leaf value — fresh
    writes enter at it, the standard PER "replay everything at least once"
    rule.
    """
    replay: ReplayState
    tree: jnp.ndarray
    max_priority: jnp.ndarray


def _tree_size(capacity: int) -> int:
    n = 1
    while n < capacity:
        n *= 2
    return n


def sum_tree_set(tree: jnp.ndarray, leaf_idx: jnp.ndarray,
                 values: jnp.ndarray) -> jnp.ndarray:
    """Set a batch of leaves and repair their ancestor sums.

    O(B log n): one ``fori_loop`` over the static tree depth; at each level
    every touched parent is recomputed from its two (already-correct)
    children, so duplicate indices are safe as long as they carry equal
    values — which PER guarantees (duplicates within a sampled batch are
    the same transition and get the same TD error).
    """
    size = tree.shape[0] // 2
    depth = size.bit_length() - 1          # log2(size); size is static
    node = leaf_idx + size
    tree = tree.at[node].set(values.astype(tree.dtype))

    def repair(_, carry):
        tree, node = carry
        parent = node // 2
        sums = tree[2 * parent] + tree[2 * parent + 1]
        return tree.at[parent].set(sums), parent

    tree, _ = jax.lax.fori_loop(0, depth, repair, (tree, node))
    return tree


def sum_tree_total(tree: jnp.ndarray) -> jnp.ndarray:
    """Total priority mass (the root node)."""
    return tree[1]


def sum_tree_leaves(tree: jnp.ndarray) -> jnp.ndarray:
    """The per-slot priority leaves (length ``tree_size >= capacity``)."""
    return tree[tree.shape[0] // 2:]


def sum_tree_find(tree: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Prefix-sum descent: leaf index whose cumulative span contains ``u``.

    ``u`` is a batch of masses in ``[0, root)``.  Invariant down the
    descent: ``u < mass(current node)``, so the walk can only end in a
    leaf with positive priority — i.e. a written slot.  O(B log n), no
    host sync: a ``fori_loop`` over the static depth with vectorized
    gathers.
    """
    size = tree.shape[0] // 2
    depth = size.bit_length() - 1

    def descend(_, carry):
        node, u = carry
        left = tree[2 * node]
        go_left = u < left
        node = jnp.where(go_left, 2 * node, 2 * node + 1)
        return node, jnp.where(go_left, u, u - left)

    node0 = jnp.ones(u.shape, jnp.int32)
    node, _ = jax.lax.fori_loop(0, depth, descend, (node0, u))
    return node - size


def per_init(capacity: int, obs_shape, action_shape=(),
             action_dtype=jnp.int32) -> PrioritizedReplayState:
    """Empty prioritized buffer (all-zero tree, ``max_priority = 1``)."""
    replay = replay_init(capacity, obs_shape, action_shape, action_dtype)
    tree = jnp.zeros((2 * _tree_size(capacity),), jnp.float32)
    return PrioritizedReplayState(replay, tree, jnp.ones((), jnp.float32))


def per_add(state: PrioritizedReplayState, batch: Transition
            ) -> PrioritizedReplayState:
    """Add a batch (N, ...) at the cursor; new slots enter at max priority."""
    capacity = state.replay.data.reward.shape[0]
    n = batch.reward.shape[0]
    idx = (state.replay.index + jnp.arange(n)) % capacity
    replay = replay_add_batch(state.replay, batch)
    tree = sum_tree_set(state.tree, idx,
                        jnp.broadcast_to(state.max_priority, (n,)))
    return PrioritizedReplayState(replay, tree, state.max_priority)


def per_sample(state: PrioritizedReplayState, key: jax.Array,
               batch_size: int, beta):
    """Priority-proportional sample with IS-weight correction.

    Returns ``(batch, idx, weights)``: ``P(i) = p_i / root`` over written
    slots only (unwritten leaves carry zero mass, and a belt-and-braces
    clip to the written prefix absorbs float-boundary edge cases — sampling
    never returns an unwritten slot); ``weights = (N * P(i)) ** -beta``
    normalized by the batch max, the Schaul et al. correction for the
    non-uniform sampling distribution.  Like the uniform sampler this is
    with-replacement; ``beta`` may be a traced scalar (annealed by the
    caller).
    """
    tree, size = state.tree, state.replay.size
    tsize = tree.shape[0] // 2
    root = jnp.maximum(sum_tree_total(tree), _MASS_EPS)
    u = jax.random.uniform(key, (batch_size,)) * root
    idx = jnp.clip(sum_tree_find(tree, u), 0, jnp.maximum(size, 1) - 1)
    prob = jnp.maximum(tree[tsize + idx] / root, _MASS_EPS)
    n_valid = jnp.maximum(size, 1).astype(jnp.float32)
    weights = (n_valid * prob) ** (-beta)
    weights = weights / jnp.maximum(jnp.max(weights), _MASS_EPS)
    batch = jax.tree_util.tree_map(lambda buf: buf[idx], state.replay.data)
    return batch, idx, weights


def per_update_priorities(state: PrioritizedReplayState, idx: jnp.ndarray,
                          td_abs: jnp.ndarray, priority_exponent: float
                          ) -> PrioritizedReplayState:
    """Push learner TD errors back as priorities ``(|td| + eps) ** alpha``."""
    p = (jnp.abs(td_abs) + _PRIORITY_EPS) ** priority_exponent
    tree = sum_tree_set(state.tree, idx, p)
    max_p = jnp.maximum(state.max_priority, jnp.max(p))
    return PrioritizedReplayState(state.replay, tree, max_p)


# --- sharded PER (one sum-tree per actor shard, stacked on axis 0) ---------

def per_init_sharded(n_shards: int, capacity: int, obs_shape,
                     action_shape=(), action_dtype=jnp.int32
                     ) -> PrioritizedReplayState:
    """``n_shards`` independent prioritized buffers (trees stacked too)."""
    one = per_init(capacity, obs_shape, action_shape, action_dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def per_add_sharded(state: PrioritizedReplayState, batch: Transition
                    ) -> PrioritizedReplayState:
    """Per-shard batched add: ``batch`` leaves are (n_shards, N, ...)."""
    return jax.vmap(per_add)(state, batch)


def per_sample_sharded(state: PrioritizedReplayState, keys: jax.Array,
                       per_shard: int, beta):
    """Sample ``per_shard`` transitions from every shard's own tree.

    IS weights are normalized *per shard* (each shard's batch max), so the
    correction stays shard-local — under ``shard_map`` no cross-actor
    collective is needed.
    """
    return jax.vmap(per_sample, in_axes=(0, 0, None, None))(
        state, keys, per_shard, beta)


def per_update_priorities_sharded(state: PrioritizedReplayState,
                                  idx: jnp.ndarray, td_abs: jnp.ndarray,
                                  priority_exponent: float
                                  ) -> PrioritizedReplayState:
    """Per-shard priority push; ``idx``/``td_abs`` are (n_shards, B)."""
    return jax.vmap(per_update_priorities, in_axes=(0, 0, 0, None))(
        state, idx, td_abs, priority_exponent)


def per_stack(states: List[PrioritizedReplayState]
              ) -> PrioritizedReplayState:
    """Stack independent prioritized buffers into the sharded layout
    (``replay_stack`` is pytree-generic — this is the same operation)."""
    return replay_stack(states)


def per_unstack(state: PrioritizedReplayState
                ) -> List[PrioritizedReplayState]:
    """Inverse of ``per_stack`` — split the shard axis back out."""
    n = state.replay.size.shape[0]
    return [jax.tree_util.tree_map(lambda x: x[i], state) for i in range(n)]


def export_state(state: Any) -> Any:
    """Host-side (numpy) snapshot of any replay pytree — ``ReplayState``,
    ``PrioritizedReplayState``, their sharded layouts, ``DoubleBuffer``.

    Materializes every leaf with ``np.asarray`` (blocks on in-flight
    device work for those values only), so the snapshot is safe to hand
    to a background checkpoint writer while the training loop keeps
    donating the live buffers.  ``repro.checkpoint`` round-trips the
    result; inverse is ``import_state``.  ``np.array`` (a forced copy),
    not ``np.asarray``: a zero-copy view of a CPU-jax leaf would tear
    the moment the runtime reuses the donated buffer.
    """
    import numpy as np
    return jax.tree_util.tree_map(np.array, state)


def import_state(template: Any, exported: Any) -> Any:
    """Re-device an ``export_state`` snapshot into ``template``'s layout.

    Validates structure plus per-leaf shape/dtype against ``template``
    (``ValueError`` with leaf-path detail on mismatch — e.g. a snapshot
    taken at a different ``capacity`` or shard count) and returns a tree
    of fresh device arrays matching the template's types.
    """
    from repro.checkpoint import ckpt as ckpt_lib
    t_def = jax.tree_util.tree_structure(template)
    e_def = jax.tree_util.tree_structure(exported)
    if t_def != e_def:
        raise ValueError(f"replay snapshot structure {e_def} does not "
                         f"match template {t_def}")
    leaves = jax.tree_util.tree_leaves(exported)
    ckpt_lib.validate_leaves([ckpt_lib.leaf_spec(x) for x in leaves],
                             template, source="replay snapshot")
    return ckpt_lib._redevice(leaves, template)
