"""Replay buffer (uniform) — fixed-size circular arrays, fully jittable."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Transition(NamedTuple):
    obs: jnp.ndarray
    action: jnp.ndarray
    reward: jnp.ndarray
    done: jnp.ndarray
    next_obs: jnp.ndarray


class ReplayState(NamedTuple):
    data: Transition          # leading dim = capacity
    index: jnp.ndarray        # next write slot
    size: jnp.ndarray         # valid entries


def replay_init(capacity: int, obs_shape, action_shape=(),
                action_dtype=jnp.int32) -> ReplayState:
    data = Transition(
        obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32),
        action=jnp.zeros((capacity,) + tuple(action_shape), action_dtype),
        reward=jnp.zeros((capacity,), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        next_obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32))
    return ReplayState(data, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))


def replay_add_batch(state: ReplayState, batch: Transition) -> ReplayState:
    """Add a batch (N, ...) of transitions at the circular cursor."""
    capacity = state.data.reward.shape[0]
    n = batch.reward.shape[0]
    idx = (state.index + jnp.arange(n)) % capacity

    data = jax.tree_util.tree_map(
        lambda buf, x: buf.at[idx].set(x), state.data, batch)
    return ReplayState(data, (state.index + n) % capacity,
                       jnp.minimum(state.size + n, capacity))


def replay_sample(state: ReplayState, key: jax.Array, batch_size: int
                  ) -> Transition:
    capacity = state.data.reward.shape[0]
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda buf: buf[idx], state.data)
