"""Replay buffer (uniform) — fixed-size circular arrays, fully jittable.

Two layouts:

* single buffer — ``replay_init`` / ``replay_add_batch`` / ``replay_sample``
  (leading dim = capacity), used by the fused DQN/DDPG loops;
* sharded buffer — the ``*_sharded`` variants stack ``n_shards`` independent
  circular buffers along a new leading axis (leading dims =
  ``(n_shards, capacity)``), one shard per actor replica in the
  actor–learner topology (``rl.actor_learner``).  The shard axis is what the
  device mesh partitions: each actor writes only its own shard, the learner
  samples per-shard and concatenates.  ``replay_stack`` / ``replay_unstack``
  round-trip between the two layouts.
"""
from typing import List, NamedTuple

import jax
import jax.numpy as jnp


class Transition(NamedTuple):
    obs: jnp.ndarray
    action: jnp.ndarray
    reward: jnp.ndarray
    done: jnp.ndarray
    next_obs: jnp.ndarray


class ReplayState(NamedTuple):
    data: Transition          # leading dim = capacity
    index: jnp.ndarray        # next write slot
    size: jnp.ndarray         # valid entries


def replay_init(capacity: int, obs_shape, action_shape=(),
                action_dtype=jnp.int32) -> ReplayState:
    data = Transition(
        obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32),
        action=jnp.zeros((capacity,) + tuple(action_shape), action_dtype),
        reward=jnp.zeros((capacity,), jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        next_obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.float32))
    return ReplayState(data, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))


def replay_add_batch(state: ReplayState, batch: Transition) -> ReplayState:
    """Add a batch (N, ...) of transitions at the circular cursor."""
    capacity = state.data.reward.shape[0]
    n = batch.reward.shape[0]
    idx = (state.index + jnp.arange(n)) % capacity

    data = jax.tree_util.tree_map(
        lambda buf, x: buf.at[idx].set(x), state.data, batch)
    return ReplayState(data, (state.index + n) % capacity,
                       jnp.minimum(state.size + n, capacity))


def replay_sample(state: ReplayState, key: jax.Array, batch_size: int
                  ) -> Transition:
    maxval = jnp.maximum(state.size, 1)
    idx = jax.random.randint(key, (batch_size,), 0, maxval)
    return jax.tree_util.tree_map(lambda buf: buf[idx], state.data)


# ---------------------------------------------------------------------------
# Sharded layout (actor-learner: one shard per actor replica)
# ---------------------------------------------------------------------------

def replay_init_sharded(n_shards: int, capacity: int, obs_shape,
                        action_shape=(), action_dtype=jnp.int32
                        ) -> ReplayState:
    """``n_shards`` independent circular buffers stacked on a leading axis."""
    one = replay_init(capacity, obs_shape, action_shape, action_dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def replay_add_sharded(state: ReplayState, batch: Transition) -> ReplayState:
    """Per-shard batched add: ``batch`` leaves are (n_shards, N, ...)."""
    return jax.vmap(replay_add_batch)(state, batch)


def replay_sample_sharded(state: ReplayState, keys: jax.Array,
                          per_shard: int) -> Transition:
    """Sample ``per_shard`` transitions from every shard.

    ``keys`` is one PRNG key per shard (n_shards, 2); the result leaves are
    (n_shards, per_shard, ...) — reshape to (n_shards * per_shard, ...) for
    a single learner batch.
    """
    return jax.vmap(replay_sample, in_axes=(0, 0, None))(state, keys,
                                                         per_shard)


def replay_total_size(state: ReplayState) -> jnp.ndarray:
    """Total valid entries across shards (scalar for a single buffer)."""
    return jnp.sum(state.size)


def replay_stack(states: List[ReplayState]) -> ReplayState:
    """Stack independent buffers into the sharded layout."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def replay_unstack(state: ReplayState) -> List[ReplayState]:
    """Inverse of ``replay_stack`` — split the shard axis back out."""
    n = state.size.shape[0]
    return [jax.tree_util.tree_map(lambda x: x[i], state) for i in range(n)]
