"""ActorQ: true int8 actor inference for the RL hot path.

The paper's headline systems result is that 8-bit *actors* collect data
1.5-5.41x faster without hurting convergence.  Everywhere else in this repo
quantization is *simulated* (fake-quant in fp32); this module is the real
thing: policy parameters are packed once per learner update into an int8
cache (``pack_actor_params``), and every dense layer of the actor forward
pass runs through the W8A8 integer GEMM in ``repro.kernels`` —
``lax.dot_general`` over int8 codes with int32 accumulation and a fused
affine-dequant epilogue (Pallas on TPU, the pure-jnp oracle on CPU).

Quantization scheme (matches ``core.ptq`` exactly, so the int8 path and the
fake-quant simulation share one quantizer):

* dense weights   — per-tensor affine int8 codes (``core.affine``),
* conv weights    — per-output-channel int8 codes, computed in int8 via an
  im2col lowering: patches through the same W8A8 GEMM with the per-channel
  scales in the kernel's per-column dequant epilogue,
* activations     — dynamic per-tensor quantization at each dense/conv
  input (computed on the fly from the live batch range; no calibration).

Packing cadence: call ``pack_actor_params`` once per learner update — e.g.
at the top of a jitted training iteration — NOT per environment step; the
rollout scan then closes over the int8 cache.  ``rl.a2c`` / ``rl.dqn``
(``actor_backend="int8"``) and ``rl.distributed`` do exactly this.

Kernel backend selection (threaded through ``backend=`` everywhere):

    "pallas"     pallas_call, compiled       (TPU hot path)
    "interpret"  pallas_call, interpret mode (CPU kernel validation)
    "ref"        pure-jnp oracle             (CPU correctness / pjit)
    "auto"       pallas on TPU, ref elsewhere (default)

Entry points:

* ``pack_actor_params(params, bits)``        -> int8 ``QuantizedParams``
* ``quantized_apply(qparams, obs)``          -> head outputs (logits/q/mu)
* ``make_act_fn(env_spec)``                  -> deterministic deployment
  policy ``act(qparams, obs)`` (argmax for discrete, tanh*scale for DDPG)
* ``make_sampling_policy(env_spec, n_act)``  -> stochastic rollout policy
  ``policy(qparams, obs, key)`` for the training-time data-collection path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import affine, ptq
from repro.core.ptq import PackedTensor
from repro.core.qconfig import QuantConfig
from repro.kernels import ops

# A QuantizedParams pytree mirrors the network spec: every weight leaf is a
# ``core.ptq.PackedTensor`` (int8 codes + affine scale/zero), biases stay f32.
QuantizedParams = Any

ACTOR_BACKENDS = ("fp32", "int8")


def validate_actor_backend(actor_backend: str) -> str:
    if actor_backend not in ACTOR_BACKENDS:
        raise ValueError(f"actor_backend must be one of {ACTOR_BACKENDS}, "
                         f"got {actor_backend!r}")
    return actor_backend


def pack_actor_params(params: Any, bits: int = 8) -> QuantizedParams:
    """Pack an actor param pytree into the int8 deployment cache.

    Same quantizer as the fake-quant simulation (``ptq.ptq_simulate``):
    per-tensor for dense kernels, per-output-channel for conv kernels.
    Weight bits may be < 8 (codes still store as int8 for the kernel);
    activations always quantize to 8 bits at run time (W{n}A8).
    Jit-safe — call inside a training iteration to refresh the cache once
    per learner update.
    """
    assert bits <= 8, f"int8 actor cache needs bits <= 8, got {bits}"
    return ptq.ptq_pack(params, QuantConfig.ptq_int(bits))


def packed_nbytes(qparams: QuantizedParams) -> int:
    """Parameter-memory footprint of the packed actor (paper's ~4x claim)."""
    return ptq.tree_nbytes(qparams)


# ---------------------------------------------------------------------------
# int8 layers
# ---------------------------------------------------------------------------

def int8_dense(layer: Dict[str, Any], x: jnp.ndarray, *,
               backend: str = "auto", act: Callable = None) -> jnp.ndarray:
    """One dense layer through the W8A8 integer GEMM.

    ``layer`` is ``{"w": PackedTensor, "b": f32}``; ``x`` is f32 with
    arbitrary leading batch dims.  The activation is dynamically quantized
    per-tensor — always to 8 bits, whatever the weight bit-width (W{n}A8:
    the fake-quant protocol this path mirrors quantizes weights only, so
    activation error must not scale with the weight sweep) — the product
    accumulates in int32, and the affine dequant is fused in the kernel
    epilogue.
    """
    w: PackedTensor = layer["w"]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xp = affine.quantize_to_int(x2, 8)
    n = w.codes.shape[-1]
    # per-tensor dense scales broadcast to the kernel's per-column layout
    w_scale = jnp.broadcast_to(
        jnp.asarray(w.delta, jnp.float32).reshape(-1), (n,))
    w_zero = jnp.broadcast_to(
        jnp.asarray(w.zero_point, jnp.float32).reshape(-1), (n,))
    y = ops.int8_matmul(xq, w.codes, xp.delta, xp.zero_point, w_scale,
                        w_zero, backend=backend)
    y = y + layer["b"]
    if act is not None:
        y = act(y)
    return y.reshape(lead + (n,))


def int8_conv2d(layer: Dict[str, Any], x: jnp.ndarray, stride: int = 1,
                act: Callable = jax.nn.relu, *, backend: str = "auto"
                ) -> jnp.ndarray:
    """Conv through the W8A8 integer GEMM via an im2col patch extraction.

    The conv weights are per-output-channel int8 codes; the input is lowered
    to patches (``lax.conv_general_dilated_patches``, channel-major
    ``(C_in, kh, kw)`` feature order) and the contraction runs through
    ``kernels.ops.int8_matmul`` with the per-channel scales mapped onto the
    kernel's per-column affine epilogue — true int8 compute, closing the
    ROADMAP follow-up (previously the codes were dequantized in front of
    ``lax.conv``).  Activations are dynamically quantized per-tensor over
    the patch matrix, same policy as ``int8_dense``.
    """
    w = layer["w"]
    if not isinstance(w, PackedTensor):
        # unpacked fp32 conv (e.g. a partially-packed tree): plain compute
        y = jax.lax.conv_general_dilated(
            x, w.astype(x.dtype), window_strides=(stride, stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + layer["b"].astype(x.dtype)
        return act(y) if act is not None else y
    kh, kw, c_in, c_out = w.codes.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    lead = patches.shape[:-1]
    p2 = patches.reshape(-1, patches.shape[-1])
    pq, pp = affine.quantize_to_int(p2, 8)
    # patches order features as (C_in, kh, kw); permute HWIO codes to match
    w2 = jnp.transpose(w.codes, (2, 0, 1, 3)).reshape(-1, c_out)
    w_scale = jnp.broadcast_to(
        jnp.asarray(w.delta, jnp.float32).reshape(-1), (c_out,))
    w_zero = jnp.broadcast_to(
        jnp.asarray(w.zero_point, jnp.float32).reshape(-1), (c_out,))
    y = ops.int8_matmul(pq, w2, pp.delta, pp.zero_point, w_scale, w_zero,
                        backend=backend)
    y = y.reshape(lead + (c_out,)) + layer["b"].astype(y.dtype)
    if act is not None:
        y = act(y)
    return y


# ---------------------------------------------------------------------------
# Quantized network applies (mirror rl.networks.mlp_apply / cnn_apply)
# ---------------------------------------------------------------------------

def quantized_mlp_apply(qparams: QuantizedParams, x: jnp.ndarray,
                        n_hidden: int, *, backend: str = "auto"
                        ) -> jnp.ndarray:
    for i in range(n_hidden):
        x = int8_dense(qparams[f"fc{i}"], x, backend=backend,
                       act=jax.nn.relu)
    return int8_dense(qparams["out"], x, backend=backend)


def quantized_cnn_apply(qparams: QuantizedParams, x: jnp.ndarray,
                        n_convs: int, *, backend: str = "auto"
                        ) -> jnp.ndarray:
    batch_shape = x.shape[:-3]
    x = x.reshape((-1,) + x.shape[-3:])
    for i in range(n_convs):
        x = int8_conv2d(qparams[f"conv{i}"], x, backend=backend)
    x = x.reshape(x.shape[0], -1)
    x = int8_dense(qparams["fc"], x, backend=backend, act=jax.nn.relu)
    y = int8_dense(qparams["out"], x, backend=backend)
    return y.reshape(batch_shape + y.shape[-1:])


def quantized_apply(qparams: QuantizedParams, x: jnp.ndarray, *,
                    backend: str = "auto") -> jnp.ndarray:
    """Head outputs of the packed actor (dispatches on the packed spec).

    The packed pytree carries the network structure (``rl.networks`` layer
    naming): ``conv*`` keys select the CNN backbone, otherwise the MLP.
    """
    names = set(qparams)
    n_convs = sum(1 for n in names if n.startswith("conv"))
    if n_convs:
        return quantized_cnn_apply(qparams, x, n_convs, backend=backend)
    n_hidden = sum(1 for n in names if n.startswith("fc"))
    return quantized_mlp_apply(qparams, x, n_hidden, backend=backend)


# ---------------------------------------------------------------------------
# Policy heads
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_act_fn(env_spec, *, backend: str = "auto"):
    """Deterministic deployment policy over packed params.

    Signature matches ``rl.env.evaluate``'s ``act_fn(params, obs)`` with the
    packed pytree in the params slot: discrete envs argmax over the first
    ``n_actions`` head outputs (A2C/PPO value heads are sliced off, DQN maps
    through unchanged); continuous envs apply the DDPG tanh*scale head.

    Cached per ``(env_spec, backend)`` (``EnvSpec`` is frozen/hashable) so
    repeated deployments of one env share an act-fn identity — which is
    what lets ``rl.env.evaluate`` reuse its compiled eval program.
    """
    if env_spec.continuous:
        def act(qparams, obs):
            mu = quantized_apply(qparams, obs, backend=backend)
            return jnp.tanh(mu) * env_spec.action_scale
    else:
        n_act = env_spec.n_actions

        def act(qparams, obs):
            out = quantized_apply(qparams, obs, backend=backend)
            return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)
    return act


@functools.lru_cache(maxsize=None)
def make_sampling_policy(env_spec, *, backend: str = "auto"):
    """Stochastic rollout policy (training-time data collection).

    Returns ``policy(qparams, obs, key) -> (action, logits)`` sampling from
    the int8 actor's categorical head — the ActorQ data-collection path.
    """
    n_act = env_spec.n_actions

    def policy(qparams, obs, key):
        out = quantized_apply(qparams, obs, backend=backend)
        logits = out[..., :n_act]
        return jax.random.categorical(key, logits).astype(jnp.int32), logits
    return policy
