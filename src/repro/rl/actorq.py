"""ActorQ: true int8 actor inference for the RL hot path.

The paper's headline systems result is that 8-bit *actors* collect data
1.5-5.41x faster without hurting convergence.  Everywhere else in this repo
quantization is *simulated* (fake-quant in fp32); this module is the real
thing: policy parameters are packed once per learner update into an int8
cache (``pack_actor_params``), and every dense layer of the actor forward
pass runs through the W8A8 integer GEMM in ``repro.kernels`` —
``lax.dot_general`` over int8 codes with int32 accumulation and a fused
affine-dequant epilogue (Pallas on TPU, the native-XLA integer backend in
``kernels.xla_backend`` everywhere else).

Quantization scheme (matches ``core.ptq`` exactly, so the int8 path and the
fake-quant simulation share one quantizer):

* dense weights   — per-tensor affine int8 codes (``core.affine``),
* conv weights    — per-output-channel int8 codes, computed in int8 via an
  im2col lowering: patches through the same W8A8 GEMM with the per-channel
  scales in the kernel's per-column dequant epilogue,
* activations     — dynamic per-tensor quantization at each dense/conv
  input (computed on the fly from the live batch range; no calibration).

Packing cadence: call ``pack_actor_params`` once per learner update — e.g.
at the top of a jitted training iteration — NOT per environment step; the
rollout scan then closes over the int8 cache.  ``rl.a2c`` / ``rl.dqn``
(``actor_backend="int8"``) and ``rl.distributed`` do exactly this.

Kernel backend selection (threaded through ``backend=`` everywhere):

    "pallas"     pallas_call, compiled       (TPU hot path)
    "interpret"  pallas_call, interpret mode (CPU kernel validation)
    "xla"        lax integer/centered GEMMs  (CPU/GPU hot path)
    "ref"        pure-jnp oracle             (CPU correctness / pjit)
    "auto"       pallas on TPU, xla elsewhere (default; see also the
                 ``REPRO_KERNEL_BACKEND`` env override in ``kernels.ops``)

Entry points:

* ``pack_actor_params(params, bits)``        -> int ``QuantizedParams``
  (``bits <= 4``: W4A8 — codes byte-packed two-per-byte, half the cache)
* ``calibrate_actor_cache(qparams, obs)``    -> cache + static activation
  scales; MLP applies then run the single-pass fused kernel
  (``kernels.fused_qmlp``) instead of one GEMM + dynamic range pass per
  layer
* ``make_actor_cache(params, backend, calib_obs=...)`` -> the one-stop
  pack(+calibrate) used at every cache-refresh site
* ``quantized_apply(qparams, obs)``          -> head outputs (logits/q/mu)
* ``make_act_fn(env_spec)``                  -> deterministic deployment
  policy ``act(qparams, obs)`` (argmax for discrete, tanh*scale for DDPG)
* ``make_sampling_policy(env_spec, n_act)``  -> stochastic rollout policy
  ``policy(qparams, obs, key)`` for the training-time data-collection path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import affine, ptq
from repro.core.ptq import PackedTensor
from repro.core.qconfig import QuantConfig
from repro.kernels import ops

# A QuantizedParams pytree mirrors the network spec: every weight leaf is a
# ``core.ptq.PackedTensor`` (int8 codes + affine scale/zero), biases stay f32.
# ``calibrate_actor_cache`` adds an ``ACT_QUANT`` entry of static activation
# scales next to the weights, which flips MLP applies onto the fused
# single-pass kernel.
QuantizedParams = Any

# The one place actor-backend strings are defined/validated — the configs,
# ``loops.train``, ``eval_policy``, ``launch.serve`` and the actor-learner
# topologies all route through ``validate_actor_backend``.
ACTOR_BACKENDS = ("fp32", "int8", "int4")
QUANTIZED_BACKENDS = ("int8", "int4")
_BACKEND_BITS = {"int8": 8, "int4": 4}

# key of the static activation-scale entry a calibrated cache carries
# (sorted next to the fc*/out weight entries in the packed pytree)
ACT_QUANT = "act_quant"


def validate_actor_backend(actor_backend: str) -> str:
    """Validate an actor-backend name against ``ACTOR_BACKENDS``.

    Returns the name unchanged (so it chains: ``bits =
    _BACKEND_BITS[validate_actor_backend(b)]``); raises ``ValueError``
    for anything outside ``("fp32", "int8", "int4")``.  Every config
    surface (``loops.train``, topologies, ``serving.PolicyServer``)
    funnels through here so the error reads the same everywhere.
    """
    if actor_backend not in ACTOR_BACKENDS:
        raise ValueError(f"actor_backend must be one of {ACTOR_BACKENDS}, "
                         f"got {actor_backend!r}")
    return actor_backend


def is_quantized(actor_backend: str) -> bool:
    """True for the integer-inference backends (int8/int4)."""
    return validate_actor_backend(actor_backend) in QUANTIZED_BACKENDS


def backend_bits(actor_backend: str) -> int:
    """Weight bit-width of a quantized actor backend (int8 -> 8, int4 -> 4)."""
    validate_actor_backend(actor_backend)
    if actor_backend not in _BACKEND_BITS:
        raise ValueError(f"actor_backend {actor_backend!r} is not a "
                         f"quantized backend {QUANTIZED_BACKENDS}")
    return _BACKEND_BITS[actor_backend]


def pack_actor_params(params: Any, bits: int = 8) -> QuantizedParams:
    """Pack an actor param pytree into the int-code deployment cache.

    Same quantizer as the fake-quant simulation (``ptq.ptq_simulate``):
    per-tensor for dense kernels, per-output-channel for conv kernels.
    Weight bits may be < 8 — ``bits <= 4`` stores two codes per int8 byte
    along the GEMM contraction axis (``actor_backend="int4"`` -> W4A8,
    half the int8 cache/sync footprint); activations always quantize to
    8 bits at run time (W{n}A8).  Jit-safe — call inside a training
    iteration to refresh the cache once per learner update.
    """
    # ValueError, not assert: the guard must survive ``python -O``
    if not 1 <= bits <= 8:
        raise ValueError(f"int actor cache needs 1 <= bits <= 8, "
                         f"got {bits}")
    return ptq.ptq_pack(params, QuantConfig.ptq_int(bits))


def packed_nbytes(qparams: QuantizedParams) -> int:
    """Parameter-memory footprint of the packed actor (paper's ~4x claim)."""
    return ptq.tree_nbytes(qparams)


def calib_slice(obs: jnp.ndarray, calib_batch: int) -> jnp.ndarray:
    """Leading-axis slice of a rollout observation batch for calibration."""
    return obs[:max(1, min(calib_batch, obs.shape[0]))]


def make_actor_cache(params: Any, actor_backend: str, *,
                     calib_obs: Any = None,
                     backend: str = "auto") -> QuantizedParams:
    """Pack (and, with ``calib_obs``, calibrate) one actor cache.

    The one-stop repack used at every cache refresh site — the fused
    drivers' per-update pack, the actor-learner ``lax.cond`` sync repack
    and the async snapshot program: codes at the backend's bit-width
    (int8 -> W8A8, int4 -> byte-packed W4A8), plus static activation
    scales (-> the single-pass fused MLP kernel) when a calibration
    observation batch is supplied.
    """
    qparams = pack_actor_params(params, backend_bits(actor_backend))
    if calib_obs is not None:
        qparams = calibrate_actor_cache(qparams, calib_obs, backend=backend)
    return qparams


# ---------------------------------------------------------------------------
# int8 layers
# ---------------------------------------------------------------------------

def _col_arrays(w: PackedTensor, n: int):
    """Kernel-layout per-column (N,) scale/zero of a packed weight.

    Packed at pack time (``ptq._pack_leaf``) and read straight off the
    cache; the broadcast fallback only serves hand-built ``PackedTensor``s
    from before the hoist.
    """
    if w.col_scale is not None:
        return w.col_scale, w.col_zero
    return (jnp.broadcast_to(
                jnp.asarray(w.delta, jnp.float32).reshape(-1), (n,)),
            jnp.broadcast_to(
                jnp.asarray(w.zero_point, jnp.float32).reshape(-1), (n,)))


def int8_dense(layer: Dict[str, Any], x: jnp.ndarray, *,
               backend: str = "auto", act: Callable = None) -> jnp.ndarray:
    """One dense layer through the W8A8 integer GEMM.

    ``layer`` is ``{"w": PackedTensor, "b": f32}``; ``x`` is f32 with
    arbitrary leading batch dims.  The activation is dynamically quantized
    per-tensor — always to 8 bits, whatever the weight bit-width (W{n}A8:
    the fake-quant protocol this path mirrors quantizes weights only, so
    activation error must not scale with the weight sweep) — the product
    accumulates in int32, and the affine dequant is fused in the kernel
    epilogue.  Sub-8-bit caches (``pack_actor_params(bits=4)``) hold
    byte-packed codes; the GEMM unpacks them in-kernel.
    """
    w: PackedTensor = layer["w"]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xp = affine.quantize_to_int(x2, 8)
    n = (w.orig_shape[-1] if w.orig_shape is not None
         else w.codes.shape[-1])
    w_scale, w_zero = _col_arrays(w, n)
    y = ops.int8_matmul(xq, w.codes, xp.delta, xp.zero_point, w_scale,
                        w_zero, backend=backend,
                        w_bits=w.bits if w.bits <= 4 else 8)
    y = y + layer["b"]
    if act is not None:
        y = act(y)
    return y.reshape(lead + (n,))


def int8_conv2d(layer: Dict[str, Any], x: jnp.ndarray, stride: int = 1,
                act: Callable = jax.nn.relu, *, backend: str = "auto"
                ) -> jnp.ndarray:
    """Conv through the W8A8 integer GEMM via an im2col patch extraction.

    The conv weights are per-output-channel int8 codes; the input is lowered
    to patches (``lax.conv_general_dilated_patches``, channel-major
    ``(C_in, kh, kw)`` feature order) and the contraction runs through
    ``kernels.ops.int8_matmul`` with the per-channel scales mapped onto the
    kernel's per-column affine epilogue — true int8 compute, closing the
    ROADMAP follow-up (previously the codes were dequantized in front of
    ``lax.conv``).  Activations are dynamically quantized per-tensor over
    the patch matrix, same policy as ``int8_dense``.
    """
    w = layer["w"]
    if not isinstance(w, PackedTensor):
        # unpacked fp32 conv (e.g. a partially-packed tree): plain compute
        y = jax.lax.conv_general_dilated(
            x, w.astype(x.dtype), window_strides=(stride, stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + layer["b"].astype(x.dtype)
        return act(y) if act is not None else y
    kh, kw, c_in, c_out = (w.orig_shape if w.orig_shape is not None
                           else w.codes.shape)
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    lead = patches.shape[:-1]
    p2 = patches.reshape(-1, patches.shape[-1])
    pq, pp = affine.quantize_to_int(p2, 8)
    if w.orig_shape is not None:
        # sub-8-bit conv codes are pre-transposed to the im2col layout and
        # byte-packed at pack time; the GEMM unpacks in-kernel
        w2 = w.codes
    else:
        # patches order features as (C_in, kh, kw); permute HWIO codes
        w2 = jnp.transpose(w.codes, (2, 0, 1, 3)).reshape(-1, c_out)
    w_scale, w_zero = _col_arrays(w, c_out)
    y = ops.int8_matmul(pq, w2, pp.delta, pp.zero_point, w_scale, w_zero,
                        backend=backend,
                        w_bits=w.bits if w.bits <= 4 else 8)
    y = y.reshape(lead + (c_out,)) + layer["b"].astype(y.dtype)
    if act is not None:
        y = act(y)
    return y


# ---------------------------------------------------------------------------
# Quantized network applies (mirror rl.networks.mlp_apply / cnn_apply)
# ---------------------------------------------------------------------------

def _mlp_layer_names(n_hidden: int):
    return [f"fc{i}" for i in range(n_hidden)] + ["out"]


def _fused_layers(qparams: QuantizedParams, n_hidden: int):
    """``(QMLPLayer, ...)`` for the single-pass kernel from a calibrated
    cache (weights + the ``ACT_QUANT`` static activation params)."""
    from repro.kernels.fused_qmlp import QMLPLayer
    act = qparams[ACT_QUANT]
    layers = []
    for i, name in enumerate(_mlp_layer_names(n_hidden)):
        w: PackedTensor = qparams[name]["w"]
        k = (w.orig_shape[0] if w.orig_shape is not None
             else w.codes.shape[0])
        n = (w.orig_shape[-1] if w.orig_shape is not None
             else w.codes.shape[-1])
        w_scale, w_zero = _col_arrays(w, n)
        x_delta, x_zero = act[i]
        layers.append(QMLPLayer(
            codes=w.codes, col_scale=w_scale, col_zero=w_zero,
            bias=qparams[name]["b"], x_delta=x_delta, x_zero=x_zero,
            bits=w.bits, k=k))
    return tuple(layers)


def quantized_mlp_apply(qparams: QuantizedParams, x: jnp.ndarray,
                        n_hidden: int, *, backend: str = "auto"
                        ) -> jnp.ndarray:
    """MLP head outputs from a packed cache.

    Fused-vs-per-layer selection: a *calibrated* cache (one carrying the
    ``ACT_QUANT`` static activation scales — see ``calibrate_actor_cache``)
    runs the whole forward in one pass (``kernels.ops.fused_qmlp``: one
    kernel dispatch, inter-layer activations int8-resident, no dynamic
    range passes); an uncalibrated cache falls back to the per-layer GEMM
    with dynamic per-tensor activation quantization.
    """
    if ACT_QUANT in qparams:
        return ops.fused_qmlp(x, _fused_layers(qparams, n_hidden),
                              backend=backend)
    for i in range(n_hidden):
        x = int8_dense(qparams[f"fc{i}"], x, backend=backend,
                       act=jax.nn.relu)
    return int8_dense(qparams["out"], x, backend=backend)


def quantized_cnn_apply(qparams: QuantizedParams, x: jnp.ndarray,
                        n_convs: int, *, backend: str = "auto"
                        ) -> jnp.ndarray:
    """CNN head outputs from a packed cache (per-layer int8 path).

    ``x`` is f32 ``(*batch, H, W, C)`` — any leading batch dims are
    flattened for the im2col int8 convs and restored on the ``(*batch,
    head_dim)`` f32 result.  Conv caches never calibrate, so this is
    always the per-layer dynamic-quantization path.
    """
    batch_shape = x.shape[:-3]
    x = x.reshape((-1,) + x.shape[-3:])
    for i in range(n_convs):
        x = int8_conv2d(qparams[f"conv{i}"], x, backend=backend)
    x = x.reshape(x.shape[0], -1)
    x = int8_dense(qparams["fc"], x, backend=backend, act=jax.nn.relu)
    y = int8_dense(qparams["out"], x, backend=backend)
    return y.reshape(batch_shape + y.shape[-1:])


def quantized_apply(qparams: QuantizedParams, x: jnp.ndarray, *,
                    backend: str = "auto") -> jnp.ndarray:
    """Head outputs of the packed actor (dispatches on the packed spec).

    The packed pytree carries the network structure (``rl.networks`` layer
    naming): ``conv*`` keys select the CNN backbone, an ``embed`` key the
    decoder-transformer sequence policy (windowed form —
    ``quantized_seq_apply``), otherwise the MLP (single-pass fused when
    the cache is calibrated — see ``quantized_mlp_apply``).
    """
    names = set(qparams)
    if "embed" in names:
        return quantized_seq_apply(qparams, x, backend=backend)
    n_convs = sum(1 for n in names if n.startswith("conv"))
    if n_convs:
        return quantized_cnn_apply(qparams, x, n_convs, backend=backend)
    n_hidden = sum(1 for n in names if n.startswith("fc"))
    return quantized_mlp_apply(qparams, x, n_hidden, backend=backend)


# ---------------------------------------------------------------------------
# Quantized sequence policy (mirror models.seq_policy.seq_apply)
# ---------------------------------------------------------------------------

def _n_blocks(qparams: QuantizedParams) -> int:
    return sum(1 for n in qparams if n.startswith("blk"))


def quantized_seq_apply(qparams: QuantizedParams, obs: jnp.ndarray, *,
                        backend: str = "auto") -> jnp.ndarray:
    """Windowed int8 forward of the packed decoder transformer.

    The stateless mirror of ``models.seq_policy.seq_apply``: every dense
    projection runs through the W{n}A8 GEMM (dynamic per-tensor activation
    quantization), while rms-norms, softmax-attention and residual adds
    stay fp32 on the activations.  ``obs`` is ``(..., context, feat)``
    frame-stacked rows with the trailing valid flag; output is the head on
    the newest row.  Used by eval / divergence / fp-comparison paths; the
    rollout hot path steps incrementally via ``quantized_seq_step``.
    """
    from repro.models import common as mcommon
    from repro.models.seq_policy import NEG_INF, valid_mask

    s = obs.shape[-2]
    x = int8_dense(qparams["embed"], obs, backend=backend)
    valid = valid_mask(obs)
    mask = jnp.tril(jnp.ones((s, s), bool)) & valid[..., None, :]
    scale = x.shape[-1] ** -0.5
    for i in range(_n_blocks(qparams)):
        blk = qparams[f"blk{i}"]
        h = mcommon.rms_norm(blk["ln1"], x)
        q = int8_dense(blk["q"], h, backend=backend)
        k = int8_dense(blk["k"], h, backend=backend)
        v = int8_dense(blk["v"], h, backend=backend)
        logits = jnp.einsum("...sd,...td->...st", q, k) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        a = jnp.einsum("...st,...td->...sd", p, v)
        x = x + int8_dense(blk["o"], a, backend=backend)
        h2 = mcommon.rms_norm(blk["ln2"], x)
        y = int8_dense(blk["fc"], h2, backend=backend, act=jax.nn.relu)
        x = x + int8_dense(blk["proj"], y, backend=backend)
    return int8_dense(qparams["head"], x[..., -1, :], backend=backend)


def seq_cache_zeros(seq_cfg, n_envs: int, size: int) -> Dict[str, Any]:
    """All-zero per-env KV-cache actor state for the sequence policy.

    One plain-layout (slot == step index) int8 cache per block: codes
    ``(n_envs, size, d_model)`` with per-token scales, plus the per-env
    write counter.  ``size`` must exceed the longest episode (the drivers
    use ``env.spec.max_steps + 1``); the all-zero tree is also the
    per-env reset value ``auto_reset_step`` restores on episode end (see
    ``rl.env.attach_policy_state``).
    """
    def layer():
        return {
            "k_codes": jnp.zeros((n_envs, size, seq_cfg.d_model), jnp.int8),
            "k_scale": jnp.zeros((n_envs, size, 1), jnp.float32),
            "v_codes": jnp.zeros((n_envs, size, seq_cfg.d_model), jnp.int8),
            "v_scale": jnp.zeros((n_envs, size, 1), jnp.float32),
        }
    return {"count": jnp.zeros((n_envs,), jnp.int32),
            "layers": tuple(layer() for _ in range(seq_cfg.n_layers))}


def seq_cache_nbytes(pstate: Dict[str, Any]) -> int:
    """Total bytes of a KV-cache actor state (codes + scales + counter)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(pstate))


def quantized_seq_step(qparams: QuantizedParams, feat: jnp.ndarray,
                       pstate: Dict[str, Any], *, context: int,
                       backend: str = "auto"):
    """One decode step of the packed transformer on the int8 KV cache.

    ``feat`` is the newest frame row ``(B, feat)``; ``pstate`` the
    per-env cache from ``seq_cache_zeros``.  Each block quantizes the new
    token's K/V with the shared ``core.affine.quantize_symmetric``,
    writes slot ``count``, and attends over the last ``context`` slots
    through ``kernels.ops.int8_cache_attention`` — so the token set (and
    the fp32 attention math over dequantized codes) matches the windowed
    ``quantized_seq_apply`` on the corresponding frame stack; the two
    differ only by activation-quantization batching (documented tolerance
    — docs/contracts.md "Attention parity").  Returns ``(head_out,
    new_pstate)`` with ``count`` advanced.
    """
    from repro.models import common as mcommon

    count = pstate["count"]
    x = int8_dense(qparams["embed"], feat, backend=backend)      # (B, D)

    def write(buf, val, c):
        return jax.vmap(
            lambda b, v, i: jax.lax.dynamic_update_slice(b, v[None],
                                                         (i, 0))
        )(buf, val, c)

    new_layers = []
    for i in range(_n_blocks(qparams)):
        blk = qparams[f"blk{i}"]
        cache = pstate["layers"][i]
        h = mcommon.rms_norm(blk["ln1"], x)
        q = int8_dense(blk["q"], h, backend=backend)
        k = int8_dense(blk["k"], h, backend=backend)
        v = int8_dense(blk["v"], h, backend=backend)
        kc, ks = affine.quantize_symmetric(k)
        vc, vs = affine.quantize_symmetric(v)
        cache = {"k_codes": write(cache["k_codes"], kc, count),
                 "k_scale": write(cache["k_scale"], ks, count),
                 "v_codes": write(cache["v_codes"], vc, count),
                 "v_scale": write(cache["v_scale"], vs, count)}
        out = ops.int8_cache_attention(
            q[:, None, :], cache["k_codes"], cache["k_scale"],
            cache["v_codes"], cache["v_scale"], count, window=context,
            backend=backend)
        x = x + int8_dense(blk["o"], out[:, 0, :], backend=backend)
        h2 = mcommon.rms_norm(blk["ln2"], x)
        y = int8_dense(blk["fc"], h2, backend=backend, act=jax.nn.relu)
        x = x + int8_dense(blk["proj"], y, backend=backend)
        new_layers.append(cache)
    head = int8_dense(qparams["head"], x, backend=backend)
    return head, {"count": count + 1, "layers": tuple(new_layers)}


def maybe_attach_seq_state(benv, net, actor_backend: str, n_envs: int):
    """Wrap a batched env with KV-cache actor state when it applies.

    No-op unless ``net`` carries a ``seq_cfg`` AND the actor backend is
    quantized — exactly the condition under which the rollout policy is
    the stateful cached stepper (``quantized_seq_step``); fp32 sequence
    actors stay stateless-windowed.  The wrapped state rides through
    rollout scans, shard_map partitioning (batch-leading leaves) and the
    checkpoint/resume contract as ordinary env state.
    """
    seq_cfg = getattr(net, "seq_cfg", None)
    if seq_cfg is None or not is_quantized(actor_backend):
        return benv
    from repro.rl.env import attach_policy_state
    pstate0 = seq_cache_zeros(seq_cfg, n_envs, benv.spec.max_steps + 1)
    return attach_policy_state(benv, pstate0)


def calibrate_actor_cache(qparams: QuantizedParams, obs: jnp.ndarray, *,
                          backend: str = "auto") -> QuantizedParams:
    """Attach static activation scales to a packed MLP cache.

    Runs the per-layer dynamic path once over ``obs`` (a replay/rollout
    observation batch) and records, per dense layer, the affine params the
    dynamic quantizer derives for that layer's input — exactly the values
    ``int8_dense`` would compute on this batch, which is the fused kernel's
    bitwise-anchor contract.  The params come back cached in the packed
    pytree under ``ACT_QUANT`` (next to the weights, so the cache rides
    sync/snapshot transfers as one pytree) and ``quantized_apply`` then
    takes the single-pass fused kernel: no per-layer dynamic min/max
    reduction, inter-layer activations int8-resident.

    Call once per sync — the actor-learner topologies refresh it inside
    the PR-4 ``lax.cond`` repack / snapshot programs (``calib_batch`` on
    the configs).  CNN caches pass through uncalibrated (the fused kernel
    is MLP-only; conv actors keep the per-layer path).
    """
    names = set(qparams)
    if "embed" in names or any(n.startswith("conv") for n in names):
        # the fused kernel is MLP-only: transformer and conv caches keep
        # the per-layer dynamic-quantization path, calibration is a no-op
        return qparams
    n_hidden = sum(1 for n in names if n.startswith("fc"))
    act = []
    x = obs.reshape(-1, obs.shape[-1]).astype(jnp.float32)
    for i, name in enumerate(_mlp_layer_names(n_hidden)):
        p = affine.calibration_params(x, 8)
        act.append((p.delta, p.zero_point))
        if i < n_hidden:
            x = int8_dense(qparams[name], x, backend=backend,
                           act=jax.nn.relu)
    return {**qparams, ACT_QUANT: tuple(act)}


# ---------------------------------------------------------------------------
# Policy heads
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_act_fn(env_spec, *, backend: str = "auto"):
    """Deterministic deployment policy over packed params.

    Signature matches ``rl.env.evaluate``'s ``act_fn(params, obs)`` with the
    packed pytree in the params slot: discrete envs argmax over the first
    ``n_actions`` head outputs (A2C/PPO value heads are sliced off, DQN maps
    through unchanged); continuous envs apply the DDPG tanh*scale head.

    Cached per ``(env_spec, backend)`` (``EnvSpec`` is frozen/hashable) so
    repeated deployments of one env share an act-fn identity — which is
    what lets ``rl.env.evaluate`` reuse its compiled eval program.
    """
    if env_spec.continuous:
        def act(qparams, obs):
            """Continuous head: tanh * action_scale, f32 actions."""
            mu = quantized_apply(qparams, obs, backend=backend)
            return jnp.tanh(mu) * env_spec.action_scale
    else:
        n_act = env_spec.n_actions

        def act(qparams, obs):
            """Discrete head: argmax over n_actions logits, int32."""
            out = quantized_apply(qparams, obs, backend=backend)
            return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)
    return act


@functools.lru_cache(maxsize=None)
def make_sampling_policy(env_spec, *, backend: str = "auto"):
    """Stochastic rollout policy (training-time data collection).

    Returns ``policy(qparams, obs, key) -> (action, logits)`` sampling from
    the int8 actor's categorical head — the ActorQ data-collection path.
    """
    n_act = env_spec.n_actions

    def policy(qparams, obs, key):
        """Sample an int32 action from the categorical head; keep logits."""
        out = quantized_apply(qparams, obs, backend=backend)
        logits = out[..., :n_act]
        return jax.random.categorical(key, logits).astype(jnp.int32), logits
    return policy
