"""RL substrate: pure-JAX envs + DQN/A2C/PPO/DDPG + the QuaRL pipelines."""
from repro.rl import (a2c, actor_learner, actorq, buffer, common, ddpg,
                      distributed, dqn, env, loops, networks, ppo)
from repro.rl.loops import train, quarl_ptq, quarl_qat, QuarlResult

__all__ = ["a2c", "actor_learner", "actorq", "buffer", "common", "ddpg",
           "distributed", "dqn", "env", "loops",
           "networks", "ppo", "train", "quarl_ptq", "quarl_qat",
           "QuarlResult"]
