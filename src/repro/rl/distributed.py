"""Distributed RL training: shard_map data-parallel rollouts + learners.

The paper's QuaRL experiments ran on single GPUs; scaling the study (its
"fast and environmentally sustainable" pitch) means running many environment
batches in parallel. This module maps the A2C iteration onto a 'data' mesh
axis with ``jax.shard_map``:

  * every device steps its own slice of the vectorized environments and
    computes gradients on its own rollouts (params replicated),
  * gradients are ``psum``-averaged across the axis,
  * all devices apply the identical Adam update (replicated optimizer state),

— i.e. synchronous data-parallel actor-learners, the standard A2C scaling
pattern, QAT-instrumented exactly like the single-host path (observer
updates are EMA states; they are pmean-ed so every replica keeps identical
ranges).

Works on any mesh whose 'data' axis divides n_envs; on a 1-device CPU mesh it
degenerates to the single-host path (used by the fast tests; the multi-device
path is exercised with 8 fake host devices in tests/test_distributed_rl.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.adam import AdamConfig, adam_update
from repro.rl import a2c, actorq, common
from repro.rl.env import Env, batched_env, rollout
from repro.rl.networks import Network


def shard_map_compat(fn, mesh, *, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (top-level API vs experimental).

    Shared by this module and ``rl.actor_learner`` (which generalizes the
    data-parallel pattern here to the replay-driven actor–learner topology).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


_shard_map = shard_map_compat


def make_distributed_a2c(env: Env, net: Network, cfg: a2c.A2CConfig,
                         mesh: Mesh, axis: str = "data"):
    """Returns (iteration, act_fn, benv_global) — iteration signature matches
    the single-host a2c.make_iteration.

    ``cfg.actor_backend="int8"`` runs the ActorQ rollout inside the
    shard_map: every device packs the replicated params into an int8 cache
    once per learner update and steps its local env slice through the W8A8
    kernel; gradients (learner side) stay fp32 and are psum-averaged as
    usual.
    """
    actorq.validate_actor_backend(cfg.actor_backend)
    n_dev = mesh.shape[axis]
    assert cfg.n_envs % n_dev == 0, (cfg.n_envs, n_dev)
    local_envs = cfg.n_envs // n_dev
    benv_local = batched_env(env, local_envs)
    benv_global = batched_env(env, cfg.n_envs)
    adam_cfg = AdamConfig(lr=cfg.lr)
    n_act = env.spec.n_actions
    int8_policy = actorq.make_sampling_policy(
        env.spec, backend=cfg.kernel_backend) \
        if actorq.is_quantized(cfg.actor_backend) else None

    def heads(params, obs, observers, step):
        ctx = common.make_ctx(cfg.quant, observers, step)
        out = net.apply(ctx, params, obs)
        return out[..., :n_act], out[..., n_act], ctx.merged_collection()

    def shard_fn(state: common.TrainState, env_state, obs, key):
        # per-device: local rollout + local grads
        key = jax.random.fold_in(key[0], jax.lax.axis_index(axis))

        if int8_policy is not None:
            # quantized actor inside the shard: one int pack per update,
            # shared by all local env steps (params are replicated, so every
            # device packs the identical cache; calib_batch calibrates per
            # shard from its local obs slice -> fused kernel in the shard)
            qparams = actorq.make_actor_cache(
                state.params, cfg.actor_backend,
                calib_obs=actorq.calib_slice(obs, cfg.calib_batch)
                if cfg.calib_batch else None,
                backend=cfg.kernel_backend)

            def policy(params, obs, k):
                return int8_policy(qparams, obs, k)
        else:
            def policy(params, obs, k):
                logits, _, _ = heads(params, obs, state.observers,
                                     state.step)
                return jax.random.categorical(k, logits).astype(jnp.int32), \
                    logits

        k_roll, _ = jax.random.split(key)
        env_state, last_obs, traj = rollout(
            benv_local, policy, state.params, env_state, obs, k_roll,
            cfg.n_steps)

        def loss_fn(params):
            logits, values, new_coll = heads(params, traj.obs,
                                             state.observers, state.step)
            _, last_value, _ = heads(params, last_obs, state.observers,
                                     state.step)

            def disc(carry, step_t):
                reward, done = step_t
                carry = reward + cfg.gamma * carry * (1 - done)
                return carry, carry
            _, returns = jax.lax.scan(
                disc, jax.lax.stop_gradient(last_value),
                (traj.reward, traj.done), reverse=True)
            adv = jax.lax.stop_gradient(returns) - values
            logp = jax.nn.log_softmax(logits, axis=-1)
            logp_a = jnp.take_along_axis(logp, traj.action[..., None],
                                         axis=-1)[..., 0]
            p = jax.nn.softmax(logits, axis=-1)
            entropy = -jnp.sum(p * logp, axis=-1).mean()
            pg = -(jax.lax.stop_gradient(adv) * logp_a).mean()
            v_loss = jnp.square(adv).mean()
            return (pg + cfg.value_coef * v_loss
                    - cfg.entropy_coef * entropy), new_coll

        (loss, new_coll), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)

        # synchronous data parallelism: average grads (and observer EMA
        # states + scalar metrics) across the axis
        grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        new_coll = jax.lax.pmean(new_coll, axis)
        reward = jax.lax.pmean(
            jnp.sum(traj.reward) / jnp.maximum(jnp.sum(traj.done), 1.0),
            axis)

        new_params, new_opt, _ = adam_update(grads, state.opt, state.params,
                                             adam_cfg)
        new_state = common.TrainState(new_params, new_opt, new_coll,
                                      state.step + 1, ())
        return new_state, env_state, last_obs, {"loss": loss,
                                                "reward": reward}

    sharded = _shard_map(
        shard_fn, mesh,
        in_specs=(P(), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(axis), P(axis), P()))

    @jax.jit
    def iteration(state, env_state, obs, key):
        keys = jax.random.split(key, n_dev)
        return sharded(state, env_state, obs, keys)

    def act_fn(params, obs, observers=None, step=1 << 30):
        ctx = common.make_ctx(cfg.quant, observers or {}, step)
        out = net.apply(ctx, params, obs)
        return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)

    return iteration, act_fn, benv_global
