"""Pendulum-v1 (continuous torque control) — the offline stand-in for the
paper's PyBullet continuous-control suite (HalfCheetah/Walker2D dynamics are
not portable without a physics engine; Pendulum exercises the same DDPG
machinery: continuous actions, dense rewards, bounded torque)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec

MAX_SPEED = 8.0
MAX_TORQUE = 2.0
DT = 0.05
G = 10.0
M = 1.0
L = 1.0


class PendulumState(NamedTuple):
    theta: jnp.ndarray
    theta_dot: jnp.ndarray
    t: jnp.ndarray


def make_pendulum(max_steps: int = 200) -> Env:
    spec = EnvSpec("pendulum", obs_shape=(3,), action_dim=1,
                   action_scale=MAX_TORQUE, max_steps=max_steps)

    def obs_of(s):
        return jnp.stack([jnp.cos(s.theta), jnp.sin(s.theta), s.theta_dot])

    def reset(key):
        k1, k2 = jax.random.split(key)
        theta = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        theta_dot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        s = PendulumState(theta, theta_dot, jnp.zeros((), jnp.int32))
        return s, obs_of(s)

    def step(s: PendulumState, action, key):
        u = jnp.clip(action[..., 0], -MAX_TORQUE, MAX_TORQUE)
        th = ((s.theta + jnp.pi) % (2 * jnp.pi)) - jnp.pi
        cost = th ** 2 + 0.1 * s.theta_dot ** 2 + 0.001 * u ** 2
        theta_dot = s.theta_dot + (3 * G / (2 * L) * jnp.sin(s.theta)
                                   + 3.0 / (M * L ** 2) * u) * DT
        theta_dot = jnp.clip(theta_dot, -MAX_SPEED, MAX_SPEED)
        theta = s.theta + theta_dot * DT
        t = s.t + 1
        ns = PendulumState(theta, theta_dot, t)
        done = (t >= max_steps).astype(jnp.float32)
        return ns, obs_of(ns), -cost, done

    return Env(spec=spec, reset=reset, step=step)
