"""MountainCar-v0 (discrete) and MountainCarContinuous-v0 (Moore 1990)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec

MIN_POS, MAX_POS = -1.2, 0.6
MAX_SPEED = 0.07
GOAL_POS = 0.5


class MCState(NamedTuple):
    pos: jnp.ndarray
    vel: jnp.ndarray
    t: jnp.ndarray


def _obs(s: MCState) -> jnp.ndarray:
    return jnp.stack([s.pos, s.vel])


def _reset(key):
    pos = jax.random.uniform(key, (), minval=-0.6, maxval=-0.4)
    s = MCState(pos, jnp.zeros(()), jnp.zeros((), jnp.int32))
    return s, _obs(s)


def make_mountaincar(max_steps: int = 200) -> Env:
    spec = EnvSpec("mountaincar", obs_shape=(2,), n_actions=3,
                   max_steps=max_steps)

    def step(s: MCState, action, key):
        force = (action.astype(jnp.float32) - 1.0) * 0.001
        vel = jnp.clip(s.vel + force + jnp.cos(3 * s.pos) * (-0.0025),
                       -MAX_SPEED, MAX_SPEED)
        pos = jnp.clip(s.pos + vel, MIN_POS, MAX_POS)
        vel = jnp.where((pos == MIN_POS) & (vel < 0), 0.0, vel)
        t = s.t + 1
        ns = MCState(pos, vel, t)
        reached = pos >= GOAL_POS
        done = (reached | (t >= max_steps)).astype(jnp.float32)
        return ns, _obs(ns), -jnp.ones(()), done

    return Env(spec=spec, reset=_reset, step=step)


def make_mountaincar_continuous(max_steps: int = 999) -> Env:
    """Continuous version (the paper's DDPG MountainCar entry)."""
    spec = EnvSpec("mountaincar_continuous", obs_shape=(2,), action_dim=1,
                   max_steps=max_steps)

    def step(s: MCState, action, key):
        force = jnp.clip(action[..., 0], -1.0, 1.0)
        vel = jnp.clip(s.vel + force * 0.0015 + jnp.cos(3 * s.pos) * -0.0025,
                       -MAX_SPEED, MAX_SPEED)
        pos = jnp.clip(s.pos + vel, MIN_POS, MAX_POS)
        vel = jnp.where((pos == MIN_POS) & (vel < 0), 0.0, vel)
        t = s.t + 1
        ns = MCState(pos, vel, t)
        reached = pos >= GOAL_POS
        done = (reached | (t >= max_steps)).astype(jnp.float32)
        reward = jnp.where(reached, 100.0, 0.0) - 0.1 * force ** 2
        return ns, _obs(ns), reward, done

    return Env(spec=spec, reset=_reset, step=step)
