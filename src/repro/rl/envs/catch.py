"""Catch — pixel-observation Atari proxy (Mnih-style conv policy input).

A ball falls from a random column of a GRID x GRID board; the agent moves a
paddle (left/stay/right) on the bottom row; +1 for catching, -1 for missing.
Observations are (GRID, GRID, 1) float pixels, so the paper's 3-conv+FC
Atari architecture (Appendix B) runs unchanged. Episodes are ``balls``
consecutive drops to make episode returns graded rather than binary.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec


class CatchState(NamedTuple):
    ball_x: jnp.ndarray
    ball_y: jnp.ndarray
    paddle_x: jnp.ndarray
    caught: jnp.ndarray   # running score this episode
    balls_left: jnp.ndarray
    t: jnp.ndarray


def make_catch(grid: int = 10, balls: int = 5) -> Env:
    spec = EnvSpec("catch", obs_shape=(grid, grid, 1), n_actions=3,
                   max_steps=grid * balls + 2)

    def obs_of(s: CatchState) -> jnp.ndarray:
        board = jnp.zeros((grid, grid), jnp.float32)
        board = board.at[s.ball_y, s.ball_x].set(1.0)
        board = board.at[grid - 1, s.paddle_x].set(0.5)
        return board[..., None]

    def new_ball(key):
        return jax.random.randint(key, (), 0, grid)

    def reset(key):
        k1, k2 = jax.random.split(key)
        s = CatchState(ball_x=new_ball(k1), ball_y=jnp.zeros((), jnp.int32),
                       paddle_x=jax.random.randint(k2, (), 0, grid),
                       caught=jnp.zeros(()),
                       balls_left=jnp.asarray(balls, jnp.int32),
                       t=jnp.zeros((), jnp.int32))
        return s, obs_of(s)

    def step(s: CatchState, action, key):
        paddle = jnp.clip(s.paddle_x + action - 1, 0, grid - 1)
        ball_y = s.ball_y + 1
        at_bottom = ball_y >= grid - 1
        catch_hit = at_bottom & (s.ball_x == paddle)
        reward = jnp.where(at_bottom,
                           jnp.where(catch_hit, 1.0, -1.0), 0.0)
        balls_left = s.balls_left - at_bottom.astype(jnp.int32)
        # respawn ball at top on bottom-hit
        ball_x = jnp.where(at_bottom, new_ball(key), s.ball_x)
        ball_y = jnp.where(at_bottom, 0, ball_y)
        t = s.t + 1
        ns = CatchState(ball_x, ball_y, paddle, s.caught + reward,
                        balls_left, t)
        done = ((balls_left <= 0) | (t >= spec.max_steps)).astype(jnp.float32)
        return ns, obs_of(ns), reward, done

    return Env(spec=spec, reset=reset, step=step)
