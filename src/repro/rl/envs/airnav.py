"""AirNav — Air-Learning-style point-to-point aerial navigation (paper §5/D).

A 2D point-mass drone navigates a 25m x 25m arena with 1-5 random circular
obstacles to a random goal. Faithful to the paper's setup:

* 25 discrete actions (5 speeds x 5 yaw rates), V_max = 2.5 m/s (paper D).
* Reward (paper Eq. 1):  r = 1000*alpha - 100*beta - D_g - D_c*delta - 1
  with alpha = reached-goal, beta = collision-or-timeout, D_g = distance to
  goal, D_c = (V_max - V_now) * t_max the distance correction (Eq. 2).
* Obstacle count/positions and the goal are randomized every episode.
* max 750 steps per episode (paper footnote 2; reduced default here).

Observation: [dx_goal, dy_goal, vx, vy, heading_sin, heading_cos,
              nearest-obstacle dx, dy, dist] (the paper uses depth+IMU; we
use the equivalent geometric features the stub sensors would produce).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec

ARENA = 25.0
V_MAX = 2.5
T_MAX = 0.5           # actuation duration (s)
N_OBSTACLES = 5
OBSTACLE_R = 1.5
GOAL_R = 1.0
DELTA = 1.0           # distance-correction weight


class AirNavState(NamedTuple):
    pos: jnp.ndarray        # (2,)
    vel: jnp.ndarray        # (2,)
    heading: jnp.ndarray    # scalar rad
    goal: jnp.ndarray       # (2,)
    obstacles: jnp.ndarray  # (N_OBSTACLES, 3): x, y, active
    t: jnp.ndarray


SPEEDS = jnp.linspace(0.0, V_MAX, 5)
YAWS = jnp.linspace(-jnp.pi / 4, jnp.pi / 4, 5)


def make_airnav(max_steps: int = 300) -> Env:
    spec = EnvSpec("airnav", obs_shape=(9,), n_actions=25,
                   max_steps=max_steps)

    def obs_of(s: AirNavState) -> jnp.ndarray:
        to_goal = s.goal - s.pos
        d_obs = jnp.linalg.norm(s.obstacles[:, :2] - s.pos, axis=1)
        d_obs = jnp.where(s.obstacles[:, 2] > 0, d_obs, 1e6)
        i = jnp.argmin(d_obs)
        nearest = s.obstacles[i, :2] - s.pos
        return jnp.concatenate([
            to_goal / ARENA, s.vel / V_MAX,
            jnp.stack([jnp.sin(s.heading), jnp.cos(s.heading)]),
            nearest / ARENA, jnp.minimum(d_obs[i], ARENA)[None] / ARENA])

    def reset(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        pos = jax.random.uniform(k1, (2,), minval=2.0, maxval=ARENA - 2.0)
        goal = jax.random.uniform(k2, (2,), minval=2.0, maxval=ARENA - 2.0)
        n_active = jax.random.randint(k3, (), 1, N_OBSTACLES + 1)
        obs_xy = jax.random.uniform(k4, (N_OBSTACLES, 2), minval=3.0,
                                    maxval=ARENA - 3.0)
        # keep obstacles away from the start position
        d_start = jnp.linalg.norm(obs_xy - pos, axis=1)
        obs_xy = jnp.where((d_start < 3.0)[:, None], obs_xy + 4.0, obs_xy)
        active = (jnp.arange(N_OBSTACLES) < n_active).astype(jnp.float32)
        s = AirNavState(pos=pos, vel=jnp.zeros(2),
                        heading=jax.random.uniform(k5, (), minval=-jnp.pi,
                                                   maxval=jnp.pi),
                        goal=goal,
                        obstacles=jnp.concatenate([obs_xy, active[:, None]],
                                                  axis=1),
                        t=jnp.zeros((), jnp.int32))
        return s, obs_of(s)

    def step(s: AirNavState, action, key):
        speed = SPEEDS[action // 5]
        yaw = YAWS[action % 5]
        heading = s.heading + yaw
        vel = speed * jnp.stack([jnp.cos(heading), jnp.sin(heading)])
        pos = jnp.clip(s.pos + vel * T_MAX, 0.0, ARENA)
        t = s.t + 1

        d_goal = jnp.linalg.norm(s.goal - pos)
        d_obs = jnp.linalg.norm(s.obstacles[:, :2] - pos, axis=1)
        collided = jnp.any((d_obs < OBSTACLE_R) & (s.obstacles[:, 2] > 0))
        reached = d_goal < GOAL_R
        timeout = t >= max_steps

        alpha = reached.astype(jnp.float32)
        beta = (collided | timeout).astype(jnp.float32)
        d_c = (V_MAX - speed) * T_MAX          # paper Eq. 2
        reward = 1000.0 * alpha - 100.0 * beta - d_goal - d_c * DELTA - 1.0

        ns = AirNavState(pos, vel, heading, s.goal, s.obstacles, t)
        done = jnp.maximum(alpha, beta)
        return ns, obs_of(ns), reward, done

    return Env(spec=spec, reset=reset, step=step)
