"""Pure-JAX environments (gym-faithful dynamics; see env.py for the API)."""
from repro.rl.envs.cartpole import make_cartpole
from repro.rl.envs.mountaincar import make_mountaincar, make_mountaincar_continuous
from repro.rl.envs.pendulum import make_pendulum
from repro.rl.envs.catch import make_catch
from repro.rl.envs.airnav import make_airnav

ENVS = {
    "cartpole": make_cartpole,
    "mountaincar": make_mountaincar,
    "mountaincar_continuous": make_mountaincar_continuous,
    "pendulum": make_pendulum,
    "catch": make_catch,
    "airnav": make_airnav,
}


def make(name: str, **kwargs):
    return ENVS[name](**kwargs)
