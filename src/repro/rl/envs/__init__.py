"""Pure-JAX environments (gym-faithful dynamics; see env.py for the API)."""
from repro.rl.envs.cartpole import make_cartpole
from repro.rl.envs.mountaincar import (
    make_mountaincar,
    make_mountaincar_continuous,
)
from repro.rl.envs.pendulum import make_pendulum
from repro.rl.envs.catch import make_catch
from repro.rl.envs.airnav import make_airnav
from repro.rl.envs.wrappers import (
    make_airnav_seq,
    make_catch_seq,
    make_flicker_airnav,
    make_framestack,
    make_masked_catch,
)

ENVS = {
    "cartpole": make_cartpole,
    "mountaincar": make_mountaincar,
    "mountaincar_continuous": make_mountaincar_continuous,
    "pendulum": make_pendulum,
    "catch": make_catch,
    "airnav": make_airnav,
    "catch_masked": make_masked_catch,
    "airnav_flicker": make_flicker_airnav,
    "catch_seq": make_catch_seq,
    "airnav_seq": make_airnav_seq,
}

__all__ = [
    "ENVS", "make", "make_cartpole", "make_mountaincar",
    "make_mountaincar_continuous", "make_pendulum", "make_catch",
    "make_airnav", "make_masked_catch", "make_flicker_airnav",
    "make_framestack", "make_catch_seq", "make_airnav_seq",
]


def make(name: str, **kwargs):
    """Build a registered env by name (the ``loops.train`` entry point)."""
    return ENVS[name](**kwargs)
