"""Partially-observed env wrappers + the frame-stacking sequence adapter.

The sequence-policy workload (ROADMAP item 5) needs environments where a
memoryless policy is handicapped: these wrappers hide part of the state
so the actor must integrate over time, and ``make_framestack`` turns any
such env into a ``(context, feat)``-observation env the decoder
transformer (``models.seq_policy``) consumes.

All wrappers are pure functional ``Env``s like everything in ``envs/``:
state is a pytree, reset/step are jittable, and they compose with
``batched_env`` / ``auto_reset_step`` / the ``steps_per_call`` scan
fusion unchanged (``tests/test_seq_policy.py`` audits the uniform
``EnvSpec`` surface across the registry).

* ``make_masked_catch`` — Catch with the ball pixel visible only in the
  top ``visible_rows`` rows: the policy must remember the ball column
  from the first frames to position the paddle.
* ``make_flicker_airnav`` — AirNav with the observation blanked except
  every ``reveal_every``-th step (flickering sensors).
* ``make_framestack`` — generic: stacks the last ``context`` flattened
  observations as rows ``[obs..., t / max_steps, valid]`` (oldest first,
  newest last; pre-episode rows all-zero so ``valid`` doubles as the
  attention mask — see ``models.seq_policy``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec
from repro.rl.envs.airnav import make_airnav
from repro.rl.envs.catch import make_catch


def make_masked_catch(grid: int = 5, balls: int = 1,
                      visible_rows: int = 2) -> Env:
    """Catch whose ball pixel is hidden once it falls past ``visible_rows``.

    The paddle pixel (0.5) stays visible everywhere; only the ball pixel
    (1.0) is masked, so the observation is otherwise identical to plain
    Catch and a memoryless policy sees an empty board for most of the
    drop.
    """
    inner = make_catch(grid=grid, balls=balls)
    spec = EnvSpec("catch_masked", obs_shape=inner.spec.obs_shape,
                   n_actions=inner.spec.n_actions,
                   max_steps=inner.spec.max_steps)
    rows = jnp.arange(grid)[:, None, None]

    def mask_obs(obs):
        return jnp.where((rows >= visible_rows) & (obs == 1.0), 0.0, obs)

    def reset(key):
        state, obs = inner.reset(key)
        return state, mask_obs(obs)

    def step(state, action, key):
        state, obs, reward, done = inner.step(state, action, key)
        return state, mask_obs(obs), reward, done

    return Env(spec=spec, reset=reset, step=step)


class FlickerState(NamedTuple):
    """Wrapper state: the wrapped env's state plus the flicker phase."""
    inner: object
    tick: jnp.ndarray


def make_flicker_airnav(reveal_every: int = 3, **kwargs) -> Env:
    """AirNav whose observation is zeroed except every ``reveal_every``-th
    step (the reset observation is always revealed)."""
    inner = make_airnav(**kwargs)
    spec = EnvSpec("airnav_flicker", obs_shape=inner.spec.obs_shape,
                   n_actions=inner.spec.n_actions,
                   max_steps=inner.spec.max_steps)

    def reset(key):
        state, obs = inner.reset(key)
        return FlickerState(state, jnp.zeros((), jnp.int32)), obs

    def step(state, action, key):
        s, obs, reward, done = inner.step(state.inner, action, key)
        tick = state.tick + 1
        obs = jnp.where(tick % reveal_every == 0, obs,
                        jnp.zeros_like(obs))
        return FlickerState(s, tick), obs, reward, done

    return Env(spec=spec, reset=reset, step=step)


class FrameStackState(NamedTuple):
    """Wrapper state: inner env state + the frame rows + the step index."""
    inner: object
    frames: jnp.ndarray   # (context, feat) — oldest first
    t: jnp.ndarray


def make_framestack(env: Env, context: int = 8) -> Env:
    """Stack the last ``context`` observations into a ``(context, feat)``
    sequence observation.

    Each row is ``[flattened_obs..., t / max_steps, 1.0]`` — the
    normalized step index is the (shift-stable) positional signal and the
    trailing ``1.0`` the validity flag; rows older than the episode stay
    all-zero.  Composes with ``batched_env`` and the rollout scan like
    any env; on auto-reset the whole stack resets with the inner state.
    """
    feat = 1
    for d in env.spec.obs_shape:
        feat *= int(d)
    feat += 2
    spec = EnvSpec(f"{env.spec.name}_seq", obs_shape=(context, feat),
                   n_actions=env.spec.n_actions,
                   action_dim=env.spec.action_dim,
                   action_scale=env.spec.action_scale,
                   max_steps=env.spec.max_steps)
    inv_t = 1.0 / float(env.spec.max_steps)

    def frame_of(obs, t):
        return jnp.concatenate([
            obs.reshape(-1).astype(jnp.float32),
            jnp.stack([t.astype(jnp.float32) * inv_t,
                       jnp.ones((), jnp.float32)])])

    # The observation IS the frame buffer, but hand out a copy: drivers
    # donate (env_state, obs) to jit, and donation rejects the same
    # buffer appearing twice (eager reset would otherwise alias them).
    def reset(key):
        state, obs = env.reset(key)
        t = jnp.zeros((), jnp.int32)
        frames = jnp.zeros((context, feat), jnp.float32)
        frames = frames.at[-1].set(frame_of(obs, t))
        return FrameStackState(state, frames, t), jnp.copy(frames)

    def step(state, action, key):
        s, obs, reward, done = env.step(state.inner, action, key)
        t = state.t + 1
        frames = jnp.concatenate(
            [state.frames[1:], frame_of(obs, t)[None]], axis=0)
        return FrameStackState(s, frames, t), jnp.copy(frames), reward, done

    return Env(spec=spec, reset=reset, step=step)


def make_catch_seq(grid: int = 5, balls: int = 1, visible_rows: int = 2,
                   context: int = 6) -> Env:
    """Frame-stacked masked Catch — the sequence-policy training env."""
    return make_framestack(
        make_masked_catch(grid=grid, balls=balls,
                          visible_rows=visible_rows), context=context)


def make_airnav_seq(reveal_every: int = 3, context: int = 8,
                    max_steps: int = 120) -> Env:
    """Frame-stacked flickering AirNav (sequence-policy variant)."""
    return make_framestack(
        make_flicker_airnav(reveal_every=reveal_every,
                            max_steps=max_steps), context=context)
