"""CartPole-v1 (faithful gym dynamics; Barto, Sutton & Anderson 1983)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env, EnvSpec

GRAVITY = 9.8
MASSCART = 1.0
MASSPOLE = 0.1
TOTAL_MASS = MASSCART + MASSPOLE
LENGTH = 0.5
POLEMASS_LENGTH = MASSPOLE * LENGTH
FORCE_MAG = 10.0
TAU = 0.02
THETA_THRESHOLD = 12 * 2 * jnp.pi / 360
X_THRESHOLD = 2.4


class CartPoleState(NamedTuple):
    x: jnp.ndarray
    x_dot: jnp.ndarray
    theta: jnp.ndarray
    theta_dot: jnp.ndarray
    t: jnp.ndarray


def make_cartpole(max_steps: int = 500) -> Env:
    spec = EnvSpec("cartpole", obs_shape=(4,), n_actions=2,
                   max_steps=max_steps)

    def obs_of(s: CartPoleState) -> jnp.ndarray:
        return jnp.stack([s.x, s.x_dot, s.theta, s.theta_dot])

    def reset(key):
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        s = CartPoleState(vals[0], vals[1], vals[2], vals[3],
                          jnp.zeros((), jnp.int32))
        return s, obs_of(s)

    def step(s: CartPoleState, action, key):
        force = jnp.where(action == 1, FORCE_MAG, -FORCE_MAG)
        costheta, sintheta = jnp.cos(s.theta), jnp.sin(s.theta)
        temp = (force + POLEMASS_LENGTH * s.theta_dot ** 2 * sintheta) \
            / TOTAL_MASS
        thetaacc = (GRAVITY * sintheta - costheta * temp) / (
            LENGTH * (4.0 / 3.0 - MASSPOLE * costheta ** 2 / TOTAL_MASS))
        xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS
        x = s.x + TAU * s.x_dot
        x_dot = s.x_dot + TAU * xacc
        theta = s.theta + TAU * s.theta_dot
        theta_dot = s.theta_dot + TAU * thetaacc
        t = s.t + 1
        ns = CartPoleState(x, x_dot, theta, theta_dot, t)
        done = ((jnp.abs(x) > X_THRESHOLD)
                | (jnp.abs(theta) > THETA_THRESHOLD)
                | (t >= max_steps)).astype(jnp.float32)
        return ns, obs_of(ns), jnp.ones(()), done

    return Env(spec=spec, reset=reset, step=step)
