"""Advantage Actor-Critic (synchronous A2C, Mnih et al. 2016)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.qconfig import QuantConfig
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.rl import actorq, common
from repro.rl.env import Env, batched_env, rollout
from repro.rl.networks import Network


@dataclasses.dataclass(frozen=True)
class A2CConfig:
    lr: float = 7e-4
    gamma: float = 0.99
    n_envs: int = 16
    n_steps: int = 8
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    quant: QuantConfig = QuantConfig.none()
    # ActorQ: "int8" samples rollout actions from the packed int8 actor
    # (refreshed once per learner update); "int4" = byte-packed W4A8,
    # half the cache; the learner stays fp32.
    actor_backend: str = "fp32"
    kernel_backend: str = "auto"
    # calib_batch > 0: static activation scales from that many rollout
    # observations at each cache refresh -> single-pass fused MLP kernel
    # (see DQNConfig.calib_batch).  0 keeps dynamic quantization.
    calib_batch: int = 0


def init(key, env: Env, net: Network, cfg: A2CConfig):
    params = net.init(key)
    opt = adam_init(params, AdamConfig(lr=cfg.lr))
    return common.TrainState(params=params, opt=opt, observers={},
                             step=jnp.zeros((), jnp.int32), extras=())


def make_iteration(env: Env, net: Network, cfg: A2CConfig):
    """net outputs (n_actions + 1): logits + value head."""
    actorq.validate_actor_backend(cfg.actor_backend)
    benv = batched_env(env, cfg.n_envs)
    adam_cfg = AdamConfig(lr=cfg.lr)
    n_act = env.spec.n_actions
    int8_policy = actorq.make_sampling_policy(
        env.spec, backend=cfg.kernel_backend) \
        if actorq.is_quantized(cfg.actor_backend) else None

    def heads(params, obs, observers, step):
        ctx = common.make_ctx(cfg.quant, observers, step)
        out = net.apply(ctx, params, obs)
        return out[..., :n_act], out[..., n_act], ctx.merged_collection()

    @jax.jit
    def iteration(state: common.TrainState, env_state, obs, key):
        k_roll, k_learn = jax.random.split(key)

        if int8_policy is not None:
            # ActorQ hot path: pack once per learner update; the rollout
            # scan below reuses the int cache for every env step (fused
            # single-pass kernel when calib_batch calibrates it).
            qparams = actorq.make_actor_cache(
                state.params, cfg.actor_backend,
                calib_obs=actorq.calib_slice(obs, cfg.calib_batch)
                if cfg.calib_batch else None,
                backend=cfg.kernel_backend)

            def policy(params, obs, k):
                return int8_policy(qparams, obs, k)
        else:
            def policy(params, obs, k):
                logits, value, _ = heads(params, obs, state.observers,
                                         state.step)
                action = jax.random.categorical(k, logits)
                return action.astype(jnp.int32), logits

        env_state, last_obs, traj = rollout(
            benv, policy, state.params, env_state, obs, k_roll, cfg.n_steps)

        def loss_fn(params):
            logits, values, new_coll = heads(
                params, traj.obs, state.observers, state.step)  # (T, B, ...)
            _, last_value, _ = heads(params, last_obs, state.observers,
                                     state.step)

            def disc(carry, step_t):
                reward, done = step_t
                carry = reward + cfg.gamma * carry * (1 - done)
                return carry, carry
            _, returns = jax.lax.scan(
                disc, jax.lax.stop_gradient(last_value),
                (traj.reward, traj.done), reverse=True)
            adv = jax.lax.stop_gradient(returns) - values
            logp = jax.nn.log_softmax(logits, axis=-1)
            logp_a = jnp.take_along_axis(
                logp, traj.action[..., None], axis=-1)[..., 0]
            p = jax.nn.softmax(logits, axis=-1)
            entropy = -jnp.sum(p * logp, axis=-1).mean()
            pg_loss = -(jax.lax.stop_gradient(adv) * logp_a).mean()
            v_loss = jnp.square(adv).mean()
            loss = pg_loss + cfg.value_coef * v_loss \
                - cfg.entropy_coef * entropy
            return loss, (new_coll, entropy, logits)

        (loss, (new_coll, entropy, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        new_params, new_opt, _ = adam_update(grads, state.opt, state.params,
                                             adam_cfg)
        state = common.TrainState(new_params, new_opt, new_coll,
                                  state.step + 1, ())
        obs_out = last_obs
        metrics = {"loss": loss, "entropy": entropy,
                   "reward": jnp.sum(traj.reward) / jnp.maximum(
                       jnp.sum(traj.done), 1.0),
                   "action_dist_variance": jnp.var(
                       jax.nn.softmax(logits, axis=-1), axis=-1).mean()}
        return state, env_state, obs_out, metrics

    def act_fn(params, obs, observers=None, step=1 << 30):
        ctx = common.make_ctx(cfg.quant, observers or {}, step)
        out = net.apply(ctx, params, obs)
        return jnp.argmax(out[..., :n_act], axis=-1).astype(jnp.int32)

    return iteration, act_fn, benv
