"""Training loops + the QuaRL pipelines (paper Algorithms 1 and 2).

``train(...)`` runs any of the four algorithms on any env;
``quarl_ptq(...)``  = Algorithm 1: M = Train(T, L, A); return Eval(Q(M)).
``quarl_qat(...)``  = Algorithm 2: insert fake-quant ops, monitor ranges for
``quant_delay`` updates, then train with quantization; Eval with Q^train.

Both return a ``QuarlResult`` with fp32 and quantized rewards plus the
paper's relative error E_%.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib
from repro.core.qconfig import QuantConfig
from repro.rl import a2c, common, ddpg, dqn, ppo
from repro.rl.env import Env, evaluate
from repro.rl.envs import make as make_env
from repro.rl.networks import Network, make_network

ALGOS = ("dqn", "a2c", "ppo", "ddpg")


def _bootstrap_observers(algo, env, net, state, quant):
    """Pre-create every QAT observer slot (scan carries need fixed pytrees)."""
    from repro.core import fake_quant
    import jax.numpy as jnp
    obs0 = jnp.zeros((2,) + tuple(env.spec.obs_shape))

    if algo == "ddpg":
        def trace(rec):
            a = jnp.tanh(net.actor.apply(common.PrefixCtx(rec, "actor/"),
                                         state.params, obs0))
            x = jnp.concatenate([obs0.reshape(2, -1), a], axis=-1)
            net.critic.apply(common.PrefixCtx(rec, "critic/"),
                             state.extras.critic_params, x)
    else:
        def trace(rec):
            net.apply(rec, state.params, obs0)
    return fake_quant.discover_observers(quant, trace)


@dataclasses.dataclass
class TrainResult:
    state: common.TrainState
    act_fn: Callable
    env: Env
    rewards: List[float]
    action_variances: List[float]
    wall_time_s: float
    algo_cfg: Any
    net: Any


def _build(algo: str, env: Env, quant: QuantConfig, net_kwargs: Dict,
           overrides: Dict):
    if algo == "ddpg":
        assert env.spec.continuous, f"DDPG needs continuous env"
        nets = ddpg.make_nets(env, **net_kwargs)
        cfg = dataclasses.replace(ddpg.DDPGConfig(quant=quant), **overrides)
        return nets, cfg
    out_dim = env.spec.n_actions
    if algo in ("a2c", "ppo"):
        out_dim += 1  # value head
    net = make_network(env.spec.obs_shape, out_dim, **net_kwargs)
    if algo == "dqn":
        cfg = dataclasses.replace(dqn.DQNConfig(quant=quant), **overrides)
    elif algo == "a2c":
        cfg = dataclasses.replace(a2c.A2CConfig(quant=quant), **overrides)
    else:
        cfg = dataclasses.replace(ppo.PPOConfig(quant=quant), **overrides)
    return net, cfg


def train(algo: str, env_name: str, *, iterations: int = 200,
          quant: QuantConfig = QuantConfig.none(), seed: int = 0,
          net_kwargs: Optional[Dict] = None,
          algo_overrides: Optional[Dict] = None,
          record_every: int = 10, eval_episodes: int = 8) -> TrainResult:
    env = make_env(env_name)
    net, cfg = _build(algo, env, quant, net_kwargs or {},
                      algo_overrides or {})
    mod = {"dqn": dqn, "a2c": a2c, "ppo": ppo, "ddpg": ddpg}[algo]
    key = jax.random.PRNGKey(seed)
    k_init, k_env, k_run = jax.random.split(key, 3)
    state = mod.init(k_init, env, net, cfg)
    if quant.is_qat:
        state = state._replace(
            observers=_bootstrap_observers(algo, env, net, state, quant))
    iteration, act_fn, benv = mod.make_iteration(env, net, cfg)
    env_state, obs = benv.reset(k_env)

    rewards, variances = [], []
    t0 = time.time()
    for i in range(iterations):
        k_run, k_it = jax.random.split(k_run)
        state, env_state, obs, metrics = iteration(state, env_state, obs,
                                                   k_it)
        if (i + 1) % record_every == 0 or i == iterations - 1:
            k_run, k_eval = jax.random.split(k_run)
            det_act = lambda p, o: act_fn(p, o, state.observers, state.step)
            r = float(evaluate(env, det_act, state.params, k_eval,
                               eval_episodes,
                               max_steps=env.spec.max_steps))
            rewards.append(r)
            variances.append(float(metrics.get(
                "action_dist_variance", metrics.get("mean_q_var", 0.0))))
    wall = time.time() - t0
    return TrainResult(state=state, act_fn=act_fn, env=env, rewards=rewards,
                       action_variances=variances, wall_time_s=wall,
                       algo_cfg=cfg, net=net)


def eval_policy(result: TrainResult, quant: QuantConfig, key,
                episodes: int = 16) -> float:
    """Eval(Q(M)) — run the (possibly quantized) policy deterministically."""
    params = common.eval_params(result.state.params, quant)
    if quant.is_ptq and hasattr(result.state.extras, "critic_params"):
        pass  # DDPG: only the actor runs at deployment
    det_act = lambda p, o: result.act_fn(p, o, result.state.observers,
                                         result.state.step)
    return float(evaluate(result.env, det_act, params, key, episodes,
                          max_steps=result.env.spec.max_steps))


@dataclasses.dataclass
class QuarlResult:
    algo: str
    env: str
    label: str
    fp32_reward: float
    quant_reward: float
    error_pct: float
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


def quarl_ptq(algo: str, env_name: str, bits_list=(8, 16), *,
              iterations: int = 200, seed: int = 0,
              net_kwargs=None, algo_overrides=None,
              eval_episodes: int = 16) -> List[QuarlResult]:
    """Algorithm 1 over fp16 + intN PTQ."""
    result = train(algo, env_name, iterations=iterations, seed=seed,
                   net_kwargs=net_kwargs, algo_overrides=algo_overrides)
    key = jax.random.PRNGKey(seed + 1000)
    fp32 = eval_policy(result, QuantConfig.none(), key, eval_episodes)
    out = []
    for bits in bits_list:
        q = QuantConfig.ptq_fp16() if bits == 16 else QuantConfig.ptq_int(bits)
        r = eval_policy(result, q, key, eval_episodes)
        out.append(QuarlResult(
            algo=algo, env=env_name, label=q.label(), fp32_reward=fp32,
            quant_reward=r,
            error_pct=metrics_lib.relative_error(fp32, r),
            extra={"weight_stats": metrics_lib.weight_distribution_stats(
                result.state.params)}))
    return out


def quarl_qat(algo: str, env_name: str, bits: int, *, iterations: int = 200,
              quant_delay_frac: float = 0.5, seed: int = 0,
              net_kwargs=None, algo_overrides=None,
              eval_episodes: int = 16) -> QuarlResult:
    """Algorithm 2: train with fake quantization after a monitoring delay."""
    delay = int(iterations * quant_delay_frac)
    quant = QuantConfig.qat(bits, quant_delay=delay)
    fp = train(algo, env_name, iterations=iterations, seed=seed,
               net_kwargs=net_kwargs, algo_overrides=algo_overrides)
    qt = train(algo, env_name, iterations=iterations, quant=quant,
               seed=seed, net_kwargs=net_kwargs,
               algo_overrides=algo_overrides)
    key = jax.random.PRNGKey(seed + 2000)
    fp32 = eval_policy(fp, QuantConfig.none(), key, eval_episodes)
    q_r = eval_policy(qt, quant, key, eval_episodes)
    return QuarlResult(
        algo=algo, env=env_name, label=f"qat{bits}", fp32_reward=fp32,
        quant_reward=q_r, error_pct=metrics_lib.relative_error(fp32, q_r),
        extra={"variances_fp": fp.action_variances,
               "variances_qat": qt.action_variances,
               "rewards_fp": fp.rewards, "rewards_qat": qt.rewards})
