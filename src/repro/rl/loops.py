"""Training loops + the QuaRL pipelines (paper Algorithms 1 and 2).

``train(...)`` runs any of the four algorithms on any env;
``quarl_ptq(...)``  = Algorithm 1: M = Train(T, L, A); return Eval(Q(M)).
``quarl_qat(...)``  = Algorithm 2: insert fake-quant ops, monitor ranges for
``quant_delay`` updates, then train with quantization; Eval with Q^train.

Both return a ``QuarlResult`` with fp32 and quantized rewards plus the
paper's relative error E_%.

Hot-path knobs (ActorQ):

* ``steps_per_call`` — the scan-fused driver. ``make_scan_iteration`` wraps
  any algorithm's jitted iteration in a ``jax.lax.scan`` over a chunk of
  ``steps_per_call`` updates inside ONE jit with donated
  ``(state, env_state, obs)`` buffers, so the Python driver pays one
  dispatch per chunk instead of one per update.  Numerically equivalent to
  the per-step driver (same seed -> same params, bitwise on CPU): the PRNG
  split chain moves into the scan carry unchanged.
* ``actor_backend`` — ``"fp32"`` (default), ``"int8"`` or ``"int4"``.
  With ``"int8"`` the *actor* runs true integer inference (``rl.actorq``):
  params are packed into an int8 cache once per learner update and every
  dense/conv layer goes through the W8A8 kernel
  (``kernels.ops.int8_matmul``; backend matrix
  pallas/interpret/ref/xla/auto).  ``"int4"`` stores the cache as byte-packed
  W4A8 codes (half the bytes, unpacked in-kernel).  Rollout data
  collection uses the quantized actor for all four algorithms; evaluation
  uses it for every algorithm.  The learner's gradient path stays fp32 —
  exactly the paper's ActorQ split.
* ``calib_batch`` — static-requant knob (quantized backends, MLP
  policies): calibrate per-layer activation scales from this many live
  observations at every cache refresh and run the actor forward as ONE
  fused kernel pass (``kernels.fused_qmlp``) with int8-resident
  inter-layer activations — no per-layer dynamic range pass, one dispatch
  instead of ``n_layers``.  0 keeps dynamic per-layer quantization.
* ``topology`` — ``"fused"`` (default), ``"actor-learner"``, or
  ``"async"``.  ``"actor-learner"`` runs the paper's distributed ActorQ
  paradigm (``rl.actor_learner``) for the replay algorithms (DQN/DDPG):
  ``num_actors`` actor replicas collect rollouts (int8 under
  ``actor_backend="int8"``) into a sharded replay buffer, the fp32
  learner samples per-shard batches, and refreshed params reach the
  actors every ``sync_every`` iterations (the staleness knob) — one
  iteration is bulk-synchronous.  ``"async"`` is the overlapped regime
  the paper's speedups come from: actors and learner compile to two
  independent jit programs over a double-buffered replay
  (``rl.buffer.DoubleBuffer``), the host dispatches both with no
  ``block_until_ready`` barrier, swaps the write/read slots at sync
  points, and ``sync_every`` counts *learner updates* between param
  pushes.  Per-actor int8-vs-fp32 divergence is recorded in
  ``TrainResult.divergences`` at true pushes only; ``"async"``
  additionally records per-sync actor lag (``TrainResult.actor_lags``).
* ``replay`` — ``"uniform"`` (default) or ``"prioritized"`` (DQN/DDPG).
  Prioritized experience replay on a fully-JAX sum-tree (``rl.buffer``):
  the learner samples proportionally to
  ``(|td| + eps) ** priority_exponent``, corrects the bias with
  importance-sampling weights annealed from ``is_beta`` to 1, and pushes
  refreshed |TD| priorities after every update.  Under the actor–learner
  topology every shard owns its own tree and priority pushes stay inside
  the shard_map.  ``priority_exponent=0.0`` is bitwise-uniform (static
  dispatch onto the uniform path — the ``num_actors=1, sync_every=1``
  contract style).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib
from repro.core.qconfig import QuantConfig, QuantMode
from repro.rl import a2c, actor_learner, actorq, common, ddpg, dqn, ppo
from repro.rl import buffer as rb
from repro.rl.env import Env, evaluate
from repro.rl.envs import make as make_env
from repro.rl.networks import make_network

ALGOS = ("dqn", "a2c", "ppo", "ddpg")


def _bootstrap_observers(algo, env, net, state, quant):
    """Pre-create every QAT observer slot (scan carries need fixed pytrees)."""
    from repro.core import fake_quant
    import jax.numpy as jnp
    obs0 = jnp.zeros((2,) + tuple(env.spec.obs_shape))

    if algo == "ddpg":
        def trace(rec):
            a = jnp.tanh(net.actor.apply(common.PrefixCtx(rec, "actor/"),
                                         state.params, obs0))
            x = jnp.concatenate([obs0.reshape(2, -1), a], axis=-1)
            net.critic.apply(common.PrefixCtx(rec, "critic/"),
                             state.extras.critic_params, x)
    else:
        def trace(rec):
            net.apply(rec, state.params, obs0)
    return fake_quant.discover_observers(quant, trace)


@dataclasses.dataclass
class TrainResult:
    """Everything ``train`` hands back: the final ``state`` (params +
    optimizer), the deterministic ``act_fn(params, obs)``, the ``env``,
    per-record ``rewards``/``action_variances``, wall time, and the
    resolved algo config / network — enough to eval, deploy
    (``serving.PolicyServer.push_params(result.state.params)``), or
    resume."""

    state: common.TrainState
    act_fn: Callable
    env: Env
    rewards: List[float]
    action_variances: List[float]
    wall_time_s: float
    algo_cfg: Any
    net: Any
    # actor-learner topologies only: [per-actor mean-abs divergence between
    # the actors' behaviour head and the fp32 learner], sampled at true
    # param pushes only — per record point for topology="actor-learner"
    # (the last push's value carries between records; nothing is recorded
    # before the first push), per sync for topology="async"
    divergences: List[List[float]] = dataclasses.field(default_factory=list)
    # topology="async" only: per sync, how many learner updates the retired
    # actor snapshot served for (the realized staleness, >= sync_every)
    actor_lags: List[int] = dataclasses.field(default_factory=list)


def make_scan_iteration(iteration: Callable, steps_per_call: int):
    """Fuse ``steps_per_call`` algorithm iterations into one jitted scan.

    ``iteration(state, env_state, obs, key) -> (state, env_state, obs,
    metrics)`` is any algo's update (the already-jitted function from
    ``make_iteration`` works; jit-of-jit inlines).  The returned ``chunk``
    has signature ``chunk(state, env_state, obs, key) -> (state, env_state,
    obs, key, metrics)`` where ``key`` is the advanced run key and
    ``metrics`` is the per-iteration metrics dict stacked to shape
    ``(steps_per_call,)`` — accumulated on device, transferred once per
    chunk.

    The per-iteration PRNG chain (``key, k_it = split(key)``) runs inside
    the scan carry, byte-for-byte the chain the per-step driver produces on
    the host — so the two drivers are bitwise equivalent on CPU.
    ``(state, env_state, obs)`` buffers are donated: the carry updates in
    place instead of round-tripping fresh allocations per update.
    """
    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def chunk(state, env_state, obs, key):
        def body(carry, _):
            state, env_state, obs, key = carry
            key, k_it = jax.random.split(key)
            state, env_state, obs, metrics = iteration(state, env_state,
                                                       obs, k_it)
            return (state, env_state, obs, key), metrics

        (state, env_state, obs, key), metrics = jax.lax.scan(
            body, (state, env_state, obs, key), None, length=steps_per_call)
        return state, env_state, obs, key, metrics

    return chunk


def _build(algo: str, env: Env, quant: QuantConfig, net_kwargs: Dict,
           overrides: Dict):
    if algo == "ddpg":
        assert env.spec.continuous, f"DDPG needs continuous env"
        nets = ddpg.make_nets(env, **net_kwargs)
        cfg = dataclasses.replace(ddpg.DDPGConfig(quant=quant), **overrides)
        return nets, cfg
    out_dim = env.spec.n_actions
    if algo in ("a2c", "ppo"):
        out_dim += 1  # value head
    net = make_network(env.spec.obs_shape, out_dim, **net_kwargs)
    if algo == "dqn":
        cfg = dataclasses.replace(dqn.DQNConfig(quant=quant), **overrides)
    elif algo == "a2c":
        cfg = dataclasses.replace(a2c.A2CConfig(quant=quant), **overrides)
    else:
        cfg = dataclasses.replace(ppo.PPOConfig(quant=quant), **overrides)
    return net, cfg


def _loop_checkpointer(checkpoint_dir, checkpoint_every, resume, keep):
    """``AsyncCheckpointer`` for the train drivers, or None when disabled.

    Catches knob typos loudly: ``checkpoint_every``/``resume`` without a
    directory would otherwise silently train with no fault tolerance.
    """
    if not checkpoint_dir:
        if resume:
            raise ValueError("resume=True needs checkpoint_dir")
        if checkpoint_every:
            raise ValueError("checkpoint_every > 0 needs checkpoint_dir")
        return None
    from repro import checkpoint as ckpt_lib
    return ckpt_lib.AsyncCheckpointer(checkpoint_dir, keep=keep)


def train(algo: str, env_name: str, *, iterations: int = 200,
          quant: QuantConfig = QuantConfig.none(), seed: int = 0,
          net_kwargs: Optional[Dict] = None,
          algo_overrides: Optional[Dict] = None,
          record_every: int = 10, eval_episodes: int = 8,
          steps_per_call: int = 1,
          actor_backend: str = "fp32", calib_batch: int = 0,
          topology: str = "fused", num_actors: int = 1,
          sync_every: int = 1, mesh=None, async_barrier: bool = False,
          replay: str = "uniform", priority_exponent: float = 0.6,
          is_beta: float = 0.4,
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
          resume: bool = False, checkpoint_keep: int = 3,
          resilience: Any = None) -> TrainResult:
    """Train ``algo`` on ``env_name``.

    ``steps_per_call > 1`` enables the scan-fused driver (see module
    docstring): the Python loop dispatches ``iterations / steps_per_call``
    fused chunks instead of one jit call per update, with chunks clipped to
    ``record_every`` boundaries so recorded rewards/metrics are identical.

    ``actor_backend="int8"`` runs rollout data collection (all four
    algorithms) and the periodic evaluations through the true-int8 actor
    (``rl.actorq``); the learner stays fp32.  ``"int4"`` packs the actor
    cache to byte-packed W4A8 codes — half the int8 cache and sync/snapshot
    bytes, same 8-bit activation protocol.

    ``calib_batch > 0`` (quantized backends, MLP policies): every cache
    refresh also calibrates *static* activation scales from that many live
    rollout observations, replacing the per-layer dynamic range pass and
    running the actor forward through the single-pass fused kernel
    (``kernels.ops.fused_qmlp``).  0 (default) keeps the PR-1 dynamic
    per-layer path bitwise unchanged.

    ``topology="actor-learner"`` (DQN/DDPG) runs the paper's distributed
    ActorQ paradigm with ``num_actors`` replicas and a ``sync_every``
    staleness cadence — see ``rl.actor_learner``; ``mesh`` optionally
    shards the actor axis over devices.

    ``topology="async"`` (DQN/DDPG) overlaps the two: actor rollout chunks
    (``steps_per_call`` rollouts per dispatch) and learner update chunks
    run as independent jit programs over a double-buffered replay with no
    host barrier between them; ``sync_every`` counts *learner updates*
    between param pushes (each round runs
    ``steps_per_call * updates_per_iter`` updates, so pushes land on the
    first round boundary reaching the cadence).  ``async_barrier=True`` is
    the equivalence-contract mode: a single replay slot threaded
    actor -> learner serializes each round by dataflow, and with
    ``steps_per_call=1`` + ``sync_every=updates_per_iter`` the learner
    trajectory is bitwise identical to ``topology="actor-learner"`` with
    ``sync_every=1`` (the anchor test).

    ``replay="prioritized"`` (DQN/DDPG) samples learner batches
    proportionally to per-transition ``(|td| + eps) ** priority_exponent``
    from a fully-JAX sum-tree (per actor shard under the actor–learner
    topology) with importance-sampling correction annealed from
    ``is_beta`` to 1 — see ``rl.buffer``.  ``priority_exponent=0.0``
    degrades to bitwise-uniform sampling.

    ``checkpoint_dir`` + ``checkpoint_every`` enable fault tolerance
    (``repro.checkpoint``, all topologies): every ``checkpoint_every``
    iterations an ``AsyncCheckpointer`` snapshots learner + optimizer
    state, replay buffer (uniform and PER sum-trees), packed actor
    caches, env state, RNG keys and the host-side metric lists to
    ``checkpoint_dir`` on a background writer thread — the jit'd step
    never blocks on disk.  ``resume=True`` restarts from the newest
    committed step, and the contract is bitwise: resume-at-k then
    train-to-n equals the uninterrupted run to n exactly (checkpoint
    cadence never alters chunk boundaries or the PRNG chain; anchor
    tests in ``tests/test_resume.py``).  ``checkpoint_keep`` bounds
    retention; see ``docs/checkpointing.md``.

    ``resilience`` (optional) is a duck-typed hook object — in practice
    ``repro.resilience.ResilienceContext`` — giving the self-healing
    runtime its host-side injection/guard points: ``round_start`` /
    ``after_round`` around every dispatched chunk, ``on_eval_cache`` on
    the quantized eval mint, ``push`` around async param pushes, and
    ``checkpoint_committed`` after saves.  All hooks run on the host
    between jitted chunks, so an un-faulted guarded run follows the
    exact chunk/PRNG schedule of a bare one (the bitwise-recovery
    contract; see docs/resilience.md).  None (default) = zero overhead.
    """
    actorq.validate_actor_backend(actor_backend)
    actor_learner.validate_topology(topology)
    rb.validate_replay(replay)
    env = make_env(env_name)
    overrides = dict(algo_overrides or {})
    overrides.setdefault("actor_backend", actor_backend)
    overrides.setdefault("calib_batch", calib_batch)
    if algo in actor_learner.ALGOS:      # the replay algorithms (DQN/DDPG)
        overrides.setdefault("replay", replay)
        overrides.setdefault("priority_exponent", priority_exponent)
        overrides.setdefault("is_beta", is_beta)
    elif rb.validate_replay(overrides.get("replay", replay)) != "uniform":
        raise ValueError(
            f"replay='prioritized' needs a replay algorithm "
            f"{actor_learner.ALGOS}; {algo!r} is on-policy")
    net, cfg = _build(algo, env, quant, net_kwargs or {}, overrides)
    mod = {"dqn": dqn, "a2c": a2c, "ppo": ppo, "ddpg": ddpg}[algo]
    key = jax.random.PRNGKey(seed)
    k_init, k_env, k_run = jax.random.split(key, 3)
    if topology == "async":
        if algo not in actor_learner.ALGOS:
            raise ValueError(
                f"topology='async' needs a replay algorithm "
                f"{actor_learner.ALGOS}, got {algo!r}")
        if quant.is_qat:
            raise ValueError("async topology does not support QAT "
                             "(the learner trains fp32; use PTQ eval)")
        return _train_async(
            algo, env, net, cfg, iterations=iterations,
            record_every=record_every, eval_episodes=eval_episodes,
            steps_per_call=steps_per_call, num_actors=num_actors,
            sync_every=sync_every, mesh=mesh, barrier=async_barrier,
            actor_backend=actor_backend, k_init=k_init, k_env=k_env,
            k_run=k_run, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume,
            checkpoint_keep=checkpoint_keep, resilience=resilience)
    if async_barrier:
        raise ValueError("async_barrier is an async-topology knob — pass "
                         "topology='async'")
    if topology == "actor-learner":
        if algo not in actor_learner.ALGOS:
            raise ValueError(
                f"topology='actor-learner' needs a replay algorithm "
                f"{actor_learner.ALGOS}, got {algo!r}")
        if quant.is_qat:
            raise ValueError("actor-learner topology does not support QAT "
                             "(the learner trains fp32; use PTQ eval)")
        al_cfg = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                                  sync_every=sync_every)
        state = actor_learner.init(k_init, env, net, algo, cfg, al_cfg)
        iteration, act_fn, benv = actor_learner.make_actor_learner(
            algo, env, net, cfg, al_cfg, mesh=mesh)
    elif num_actors != 1 or sync_every != 1 or mesh is not None:
        raise ValueError(
            "num_actors/sync_every/mesh are actor-learner knobs — pass "
            "topology='actor-learner' (the fused driver would silently "
            "ignore them)")
    else:
        state = mod.init(k_init, env, net, cfg)
        if quant.is_qat:
            state = state._replace(
                observers=_bootstrap_observers(algo, env, net, state,
                                               quant))
        iteration, act_fn, benv = mod.make_iteration(env, net, cfg)
    env_state, obs = benv.reset(k_env)

    kernel_backend = getattr(cfg, "kernel_backend", "auto")
    int8_act = actorq.make_act_fn(env.spec, backend=kernel_backend) \
        if actorq.is_quantized(actor_backend) else None
    # stable act-fn identity across the run -> evaluate() compiles once;
    # observers/step ride along in the params slot as traced inputs
    det_act = _det_act(act_fn)
    chunks: Dict[int, Callable] = {}   # compiled fused drivers by length

    rewards, variances, divergences = [], [], []
    ckptr = _loop_checkpointer(checkpoint_dir, checkpoint_every, resume,
                               checkpoint_keep)
    i = 0
    if ckptr is not None and resume:
        start = ckptr.latest_step()
        if start is not None:
            # template = the freshly initialized run state: same
            # seed/config -> same treedef, and restore() validates every
            # leaf's shape/dtype against it before touching anything
            tree, extra = ckptr.restore(
                start, {"state": state, "env_state": env_state,
                        "obs": obs, "key": k_run})
            state, env_state, obs, k_run = (
                tree["state"], tree["env_state"], tree["obs"], tree["key"])
            i = int(extra["iteration"])
            rewards = [float(r) for r in extra["rewards"]]
            variances = [float(v) for v in extra["action_variances"]]
            divergences = [list(d) for d in extra["divergences"]]
    last_saved = i
    t0 = time.time()
    try:
        while i < iterations:
            if resilience is not None:
                resilience.round_start(i)
                resilience.dropped_sync_na(i, topology)
            # clip chunks to record boundaries so the recorded
            # metrics/rewards (and their PRNG draws) match the per-step
            # driver exactly
            next_stop = min((i // record_every + 1) * record_every,
                            iterations)
            n = min(max(steps_per_call, 1), next_stop - i)
            if n not in chunks:
                chunks[n] = make_scan_iteration(iteration, n)
            state, env_state, obs, k_run, metrics = chunks[n](
                state, env_state, obs, k_run)
            i += n
            if resilience is not None:
                state = _guard_round(resilience, state, i, cfg,
                                     actor_backend, kernel_backend)
            if i % record_every == 0 or i == iterations:
                last = jax.tree_util.tree_map(lambda m: m[-1], metrics)
                # actor-learner states carry the fp32 learner inside
                lview = state.learner \
                    if isinstance(state, actor_learner.ActorLearnerState) \
                    else state
                k_run, k_eval = jax.random.split(k_run)
                if int8_act is not None:
                    # evaluate the actor configuration that actually
                    # collects data / gets deployed: with calib_batch the
                    # eval cache is calibrated (from the live obs) and
                    # runs the fused kernel
                    cb = getattr(cfg, "calib_batch", 0)
                    obs_g = obs.reshape((-1,) + tuple(env.spec.obs_shape))

                    def mint_eval(p=lview.params, og=obs_g, cb=cb):
                        return actorq.make_actor_cache(
                            p, actor_backend,
                            calib_obs=actorq.calib_slice(og, cb)
                            if cb else None,
                            backend=kernel_backend)

                    qparams = mint_eval()
                    if resilience is not None:
                        qparams = resilience.on_eval_cache(qparams, i,
                                                           mint_eval)
                    r = float(evaluate(env, int8_act, qparams, k_eval,
                                       eval_episodes,
                                       max_steps=env.spec.max_steps))
                else:
                    r = float(evaluate(
                        env, det_act,
                        (lview.params, lview.observers, lview.step),
                        k_eval, eval_episodes,
                        max_steps=env.spec.max_steps))
                rewards.append(r)
                variances.append(float(last.get(
                    "action_dist_variance", last.get("mean_q_var", 0.0))))
                # staleness contract: the first true push happens at
                # iteration sync_every, so record points before it would
                # only see the init-time zeros (t=0 is not a sync — the
                # actors hold a fresh copy by construction) and are
                # skipped
                if "divergence" in last and i >= sync_every:
                    divergences.append(
                        np.asarray(last["divergence"]).tolist())
            if ckptr is not None and checkpoint_every > 0 and (
                    i - last_saved >= checkpoint_every or
                    (i == iterations and i > last_saved)):
                # end of the loop body: the saved key and metric lists
                # already include this boundary's eval draws, so a resumed
                # run continues the PRNG chain bitwise.  Cadence never
                # clips chunks — the chunk-boundary sequence is a function
                # of i alone, identical with or without checkpointing.
                ckptr.save_async(
                    i, {"state": state, "env_state": env_state, "obs": obs,
                        "key": k_run},
                    extra={"iteration": i, "rewards": rewards,
                           "action_variances": variances,
                           "divergences": divergences})
                last_saved = i
                if resilience is not None:
                    resilience.checkpoint_committed(ckptr, i)
        wall = time.time() - t0
        if ckptr is not None:
            ckptr.wait()
    finally:
        # an escaping fault/guard error must not leak the writer thread:
        # the supervisor's next attempt opens its own checkpointer on the
        # same directory (single-writer discipline holds per attempt)
        if ckptr is not None:
            ckptr.close()
    if isinstance(state, actor_learner.ActorLearnerState):
        state = state.learner
    return TrainResult(state=state, act_fn=act_fn, env=env, rewards=rewards,
                       action_variances=variances, wall_time_s=wall,
                       algo_cfg=cfg, net=net, divergences=divergences)


def _guard_round(resilience, state, step, cfg, actor_backend,
                 kernel_backend):
    """Topology-aware ``after_round`` adapter for the sync drivers.

    Maps the resilience hooks onto the state shape: the fused driver's
    ``TrainState`` exposes its params directly; ``ActorLearnerState``
    additionally carries the packed actor cache, which is both the
    bitflip_push target and — when minting is deterministic
    (``calib_batch == 0``) — verifiable against a fresh repack of the
    stale actor params (the in-jit sync mint and the eager re-mint are
    the same ops on the same buffers; CPU bitwise parity is the repo's
    standing fused-vs-per-step anchor).  All host-side: corruption and
    verification never touch the jitted chunk schedule.
    """
    is_al = isinstance(state, actor_learner.ActorLearnerState)
    if is_al:
        state = resilience.after_round(
            state, step,
            learner_view=lambda s: s.learner.params,
            set_learner=lambda s, p: s._replace(
                learner=s.learner._replace(params=p)),
            repack=lambda s, fn: s if s.actor_cache == ()
            else actor_learner.with_cache(s, fn(s.actor_cache)))
        cb = getattr(cfg, "calib_batch", 0)
        if (actorq.is_quantized(actor_backend) and cb == 0
                and state.actor_cache != ()
                and step % max(resilience.guard.check_every, 1) == 0):
            resilience.verify_state_cache(
                state.actor_cache,
                functools.partial(actor_learner.remint_cache, state,
                                  actor_backend,
                                  kernel_backend=kernel_backend),
                step)
        return state
    return resilience.after_round(
        state, step,
        learner_view=lambda s: s.params,
        set_learner=lambda s, p: s._replace(params=p))


def _train_async(algo, env, net, cfg, *, iterations, record_every,
                 eval_episodes, steps_per_call, num_actors, sync_every,
                 mesh, barrier, actor_backend, k_init, k_env, k_run,
                 checkpoint_dir=None, checkpoint_every=0, resume=False,
                 checkpoint_keep=3, resilience=None) -> TrainResult:
    """The ``topology="async"`` host driver: overlapped dispatch.

    Each round dispatches one actor chunk (``steps_per_call`` rollouts
    into the write slot) and one learner chunk
    (``steps_per_call * updates_per_iter`` updates against the read slot)
    back-to-back — JAX's async dispatch queues both with **no**
    ``block_until_ready`` between them; within a sync period the two
    program chains share no buffers, so the runtime is free to overlap
    them.  At sync points the host swaps the slots (a reference exchange,
    no device op) and pushes a fresh param snapshot; the divergence
    program is dispatched there too and only materialized at the end.
    The periodic evaluation at ``record_every`` boundaries is the one
    place the driver synchronizes (it reads rewards back to the host) —
    between records the loop never blocks.

    ``barrier=True`` threads a single replay slot actor -> learner, which
    serializes each round by dataflow — the equivalence-contract mode
    (see ``train``).
    """
    al_cfg = actor_learner.ActorLearnerConfig(num_actors=num_actors,
                                              sync_every=sync_every)
    progs = actor_learner.make_async_actor_learner(algo, env, net, cfg,
                                                   al_cfg, mesh=mesh)
    learner, wbuf = actor_learner.init_async(k_init, env, net, algo, cfg,
                                             al_cfg, double=not barrier)
    env_state, obs = progs.benv_global.reset(k_env)
    # snapshot after reset: with calib_batch the t=0 mint calibrates its
    # static activation scales from the fresh initial observations
    snap = progs.make_snapshot(learner, obs)

    kernel_backend = getattr(cfg, "kernel_backend", "auto")
    int8_act = actorq.make_act_fn(env.spec, backend=kernel_backend) \
        if actorq.is_quantized(actor_backend) else None
    det_act = _det_act(progs.act_fn)

    rewards, variances, actor_lags = [], [], []
    div_futs: List[Any] = []      # per-sync futures, materialized at the end
    updates_since_push = 0
    total_updates = 0             # learner updates dispatched (host-side)
    snap_minted_at = 0
    ckptr = _loop_checkpointer(checkpoint_dir, checkpoint_every, resume,
                               checkpoint_keep)
    i = 0
    if ckptr is not None and resume:
        start = ckptr.latest_step()
        if start is not None:
            # barrier mode threads ONE slot through learner.extras.replay
            # (wbuf is reassigned from it each round), so saving wbuf too
            # would duplicate the buffer — it checkpoints as None there
            tree, extra = ckptr.restore(
                start, {"learner": learner,
                        "wbuf": None if barrier else wbuf,
                        "env_state": env_state, "obs": obs, "snap": snap,
                        "key": k_run})
            learner, wbuf, env_state, obs, snap, k_run = (
                tree["learner"], tree["wbuf"], tree["env_state"],
                tree["obs"], tree["snap"], tree["key"])
            i = int(extra["iteration"])
            rewards = [float(r) for r in extra["rewards"]]
            variances = [float(v) for v in extra["action_variances"]]
            actor_lags = [int(x) for x in extra["actor_lags"]]
            div_futs = [np.asarray(d, dtype=np.float32)
                        for d in extra["divergences"]]
            updates_since_push = int(extra["updates_since_push"])
            total_updates = int(extra["total_updates"])
            snap_minted_at = int(extra["snap_minted_at"])
    last_saved = i
    t0 = time.time()
    try:
        while i < iterations:
            if resilience is not None:
                resilience.round_start(i)
            # clip rounds to record boundaries so evals land at the same
            # iteration counts whatever the chunk size.  NB unlike the
            # scan-fused driver the PRNG chain here is per-ROUND (one
            # split serves the whole chunk), so different steps_per_call
            # values are different — equally valid — trajectories; only
            # the barrier anchor mode at steps_per_call=1 is
            # bitwise-pinned to the synchronous topology
            next_stop = min((i // record_every + 1) * record_every,
                            iterations)
            c = min(max(steps_per_call, 1), next_stop - i)
            k_run, k_it = jax.random.split(k_run)
            k_roll, k_up = jax.random.split(k_it)
            if barrier:
                wbuf = learner.extras.replay
            env_state, obs, wbuf, _ = progs.actor_chunk(
                snap, env_state, obs, wbuf, k_roll, n_chunks=c)
            if barrier:
                learner = learner._replace(
                    extras=learner.extras._replace(replay=wbuf))
            learner, _ = progs.learner_chunk(
                learner, k_up, n_updates=c * cfg.updates_per_iter)
            total_updates += c * cfg.updates_per_iter
            updates_since_push += c * cfg.updates_per_iter
            i += c
            if resilience is not None:
                # nan_grad target + finite guard on the learner (the
                # one host sync a guarded async run adds per round)
                learner = resilience.after_round(
                    learner, i,
                    learner_view=lambda s: s.params,
                    set_learner=lambda s, p: s._replace(params=p))
            if updates_since_push >= sync_every and (
                    resilience is None or resilience.sync_due(i)):
                if not barrier:
                    learner, wbuf = actor_learner.swap_read_slot(learner,
                                                                 wbuf)
                actor_lags.append(total_updates - snap_minted_at)
                if resilience is not None:
                    # guarded push: bitflip_push lands here, the CRC +
                    # structural verify catches it, and a corrupted
                    # payload is re-minted (bounded backoff) before it
                    # can reach the actors
                    snap = resilience.push(
                        functools.partial(progs.make_snapshot, learner,
                                          obs), i)
                else:
                    snap = progs.make_snapshot(learner, obs)
                snap_minted_at = total_updates
                div_futs.append(progs.divergence(learner, snap, obs))
                updates_since_push = 0
            if i % record_every == 0 or i == iterations:
                k_run, k_eval = jax.random.split(k_run)
                if int8_act is not None:
                    # same contract as the sync driver: eval the
                    # calibrated (fused) cache whenever the rollout
                    # actors run one
                    cb = getattr(cfg, "calib_batch", 0)

                    def mint_eval(p=learner.params, og=obs, cb=cb):
                        return actorq.make_actor_cache(
                            p, actor_backend,
                            calib_obs=actorq.calib_slice(og, cb)
                            if cb else None,
                            backend=kernel_backend)

                    qparams = mint_eval()
                    if resilience is not None:
                        qparams = resilience.on_eval_cache(qparams, i,
                                                           mint_eval)
                    r = float(evaluate(env, int8_act, qparams, k_eval,
                                       eval_episodes,
                                       max_steps=env.spec.max_steps))
                else:
                    r = float(evaluate(
                        env, det_act,
                        (learner.params, learner.observers, learner.step),
                        k_eval, eval_episodes,
                        max_steps=env.spec.max_steps))
                rewards.append(r)
                # neither async program surfaces an action-variance
                # metric (same zeros the synchronous actor-learner
                # topology records)
                variances.append(0.0)
            if ckptr is not None and checkpoint_every > 0 and (
                    i - last_saved >= checkpoint_every or
                    (i == iterations and i > last_saved)):
                # saves land at natural round boundaries only (cadence
                # never clips a round), so the per-round PRNG chain —
                # and with it the whole trajectory — is identical with
                # or without checkpointing.  Host-copying here blocks
                # this thread on the in-flight chunks, but never inserts
                # a device barrier into the dispatch chain itself.
                div_futs = [np.asarray(d) for d in div_futs]
                ckptr.save_async(
                    i, {"learner": learner,
                        "wbuf": None if barrier else wbuf,
                        "env_state": env_state, "obs": obs, "snap": snap,
                        "key": k_run},
                    extra={"iteration": i, "rewards": rewards,
                           "action_variances": variances,
                           "divergences": [d.tolist() for d in div_futs],
                           "actor_lags": actor_lags,
                           "updates_since_push": updates_since_push,
                           "total_updates": total_updates,
                           "snap_minted_at": snap_minted_at})
                last_saved = i
                if resilience is not None:
                    resilience.checkpoint_committed(ckptr, i)
        wall = time.time() - t0
        divergences = [np.asarray(d).tolist() for d in div_futs]
        if ckptr is not None:
            ckptr.wait()
    finally:
        # never leak the writer thread past a fault/guard error — the
        # supervisor's next attempt opens a fresh checkpointer
        if ckptr is not None:
            ckptr.close()
    return TrainResult(state=learner, act_fn=progs.act_fn, env=env,
                       rewards=rewards, action_variances=variances,
                       wall_time_s=wall, algo_cfg=cfg, net=net,
                       divergences=divergences, actor_lags=actor_lags)


@functools.lru_cache(maxsize=32)
def _det_act(act_fn):
    """Deterministic wrapper with a cached identity per underlying act_fn.

    Threads (params, observers, step) through ``evaluate``'s params slot so
    repeated evals of one trained policy (e.g. the ``quarl_ptq`` bits loop)
    reuse a single compiled eval program.
    """
    return lambda p, o: act_fn(p[0], o, p[1], p[2])


def eval_policy(result: TrainResult, quant: QuantConfig, key,
                episodes: int = 16, *, actor_backend: str = "fp32",
                kernel_backend: str = "auto") -> float:
    """Eval(Q(M)) — run the (possibly quantized) policy deterministically.

    Deployment quantizes only the actor: ``result.state.params`` holds the
    actor params for every algorithm (the DDPG critic lives in
    ``state.extras`` and never runs at deployment, per the paper).

    ``actor_backend="int8"`` deploys the packed int8 actor through the W8A8
    kernel (``kernels.ops.int8_matmul``, ``kernel_backend`` selecting
    pallas/interpret/ref/xla/auto) for int PTQ configs of <= 8 bits;
    ``"int4"`` additionally caps the packed width at 4 bits (byte-packed
    W4A8 — the half-size deployment cache); other configs (fp16, wide
    ints, QAT range replay) keep the fp32 simulation.
    """
    actorq.validate_actor_backend(actor_backend)
    if (actorq.is_quantized(actor_backend)
            and quant.mode == QuantMode.PTQ_INT and quant.bits <= 8):
        bits = min(quant.bits, actorq.backend_bits(actor_backend))
        qparams = actorq.pack_actor_params(result.state.params, bits=bits)
        act = actorq.make_act_fn(result.env.spec, backend=kernel_backend)
        return float(evaluate(result.env, act, qparams, key, episodes,
                              max_steps=result.env.spec.max_steps))
    params = common.eval_params(result.state.params, quant)
    return float(evaluate(
        result.env, _det_act(result.act_fn),
        (params, result.state.observers, result.state.step), key, episodes,
        max_steps=result.env.spec.max_steps))


@dataclasses.dataclass
class QuarlResult:
    """One row of a QuaRL PTQ/QAT study: fp32 vs quantized eval reward
    for (``algo``, ``env``) at the bit-width named by ``label``, with the
    paper's relative ``error_pct`` and study-specific ``extra`` values."""

    algo: str
    env: str
    label: str
    fp32_reward: float
    quant_reward: float
    error_pct: float
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


def quarl_ptq(algo: str, env_name: str, bits_list=(8, 16), *,
              iterations: int = 200, seed: int = 0,
              net_kwargs=None, algo_overrides=None,
              eval_episodes: int = 16, steps_per_call: int = 1,
              actor_backend: str = "fp32") -> List[QuarlResult]:
    """Algorithm 1 over fp16 + intN PTQ.

    ``actor_backend="int8"`` deploys each intN evaluation through the packed
    int8 actor instead of the fp32 fake-quant simulation (the fp32 baseline
    eval always runs fp32).
    """
    result = train(algo, env_name, iterations=iterations, seed=seed,
                   net_kwargs=net_kwargs, algo_overrides=algo_overrides,
                   steps_per_call=steps_per_call)
    key = jax.random.PRNGKey(seed + 1000)
    fp32 = eval_policy(result, QuantConfig.none(), key, eval_episodes)
    out = []
    for bits in bits_list:
        q = QuantConfig.ptq_fp16() if bits == 16 else QuantConfig.ptq_int(bits)
        r = eval_policy(result, q, key, eval_episodes,
                        actor_backend=actor_backend)
        out.append(QuarlResult(
            algo=algo, env=env_name, label=q.label(), fp32_reward=fp32,
            quant_reward=r,
            error_pct=metrics_lib.relative_error(fp32, r),
            extra={"weight_stats": metrics_lib.weight_distribution_stats(
                result.state.params)}))
    return out


def quarl_qat(algo: str, env_name: str, bits: int, *, iterations: int = 200,
              quant_delay_frac: float = 0.5, seed: int = 0,
              net_kwargs=None, algo_overrides=None,
              eval_episodes: int = 16, steps_per_call: int = 1,
              actor_backend: str = "fp32") -> QuarlResult:
    """Algorithm 2: train with fake quantization after a monitoring delay.

    ``actor_backend="int8"`` collects the QAT run's rollouts with the true
    int8 actor (A2C/DQN); the QAT evaluation itself replays the monitored
    fake-quant ranges, which need the fp32 simulation path.
    """
    delay = int(iterations * quant_delay_frac)
    quant = QuantConfig.qat(bits, quant_delay=delay)
    fp = train(algo, env_name, iterations=iterations, seed=seed,
               net_kwargs=net_kwargs, algo_overrides=algo_overrides,
               steps_per_call=steps_per_call)
    qt = train(algo, env_name, iterations=iterations, quant=quant,
               seed=seed, net_kwargs=net_kwargs,
               algo_overrides=algo_overrides,
               steps_per_call=steps_per_call, actor_backend=actor_backend)
    key = jax.random.PRNGKey(seed + 2000)
    fp32 = eval_policy(fp, QuantConfig.none(), key, eval_episodes)
    q_r = eval_policy(qt, quant, key, eval_episodes)
    return QuarlResult(
        algo=algo, env=env_name, label=f"qat{bits}", fp32_reward=fp32,
        quant_reward=q_r, error_pct=metrics_lib.relative_error(fp32, q_r),
        extra={"variances_fp": fp.action_variances,
               "variances_qat": qt.action_variances,
               "rewards_fp": fp.rewards, "rewards_qat": qt.rewards})
