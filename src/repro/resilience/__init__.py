"""Self-healing ActorQ runtime: fault injection, guards, supervision.

Three modules (docs/resilience.md has the full model):

* ``faults``     — deterministic seeded fault injection (``FaultPlan``)
  and the ``ResilienceContext`` hook object the training drivers take
  (``loops.train(resilience=...)``).
* ``guards``     — typed integrity/numerical/structural guards: CRC'd
  packed-cache pushes, jit-compatible finite checks, int8/int4 cache
  validation.
* ``supervisor`` — the retry → rollback → abort escalation driver with
  a per-phase heartbeat watchdog (lazy-imported below: it imports
  ``rl.loops``, which must stay importable without this package).
"""
from repro.resilience.faults import (ActorCrashError, FaultError,
                                     FaultInjector, FaultPlan, FaultSpec,
                                     ResilienceContext, bitflip_tree,
                                     poison_params)
from repro.resilience.guards import (CodeRangeError, GuardConfig,
                                     GuardError, IntegrityError,
                                     NonFiniteError, all_finite,
                                     check_finite, tree_crc32,
                                     validate_cache, verify_crc)

_SUPERVISOR = ("supervise", "SupervisorAbort", "SupervisorConfig",
               "SupervisorReport", "Watchdog")

__all__ = [
    "ActorCrashError", "FaultError", "FaultInjector", "FaultPlan",
    "FaultSpec", "ResilienceContext", "bitflip_tree", "poison_params",
    "CodeRangeError", "GuardConfig", "GuardError", "IntegrityError",
    "NonFiniteError", "all_finite", "check_finite", "tree_crc32",
    "validate_cache", "verify_crc", *_SUPERVISOR,
]


def __getattr__(name):
    """Lazy re-export of the supervisor layer (breaks the loops cycle)."""
    if name in _SUPERVISOR:
        from repro.resilience import supervisor
        return getattr(supervisor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
