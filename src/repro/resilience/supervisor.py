"""Supervised training driver: watchdog, retry, rollback, abort.

``supervise(...)`` wraps ``loops.train`` in the escalation policy a
long-lived ActorQ run needs (wasted retraining is the dominant
emissions term — see PAPERS.md "Greener Deep RL"):

1. **Retry** — a typed fault/guard/checkpoint error restarts the phase
   by resuming from the newest *valid* checkpoint (``resume=True``; the
   corrupted-step fallback lives in ``CheckpointManager.latest_step``),
   up to ``max_retries`` times with deterministic-jitter exponential
   backoff between attempts.  The PR-8 bitwise-resume contract makes a
   successful retry indistinguishable from a run that never faulted.
2. **Rollback** — when retries exhaust (the newest checkpoint itself
   reproduces the failure — e.g. it already contains poisoned params),
   the newest checkpoint step is deleted and the retry budget resets,
   up to ``max_rollbacks`` times: training re-runs from the previous
   good step, and — same contract — lands bitwise where a clean run
   from that step would.
3. **Abort** — when rollbacks exhaust too, ``SupervisorAbort`` raises
   with a structured ``SupervisorReport`` (attempt log, faults fired /
   not-applicable, quarantined shards, watchdog stalls) so the failure
   is diagnosable instead of a stack trace at hour six.

The per-phase **watchdog** consumes the heartbeats the resilience hooks
emit from the drivers (round / push / checkpoint) on a monitor thread;
a heartbeat gap beyond ``watchdog_timeout_s`` is recorded as a stall
(an injected straggler shows up here).  It observes — it never kills a
jitted computation mid-flight; stalls surface in the report.

Quarantine semantics on the single-host vectorized actor axis: a
crashed shard is recorded in ``report.quarantined`` and the run resumes
with all shards live (resume re-initializes the vectorized env state
from the checkpoint).  Under the planned multi-process topology
(ROADMAP item 4) the same record maps to excluding the dead actor
process from the mesh — degrade, don't die.
"""
from __future__ import annotations

import dataclasses
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.resilience import faults, guards


class SupervisorAbort(RuntimeError):
    """Escalation exhausted; carries the structured ``report``."""

    def __init__(self, message: str, report: "SupervisorReport"):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Escalation-policy knobs.

    ``max_retries`` — resume-from-checkpoint attempts per rollback
    level; ``max_rollbacks`` — newest-checkpoint deletions before
    abort (0 disables rollback); ``watchdog_timeout_s`` — heartbeat gap
    that counts as a stall; ``backoff_base_s``/``backoff_factor``/
    ``backoff_cap_s`` — inter-attempt backoff (deterministic jitter
    keyed on the fault-plan seed).
    """

    max_retries: int = 2
    max_rollbacks: int = 1
    watchdog_timeout_s: float = 60.0
    backoff_base_s: float = 0.02
    backoff_factor: float = 2.0
    backoff_cap_s: float = 1.0


@dataclasses.dataclass
class SupervisorReport:
    """Structured diagnostic record of one supervised run."""

    status: str = "ok"                 # "ok" | "aborted"
    attempts: int = 0
    retries: int = 0
    rollbacks: int = 0
    attempt_log: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list)
    faults_fired: List[Tuple[str, int, str]] = dataclasses.field(
        default_factory=list)
    faults_not_applicable: List[Tuple[str, int, str]] = dataclasses.field(
        default_factory=list)
    quarantined: List[int] = dataclasses.field(default_factory=list)
    stalls: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    events: List[Tuple[str, int, str]] = dataclasses.field(
        default_factory=list)
    error: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready form (bench artifacts, CLI dumps)."""
        return dataclasses.asdict(self)

    def summary(self) -> str:
        """Human-readable one-paragraph digest for CLI output."""
        lines = [f"supervisor: {self.status} after {self.attempts} "
                 f"attempt(s) ({self.retries} retries, "
                 f"{self.rollbacks} rollbacks)"]
        if self.faults_fired:
            lines.append("  faults fired: " + ", ".join(
                f"{k}@{s}" + (f" [{d}]" if d else "")
                for k, s, d in self.faults_fired))
        if self.faults_not_applicable:
            lines.append("  not applicable: " + ", ".join(
                f"{k}@{s} ({w})" for k, s, w in
                self.faults_not_applicable))
        if self.quarantined:
            lines.append(f"  quarantined shards: {self.quarantined}")
        if self.stalls:
            lines.append(f"  watchdog stalls: {len(self.stalls)}")
        if self.error:
            lines.append(f"  last error: {self.error}")
        return "\n".join(lines)


class Watchdog:
    """Heartbeat monitor on a daemon thread.

    ``beat(phase, step)`` is the producer side (wired as the
    ``ResilienceContext`` heartbeat sink); the monitor records a stall
    whenever the gap since the last beat exceeds ``timeout_s``, once
    per stall episode (the next beat re-arms it).  Observation only —
    a stalled jit computation cannot be safely interrupted from here.
    """

    def __init__(self, timeout_s: float = 60.0,
                 poll_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = float(timeout_s)
        self._poll_s = poll_s if poll_s is not None \
            else max(min(timeout_s / 4.0, 1.0), 0.01)
        self._clock = clock
        self._lock = threading.Lock()
        self._last = (clock(), "start", -1)
        self._stalled = False
        self.stalls: List[Dict[str, Any]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def beat(self, phase: str, step: int) -> None:
        """Record liveness (called from the training thread's hooks)."""
        with self._lock:
            self._last = (self._clock(), phase, step)
            self._stalled = False

    def check(self) -> None:
        """One monitor poll (exposed for deterministic tests)."""
        with self._lock:
            t, phase, step = self._last
            gap = self._clock() - t
            if gap > self.timeout_s and not self._stalled:
                self._stalled = True
                self.stalls.append({"phase": phase, "step": step,
                                    "stalled_for_s": round(gap, 3)})

    def _run(self) -> None:
        while not self._stop.wait(self._poll_s):
            self.check()

    def start(self) -> "Watchdog":
        """Start the monitor thread (idempotent)."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="resilience-watchdog", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop and join the monitor thread."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


# exceptions that trigger the escalation ladder: injected faults, guard
# violations, and checkpoint-layer ValueErrors (torn/corrupt restores).
# Anything else (TypeError, jit tracer errors, ...) is a bug and raises
# straight through — retrying a deterministic programming error burns
# exactly the compute this subsystem exists to save.
RECOVERABLE = (faults.FaultError, guards.GuardError, ValueError)


def supervise(train_kwargs: Dict[str, Any], *,
              plan: Optional[faults.FaultPlan] = None,
              guard: Optional[guards.GuardConfig] = None,
              config: Optional[SupervisorConfig] = None,
              train_fn: Optional[Callable] = None,
              sleep: Callable[[float], None] = time.sleep
              ) -> Tuple[Any, SupervisorReport]:
    """Run ``loops.train(**train_kwargs)`` under supervision.

    ``train_kwargs`` are the exact ``loops.train`` kwargs (including
    ``algo``/``env_name``); rollback and retry-by-resume need
    ``checkpoint_dir`` + ``checkpoint_every`` in there — without them a
    retry restarts from scratch (still bounded, still reported).

    Returns ``(TrainResult, SupervisorReport)`` on success; raises
    ``SupervisorAbort`` (carrying the report) when the escalation
    ladder exhausts.  The ``FaultInjector`` built from ``plan`` is
    shared across attempts: a fault that fired and crashed an attempt
    does not re-fire in the recovery that replays its round.
    """
    if train_fn is None:
        from repro.rl import loops
        train_fn = loops.train
    cfg = config if config is not None else SupervisorConfig()
    injector = faults.FaultInjector(plan) if plan is not None else None
    seed = plan.seed if plan is not None else 0
    watchdog = Watchdog(timeout_s=cfg.watchdog_timeout_s).start()
    ctx = faults.ResilienceContext(injector, guard,
                                   on_heartbeat=watchdog.beat)
    report = SupervisorReport()
    kwargs = dict(train_kwargs)
    ckpt_dir = kwargs.get("checkpoint_dir")
    can_resume = bool(ckpt_dir) and kwargs.get("checkpoint_every", 0) > 0
    retries = 0
    try:
        while True:
            report.attempts += 1
            watchdog.beat("attempt", report.attempts)
            try:
                result = train_fn(**kwargs, resilience=ctx)
                report.status = "ok"
                return result, report
            except RECOVERABLE as e:
                report.error = f"{type(e).__name__}: {e}"
                report.attempt_log.append({
                    "attempt": report.attempts,
                    "error": report.error,
                    "action": None,
                })
                if retries < cfg.max_retries:
                    retries += 1
                    report.retries += 1
                    report.attempt_log[-1]["action"] = "retry"
                    if can_resume:
                        kwargs["resume"] = True
                    sleep(guards.backoff_delay(
                        retries - 1, base_s=cfg.backoff_base_s,
                        factor=cfg.backoff_factor,
                        cap_s=cfg.backoff_cap_s, seed=seed))
                    continue
                if report.rollbacks < cfg.max_rollbacks and can_resume:
                    # the newest checkpoint keeps reproducing the
                    # failure (e.g. poison was saved before the guard
                    # tripped): discard it and re-run from the previous
                    # good step with a fresh retry budget
                    step = _rollback_newest(ckpt_dir)
                    report.rollbacks += 1
                    retries = 0
                    report.attempt_log[-1]["action"] = \
                        f"rollback (dropped step {step})"
                    kwargs["resume"] = True
                    continue
                report.status = "aborted"
                report.attempt_log[-1]["action"] = "abort"
                raise SupervisorAbort(
                    f"training failed after {report.attempts} attempt(s), "
                    f"{report.retries} retries, {report.rollbacks} "
                    f"rollbacks: {report.error}", report) from e
    finally:
        watchdog.stop()
        report.stalls = list(watchdog.stalls)
        report.events = list(ctx.events)
        report.quarantined = list(ctx.quarantined)
        if injector is not None:
            report.faults_fired = list(injector.fired)
            report.faults_not_applicable = list(injector.not_applicable)


def _rollback_newest(ckpt_dir: str) -> Optional[int]:
    """Delete the newest valid checkpoint step; returns its number."""
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(ckpt_dir)
    step = mgr.latest_step()
    if step is not None:
        shutil.rmtree(mgr.step_path(step), ignore_errors=True)
    return step
