"""Integrity and numerical guards for the self-healing ActorQ runtime.

Three guard families, each raising a *typed* error instead of letting a
fault corrupt training silently:

* **Integrity** — ``tree_crc32`` checksums a packed actor cache (codes +
  scales, every leaf in flatten order) so a param-push payload can be
  verified at the consumer: ``verify_crc`` raises ``IntegrityError`` on
  any bit difference.  The async sync-push and ``PolicyServer`` hot-swap
  carry the CRC with the payload; the bulk-synchronous topology verifies
  the carried cache against a repack of its fp32 source.
* **Numerical** — ``all_finite`` is a jit-compatible all-leaves-finite
  reduction over the float leaves of any pytree; the host-side
  ``check_finite`` wrapper raises ``NonFiniteError`` naming every
  offending leaf path (a NaN/Inf gradient that landed on the learner is
  caught at the next guarded round instead of poisoning every update
  after it).
* **Structural** — ``validate_cache`` checks the quantizer invariants of
  a packed int8/int4 cache (integer code dtype, bits in range, finite
  strictly-positive scales, finite zero-points/epilogue columns) and
  raises ``CodeRangeError``.  Scale corruption is caught here even
  without a reference CRC; code bit-flips need the integrity guard
  (every int8 byte is a valid code — that is *why* pushes carry a CRC).

``GuardConfig`` bundles the knobs the training drivers and the
supervisor consume (see ``repro.resilience.faults.ResilienceContext``).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import PackedTensor

PyTree = Any


class GuardError(RuntimeError):
    """Base class for guard violations (typed, never a bare assert)."""


class IntegrityError(GuardError):
    """A packed payload's checksum does not match its content."""


class NonFiniteError(GuardError):
    """NaN/Inf found in params/updates that must be finite."""


class CodeRangeError(GuardError):
    """Packed int8/int4 cache violates the quantizer invariants."""


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Guard knobs threaded through the drivers and the supervisor.

    ``check_finite`` — finite-params check on the learner after update
    rounds; ``verify_pushes`` — CRC/repack verification of packed param
    pushes; ``validate_codes`` — structural cache validation alongside
    the push guard; ``check_every`` — host-sync cadence in driver rounds
    (1 = every round; raise it to amortize the host sync on very small
    nets); ``push_retries`` — bounded retries of a failed (corrupted)
    param push before the typed error escalates; ``backoff_base_s`` /
    ``backoff_factor`` / ``backoff_cap_s`` — exponential-backoff policy
    for those retries (deterministic jitter, see ``backoff_delay``).
    """

    check_finite: bool = True
    verify_pushes: bool = True
    validate_codes: bool = True
    check_every: int = 1
    push_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_factor: float = 2.0
    backoff_cap_s: float = 0.5


def deterministic_jitter(seed: int, attempt: int) -> float:
    """Jitter fraction in [0, 1) as a pure function of (seed, attempt).

    CRC32 over the pair's little-endian bytes — stable across runs and
    platforms, so a chaos run's retry timing is reproducible (no
    ``random`` module, no global state).
    """
    h = zlib.crc32(int(seed).to_bytes(8, "little", signed=True)
                   + int(attempt).to_bytes(8, "little", signed=True))
    return (h & 0xFFFFFFFF) / 2 ** 32


def backoff_delay(attempt: int, *, base_s: float, factor: float,
                  cap_s: float, seed: int = 0) -> float:
    """Exponential backoff with deterministic jitter, capped.

    ``base * factor**attempt * (1 + jitter)`` clipped to ``cap_s``;
    ``jitter`` comes from ``deterministic_jitter(seed, attempt)`` so two
    runs of the same fault plan sleep identically.
    """
    raw = base_s * (factor ** max(attempt, 0))
    return min(raw * (1.0 + deterministic_jitter(seed, attempt)), cap_s)


def tree_crc32(tree: PyTree) -> int:
    """CRC32 over every leaf's bytes + dtype/shape, in flatten order.

    The checksum that travels with a packed param push: any bit flip in
    the codes, scales, zero-points or epilogue columns — or a silent
    dtype/shape change — moves it.  Leaves are pulled to host
    (``np.asarray``); call off the hot path (pushes, hot-swaps).
    """
    crc = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        crc = zlib.crc32(str((arr.dtype.str, arr.shape)).encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), crc)
    return crc & 0xFFFFFFFF


def verify_crc(tree: PyTree, expected: int, *, what: str = "payload"
               ) -> None:
    """Raise ``IntegrityError`` unless ``tree_crc32(tree) == expected``."""
    got = tree_crc32(tree)
    if got != int(expected):
        raise IntegrityError(
            f"{what}: checksum mismatch — expected {int(expected):#010x}, "
            f"got {got:#010x} (corrupted packed payload; refusing to "
            f"serve/sync it)")


def _float_leaves(tree: PyTree) -> List[jnp.ndarray]:
    return [x for x in jax.tree_util.tree_leaves(tree)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]


def all_finite(tree: PyTree):
    """Jit-compatible scalar bool: every float leaf all-finite.

    Builds a single fused reduction over the float leaves — usable
    inside a jitted update (guard the gradient before applying it) or
    eagerly from the host driver.  Non-float leaves (int codes,
    counters) are skipped.
    """
    leaves = _float_leaves(tree)
    if not leaves:
        return jnp.asarray(True)
    ok = jnp.asarray(True)
    for x in leaves:
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(x)))
    return ok


def nonfinite_paths(tree: PyTree, limit: int = 8) -> List[str]:
    """Tree paths of leaves containing NaN/Inf (host-side diagnosis)."""
    bad = []
    paths_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in paths_leaves:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and \
                not np.all(np.isfinite(arr)):
            bad.append(jax.tree_util.keystr(path) or "<root>")
            if len(bad) >= limit:
                break
    return bad


def check_finite(tree: PyTree, *, what: str = "params") -> None:
    """Host-side finite guard: raise ``NonFiniteError`` naming leaves.

    The fast path is one fused ``all_finite`` reduction; the per-leaf
    diagnosis only runs on failure.
    """
    if bool(np.asarray(all_finite(tree))):
        return
    bad = nonfinite_paths(tree)
    raise NonFiniteError(
        f"{what}: non-finite values in {len(bad)} leaf/leaves "
        f"(NaN/Inf gradient or corrupted update): {', '.join(bad)}")


def _packed_leaves(tree: PyTree):
    return [x for x in jax.tree_util.tree_leaves(
        tree, is_leaf=lambda y: isinstance(y, PackedTensor))
        if isinstance(x, PackedTensor)]


def validate_cache(cache: PyTree, *, what: str = "actor cache") -> None:
    """Structural validation of a packed int8/int4 actor cache.

    Checks, per ``PackedTensor``: integer code dtype; ``bits`` in
    [1, 16]; finite strictly-positive quantizer scales (``delta``);
    finite zero-points and hoisted per-column epilogue arrays; packed
    int4 code payloads sized consistently with ``orig_shape``.  Float
    side-entries (biases, static activation scales) must be finite.
    Raises ``CodeRangeError`` with the first violation found.
    """
    packed = _packed_leaves(cache)
    for i, p in enumerate(packed):
        codes = np.asarray(p.codes)
        if not np.issubdtype(codes.dtype, np.integer):
            raise CodeRangeError(
                f"{what}: packed leaf {i} codes dtype {codes.dtype} is "
                f"not an integer type")
        if not 1 <= int(p.bits) <= 16:
            raise CodeRangeError(
                f"{what}: packed leaf {i} bits={p.bits} outside [1, 16]")
        if p.orig_shape is None and int(p.bits) < 16:
            lo, hi = -(2 ** (p.bits - 1)), 2 ** (p.bits - 1) - 1
            cmin, cmax = int(codes.min()), int(codes.max())
            if cmin < lo or cmax > hi:
                raise CodeRangeError(
                    f"{what}: packed leaf {i} codes [{cmin}, {cmax}] "
                    f"exceed the {p.bits}-bit range [{lo}, {hi}]")
        if p.orig_shape is not None:
            k = 1
            for d in p.orig_shape[:-1]:
                k *= d
            want = ((k + 1) // 2) * p.orig_shape[-1]
            if codes.size != want:
                raise CodeRangeError(
                    f"{what}: packed leaf {i} has {codes.size} packed "
                    f"bytes, orig_shape {p.orig_shape} needs {want}")
        for name, arr in (("delta", p.delta), ("zero_point", p.zero_point),
                          ("col_scale", p.col_scale),
                          ("col_zero", p.col_zero)):
            if arr is None:
                continue
            a = np.asarray(arr)
            if not np.all(np.isfinite(a)):
                raise CodeRangeError(
                    f"{what}: packed leaf {i} {name} contains NaN/Inf "
                    f"(corrupted quantizer scales)")
            if name == "delta" and not np.all(a > 0):
                raise CodeRangeError(
                    f"{what}: packed leaf {i} delta must be strictly "
                    f"positive, min={float(a.min())}")
    # non-packed float entries (biases, calibrated activation scales)
    rest = jax.tree_util.tree_map(
        lambda x: None if isinstance(x, PackedTensor) else x, cache,
        is_leaf=lambda x: isinstance(x, PackedTensor))
    bad = nonfinite_paths(rest)
    if bad:
        raise CodeRangeError(
            f"{what}: non-finite float entries outside the packed "
            f"weights: {', '.join(bad)}")


def retry_call(fn, *, retries: int, base_s: float, factor: float,
               cap_s: float, seed: int = 0, retry_on=Exception,
               on_retry=None, sleep=None):
    """Bounded retry with deterministic-jitter exponential backoff.

    Calls ``fn()`` up to ``retries + 1`` times; on a ``retry_on``
    exception sleeps ``backoff_delay(attempt, ...)`` and retries,
    invoking ``on_retry(attempt, exc)`` first (event logging).  The last
    failure is re-raised unchanged.  ``sleep`` is injectable for tests.
    """
    import time as _time
    do_sleep = _time.sleep if sleep is None else sleep
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            if attempt >= retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            do_sleep(backoff_delay(attempt, base_s=base_s, factor=factor,
                                   cap_s=cap_s, seed=seed))
            attempt += 1


def checksum_entry(cache: PyTree) -> int:
    """CRC for a cache about to be published (push-site convenience).

    Alias of ``tree_crc32`` named for the call sites — the value is what
    ``serving.CacheEntry.crc32`` and the async sync-push carry alongside
    the payload.
    """
    return tree_crc32(cache)


def verify_or_none(cache: PyTree, crc: Optional[int], *,
                   what: str) -> None:
    """``verify_crc`` that tolerates a missing checksum (older caches)."""
    if crc is not None:
        verify_crc(cache, crc, what=what)
