"""Deterministic, seeded fault injection for the ActorQ runtime.

A chaos run is specified as a ``FaultPlan`` — a seed plus a list of
``FaultSpec`` entries, each naming a fault kind, the driver round it
fires at, and kind-specific knobs.  Plans parse from a compact CLI
string (``launch/train.py --fault-plan``)::

    "7:nan_grad@3,bitflip_push@5:nbits=3,actor_crash@8:shard=1"

Fault kinds (``FAULT_KINDS``):

* ``actor_crash``   — an actor shard dies: raises ``ActorCrashError``
  at the start of the target round (params/replay for the round are
  lost; the supervisor resumes from the last checkpoint and records the
  shard as quarantined).
* ``straggler``     — a slow actor: sleeps ``delay_s`` at the start of
  the round.  The watchdog observes the stalled heartbeat.
* ``bitflip_push``  — flips ``nbits`` bits in the packed int8/int4
  payload of the next param push (async: the minted snapshot cache;
  actor-learner: the carried in-state cache; fused: the record-point
  eval cache).  The integrity guard's CRC catches it.
* ``nan_grad``      — poisons the learner params with NaN (or Inf with
  ``mode=inf``) after the target round's update, as if a non-finite
  gradient landed.  The finite guard catches it on the next check.
* ``dropped_sync``  — the next due param push never happens (async
  topology: the host-controlled push is skipped; the staleness metrics
  record the widened actor lag).  In the in-jit sync topologies the
  sync is compiled into the step, so the fault is recorded as
  not-applicable instead of fired.
* ``crash_commit``  — a crash mid-checkpoint-commit: the target step's
  committed ``leaves.msgpack`` is truncated after the save, simulating
  a torn write that the manifest checksum must reject on load.

Every fault is injected from the *host* driver between jitted chunks,
so the device-side computation of surviving rounds is untouched — this
is what makes recovery bitwise-reproducible (see docs/resilience.md).

``FaultInjector`` is the stateful consumer: it owns which entries have
fired (``repeat`` counts down) and is shared across supervisor retry
attempts so a fault does not re-fire after the restart that it caused.
``ResilienceContext`` bundles injector + guards into the single object
``loops.train(resilience=...)`` threads through the drivers; the loops
module stays free of resilience imports (duck-typed hooks).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.resilience import guards

PyTree = Any

FAULT_KINDS = ("actor_crash", "straggler", "bitflip_push", "nan_grad",
               "dropped_sync", "crash_commit")


class FaultError(RuntimeError):
    """Base class for errors raised by injected faults."""


class ActorCrashError(FaultError):
    """An actor shard crashed (injected or real)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: kind, target driver round, kind knobs.

    ``step`` is the 0-based driver round (outer iteration) the fault
    arms at; ``repeat`` is how many times it fires before exhausting
    (so an escalation-to-abort test can keep re-firing the same fault
    past the retry budget).  ``shard`` targets ``actor_crash``;
    ``delay_s`` is the ``straggler`` sleep; ``nbits`` the number of
    ``bitflip_push`` bit flips; ``mode`` picks NaN vs Inf poisoning
    for ``nan_grad``.
    """

    kind: str
    step: int
    shard: int = 0
    delay_s: float = 0.05
    nbits: int = 1
    mode: str = "nan"
    repeat: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.mode not in ("nan", "inf"):
            raise ValueError(f"nan_grad mode must be nan|inf, "
                             f"got {self.mode!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus the ordered fault entries of one chaos run."""

    seed: int
    faults: Tuple[FaultSpec, ...] = ()

    @staticmethod
    def parse(spec: str) -> "FaultPlan":
        """Parse ``SEED:kind@step[:key=val...][,entry...]``.

        Example: ``"7:nan_grad@3,bitflip_push@5:nbits=3"``.  Integer
        knobs parse as int, ``delay_s`` as float, ``mode`` as str.
        """
        head, _, rest = spec.partition(":")
        try:
            seed = int(head)
        except ValueError:
            raise ValueError(
                f"fault plan must start with 'SEED:', got {spec!r}")
        faults: List[FaultSpec] = []
        for entry in filter(None, rest.split(",")):
            parts = entry.split(":")
            kind, _, step_s = parts[0].partition("@")
            if not step_s:
                raise ValueError(
                    f"fault entry {entry!r} needs 'kind@step'")
            kw: Dict[str, Any] = {}
            for p in parts[1:]:
                k, _, v = p.partition("=")
                if k == "delay_s":
                    kw[k] = float(v)
                elif k == "mode":
                    kw[k] = v
                else:
                    kw[k] = int(v)
            faults.append(FaultSpec(kind=kind, step=int(step_s), **kw))
        return FaultPlan(seed=seed, faults=tuple(faults))

    def spec_string(self) -> str:
        """Inverse of ``parse`` (diagnostic reports round-trip plans)."""
        entries = []
        for f in self.faults:
            s = f"{f.kind}@{f.step}"
            defaults = FaultSpec(kind=f.kind, step=f.step)
            for field in ("shard", "delay_s", "nbits", "mode", "repeat"):
                v = getattr(f, field)
                if v != getattr(defaults, field):
                    s += f":{field}={v}"
            entries.append(s)
        return f"{self.seed}:{','.join(entries)}"


def bitflip_tree(tree: PyTree, seed: int, nbits: int = 1) -> PyTree:
    """Flip ``nbits`` random bits across a pytree's leaf payloads.

    The target (leaf, byte, bit) triples come from a ``numpy``
    Generator seeded with ``seed`` — the same plan corrupts the same
    bits every run.  Leaves are rewritten on host and rebuilt with
    their original dtypes/shapes; the tree structure (including
    ``PackedTensor`` nodes) is preserved.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host = [np.array(x) for x in leaves]
    sizes = np.array([h.nbytes for h in host], dtype=np.int64)
    total = int(sizes.sum())
    if total == 0:
        return tree
    rng = np.random.default_rng(seed)
    offsets = np.cumsum(sizes)
    for flat_bit in rng.integers(0, total * 8, size=max(nbits, 1)):
        byte, bit = divmod(int(flat_bit), 8)
        li = int(np.searchsorted(offsets, byte, side="right"))
        local = byte - (0 if li == 0 else int(offsets[li - 1]))
        buf = host[li].view(np.uint8).reshape(-1)
        buf[local] ^= np.uint8(1 << bit)
    rebuilt = [jax.numpy.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, rebuilt)


def poison_params(params: PyTree, mode: str = "nan") -> PyTree:
    """Overwrite the first float leaf's first element with NaN/Inf.

    Models a non-finite gradient having landed on the learner: one
    poisoned value is enough — it propagates through every subsequent
    update — while keeping the corruption minimal and inspectable.
    """
    bad = float("nan") if mode == "nan" else float("inf")
    done = [False]

    def one(x):
        arr = np.array(x)
        if not done[0] and np.issubdtype(arr.dtype, np.floating) \
                and arr.size:
            arr.reshape(-1)[0] = bad
            done[0] = True
            return jax.numpy.asarray(arr)
        return x

    return jax.tree_util.tree_map(one, params)


def truncate_file(path, keep_bytes: int = 7) -> None:
    """Truncate a file to ``keep_bytes`` — a torn write, post-commit."""
    import os
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
        f.flush()
        os.fsync(f.fileno())


class FaultInjector:
    """Stateful consumer of a ``FaultPlan``.

    Owns the per-entry remaining-fire counts.  SHARED across supervisor
    retry attempts: a fault that fired (and crashed the run) must not
    re-fire after the resume replays its round — the resumed round is
    the *recovery*, not a fresh target.  ``fired`` records every
    injection as ``(kind, step, detail)`` for the diagnostic report;
    ``not_applicable`` records faults that could not fire in the chosen
    topology (e.g. ``dropped_sync`` under in-jit syncs).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._remaining = [f.repeat for f in plan.faults]
        self.fired: List[Tuple[str, int, str]] = []
        self.not_applicable: List[Tuple[str, int, str]] = []

    def pending(self, kind: str, step: int) -> Optional[int]:
        """Index of an armed entry of ``kind`` due at ``step``, if any.

        An entry is due at the first opportunity with ``step >= f.step``
        — chunked drivers advance rounds by ``steps_per_call``, so exact
        equality would silently skip plans whose target falls inside a
        chunk.
        """
        for i, f in enumerate(self.plan.faults):
            if (f.kind == kind and step >= f.step
                    and self._remaining[i] > 0):
                return i
        return None

    def take(self, kind: str, step: int) -> Optional[FaultSpec]:
        """Consume one firing of an armed entry; None when not due."""
        i = self.pending(kind, step)
        if i is None:
            return None
        self._remaining[i] -= 1
        return self.plan.faults[i]

    def record_fired(self, kind: str, step: int, detail: str = "") -> None:
        """Log an injection that actually happened."""
        self.fired.append((kind, step, detail))

    def record_na(self, kind: str, step: int, why: str) -> None:
        """Log a planned fault that cannot apply in this topology."""
        self.not_applicable.append((kind, step, why))

    @property
    def injected_count(self) -> int:
        """Number of faults that actually fired (bench recovery gate)."""
        return len(self.fired)


class ResilienceContext:
    """The duck-typed hook object ``loops.train(resilience=...)`` takes.

    Bundles a ``FaultInjector`` (may be None for guards-only runs) with
    a ``GuardConfig`` and exposes the driver hooks:

    * ``round_start(step)``      — fires actor_crash / straggler.
    * ``after_round(state, step, learner_view=, repack=)`` — fires
      nan_grad (poisons the learner view via ``learner_view``/its
      default), fires bitflip_push against a carried in-state cache
      (via ``repack``, which rebuilds/verifies it), runs the finite
      guard at ``check_every`` cadence.  Returns the (possibly
      corrupted) state — corruption flows forward so the *guard*, not
      the injector, is what stops the run.
    * ``on_eval_cache(cache, step, remint)`` — fused-topology eval-path
      cache guard: bitflip_push target + validate/verify with bounded
      re-mint retries.
    * ``push(mint, step)``       — async-topology guarded param push:
      mints via ``mint()``, applies bitflip_push, verifies CRC +
      structure, retries by re-minting (bounded, deterministic-jitter
      backoff); returns None when dropped_sync consumed the push.
    * ``after_checkpoint(ckpt_dir, step)`` — fires crash_commit against
      the just-committed step dir.
    * ``heartbeat(phase, step)`` — watchdog liveness (supervisor owns
      the watchdog; standalone contexts accept and drop beats).

    All hooks are host-side and no-ops when neither a fault is due nor
    a guard is enabled, so an un-faulted guarded run differs from a
    bare run only by the guard reductions (benched < 5% overhead).
    """

    def __init__(self, injector: Optional[FaultInjector] = None,
                 guard: Optional[guards.GuardConfig] = None,
                 on_heartbeat: Optional[Callable[[str, int], None]] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.injector = injector
        self.guard = guards.GuardConfig() if guard is None else guard
        self._on_heartbeat = on_heartbeat
        self._sleep = sleep
        self.quarantined: List[int] = []
        self.events: List[Tuple[str, int, str]] = []

    # -- bookkeeping ----------------------------------------------------
    def _log(self, what: str, step: int, detail: str = "") -> None:
        self.events.append((what, step, detail))

    def heartbeat(self, phase: str, step: int) -> None:
        """Report liveness to the supervisor's watchdog (if attached)."""
        if self._on_heartbeat is not None:
            self._on_heartbeat(phase, step)

    @property
    def seed(self) -> int:
        """Plan seed (0 for guards-only contexts) — keys the jitter."""
        return self.injector.plan.seed if self.injector else 0

    def _take(self, kind: str, step: int) -> Optional[FaultSpec]:
        if self.injector is None:
            return None
        return self.injector.take(kind, step)

    # -- driver hooks ---------------------------------------------------
    def round_start(self, step: int) -> None:
        """Start-of-round hook: actor_crash and straggler fire here."""
        self.heartbeat("round", step)
        f = self._take("straggler", step)
        if f is not None:
            self.injector.record_fired("straggler", step,
                                       f"delay_s={f.delay_s}")
            self._log("straggler", step, f"slept {f.delay_s}s")
            self._sleep(f.delay_s)
        f = self._take("actor_crash", step)
        if f is not None:
            self.injector.record_fired("actor_crash", step,
                                       f"shard={f.shard}")
            if f.shard not in self.quarantined:
                self.quarantined.append(f.shard)
            raise ActorCrashError(
                f"actor shard {f.shard} crashed at round {step} "
                f"(injected)")

    def after_round(self, state, step: int, *, learner_view=None,
                    set_learner=None, repack=None):
        """Post-update hook: nan_grad / bitflip_push + finite guard.

        ``learner_view(state)`` extracts the learner params pytree;
        ``set_learner(state, params)`` writes a modified one back
        (both default to identity for plain learner-state objects).
        ``repack`` is ``(state, corrupt_fn) -> state`` for topologies
        that carry a packed actor cache inside the jitted state.
        """
        f = self._take("nan_grad", step)
        if f is not None:
            self.injector.record_fired("nan_grad", step, f.mode)
            self._log("nan_grad", step, f"poisoned ({f.mode})")
            view = state if learner_view is None else learner_view(state)
            poisoned = poison_params(view, f.mode)
            state = poisoned if set_learner is None \
                else set_learner(state, poisoned)
        if repack is not None:
            # only consume the entry when this topology carries an
            # in-state cache target; otherwise the push/eval-cache hook
            # downstream owns the fault
            f = self._take("bitflip_push", step)
            if f is not None:
                self.injector.record_fired(
                    "bitflip_push", step,
                    f"nbits={f.nbits} (in-state cache)")
                self._log("bitflip_push", step, "corrupted in-state cache")
                state = repack(state, lambda c: bitflip_tree(
                    c, self.seed + step, f.nbits))
        if (self.guard.check_finite
                and step % max(self.guard.check_every, 1) == 0):
            view = state if learner_view is None else learner_view(state)
            guards.check_finite(view, what=f"learner params @round {step}")
        return state

    def verify_state_cache(self, cache, reference_mint, step: int) -> None:
        """Verify a carried in-state cache against a fresh repack.

        Used by the bulk-synchronous actor-learner topology where the
        cache lives inside jitted state (no CRC travels with it): the
        reference is re-minted from the fp32 source params and compared
        by checksum.  Only sound when minting is deterministic
        (``calib_batch == 0``); callers gate on that.
        """
        if not self.guard.verify_pushes:
            return
        ref = reference_mint()
        guards.verify_crc(cache, guards.tree_crc32(ref),
                          what=f"in-state actor cache @round {step}")
        if self.guard.validate_codes:
            guards.validate_cache(cache,
                                  what=f"in-state actor cache @round {step}")

    def on_eval_cache(self, cache, step: int, remint):
        """Guard (and possibly corrupt) a freshly minted eval cache.

        ``remint()`` rebuilds the cache from the fp32 params — both the
        bitflip repair path and the verification reference.  Returns
        the cache to use.
        """
        f = self._take("bitflip_push", step)
        if f is not None:
            self.injector.record_fired("bitflip_push", step,
                                       f"nbits={f.nbits} (eval cache)")
            self._log("bitflip_push", step, "corrupted eval cache")
            cache = bitflip_tree(cache, self.seed + step, f.nbits)
        if not self.guard.verify_pushes:
            return cache

        attempt = [0]

        def check_or_remint():
            if attempt[0] > 0:
                c = remint()
                self._log("push_retry", step,
                          f"re-minted eval cache (attempt {attempt[0]})")
            else:
                c = cache
            attempt[0] += 1
            guards.verify_crc(c, guards.tree_crc32(remint()),
                              what=f"eval cache @round {step}")
            if self.guard.validate_codes:
                guards.validate_cache(c, what=f"eval cache @round {step}")
            return c

        return guards.retry_call(
            check_or_remint, retries=self.guard.push_retries,
            base_s=self.guard.backoff_base_s,
            factor=self.guard.backoff_factor,
            cap_s=self.guard.backoff_cap_s, seed=self.seed + step,
            retry_on=guards.GuardError, sleep=self._sleep)

    def sync_due(self, step: int) -> bool:
        """Consume a due dropped_sync; False = skip this push entirely.

        The async driver asks *before* swapping replay slots, so a
        dropped sync drops the whole exchange — the realized actor lag
        widens until the next cadence point, which is exactly the
        staleness signature the metrics should show.
        """
        f = self._take("dropped_sync", step)
        if f is not None:
            self.injector.record_fired("dropped_sync", step)
            self._log("dropped_sync", step, "push skipped")
            return False
        return True

    def push(self, mint, step: int):
        """Guarded async param push: mint → corrupt? → verify → retry.

        ``mint()`` produces the snapshot payload.  A due dropped_sync
        consumes the push and returns None (the driver skips the sync
        bookkeeping — staleness metrics then show the widened lag).  A
        due bitflip_push corrupts the payload once; verification
        re-mints with bounded backoff, so a transient corruption costs
        one retry while a persistent one escalates its typed error.
        """
        self.heartbeat("push", step)
        if self._take("dropped_sync", step) is not None:
            self.injector.record_fired("dropped_sync", step)
            self._log("dropped_sync", step, "push skipped")
            return None
        f = self._take("bitflip_push", step)
        corrupt_once = [f]

        def mint_verify():
            snap = mint()
            fs = corrupt_once[0]
            if fs is not None:
                corrupt_once[0] = None
                self.injector.record_fired(
                    "bitflip_push", step, f"nbits={fs.nbits} (push)")
                self._log("bitflip_push", step, "corrupted push payload")
                snap = bitflip_tree(snap, self.seed + step, fs.nbits)
            if self.guard.verify_pushes:
                ref_crc = guards.tree_crc32(mint())
                guards.verify_crc(snap, ref_crc,
                                  what=f"param push @update {step}")
                if self.guard.validate_codes:
                    guards.validate_cache(
                        snap, what=f"param push @update {step}")
            return snap

        def on_retry(attempt, exc):
            self._log("push_retry", step, f"{type(exc).__name__}: {exc}")

        return guards.retry_call(
            mint_verify, retries=self.guard.push_retries,
            base_s=self.guard.backoff_base_s,
            factor=self.guard.backoff_factor,
            cap_s=self.guard.backoff_cap_s, seed=self.seed + step,
            retry_on=guards.GuardError, on_retry=on_retry,
            sleep=self._sleep)

    def dropped_sync_na(self, step: int, topology: str) -> None:
        """Record a dropped_sync that cannot fire (in-jit sync)."""
        f = self._take("dropped_sync", step)
        if f is not None:
            self.injector.record_na(
                "dropped_sync", step,
                f"sync is compiled into the {topology} step; cannot be "
                f"dropped from the host")

    def checkpoint_committed(self, ckptr, step: int) -> None:
        """Driver hook after ``ckptr.save_async(step, ...)``.

        Cheap when no crash_commit is armed (one pending check, no
        barrier); when one is due it drains the async writer so the
        commit exists on disk, then tears it via ``after_checkpoint`` —
        the crash lands *after* the rename, which is the case the
        manifest checksum (not the commit protocol) must catch.
        """
        self.heartbeat("checkpoint", step)
        if (self.injector is None
                or self.injector.pending("crash_commit", step) is None):
            return
        ckptr.wait()
        self.after_checkpoint(ckptr.manager.step_path(step), step)

    def after_checkpoint(self, ckpt_path, step: int) -> None:
        """Post-commit hook: crash_commit tears the just-saved step."""
        self.heartbeat("checkpoint", step)
        if ckpt_path is None:
            return
        f = self._take("crash_commit", step)
        if f is None:
            return
        import os
        leaves = os.path.join(ckpt_path, "leaves.msgpack")
        if os.path.exists(leaves):
            truncate_file(leaves)
            self.injector.record_fired("crash_commit", step,
                                       str(ckpt_path))
            self._log("crash_commit", step, f"truncated {leaves}")

    def serving_fault_hook(self):
        """Batch-dispatch hook for ``PolicyServer(fault_hook=...)``.

        Returns a callable fired per dispatched batch; an armed
        ``actor_crash`` raises (the server's worker auto-restart
        handles it), a ``straggler`` sleeps.  Steps here count
        dispatched batches, tracked internally.
        """
        count = [0]

        def hook(batch):
            step = count[0]
            count[0] += 1
            f = self._take("straggler", step)
            if f is not None:
                self.injector.record_fired("straggler", step,
                                           f"serving delay {f.delay_s}s")
                self._sleep(f.delay_s)
            f = self._take("actor_crash", step)
            if f is not None:
                self.injector.record_fired("actor_crash", step,
                                           "serving worker")
                raise ActorCrashError(
                    f"serving worker crashed at batch {step} (injected)")

        return hook
