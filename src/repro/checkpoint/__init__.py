"""Fault-tolerant checkpointing: durable atomic saves, manifest-validated
loads, retention/GC, and an async background writer (see
``docs/checkpointing.md``)."""
from repro.checkpoint.ckpt import (latest_step, load_checkpoint,
                                   save_checkpoint, sweep_orphans)
from repro.checkpoint.manager import AsyncCheckpointer, CheckpointManager

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "sweep_orphans", "CheckpointManager", "AsyncCheckpointer"]
