"""Manifest-based checkpoint manager and the async background writer.

``CheckpointManager`` is the durable directory-per-step format RL training
checkpoints use::

    <dir>/ckpt_00000040/leaves.msgpack   flattened pytree (ckpt.py encoding)
    <dir>/ckpt_00000040/manifest.json    format tag, step, per-leaf
                                         shape/dtype, caller "extra" dict

Commit protocol (the levanter/orbax async-commit idiom): stage everything
into ``ckpt_N.tmp-<uuid>/``, fsync data + manifest + the staging dir,
``os.replace`` onto the final name, fsync the parent.  The rename is the
commit point — a crash at ANY earlier instant leaves previously committed
steps untouched and at worst tmp debris behind, which ``sweep_orphans``
reclaims on the next save.  Loads validate every leaf against the
manifest + caller template (``ValueError`` with per-leaf detail) instead
of trusting shapes.

``AsyncCheckpointer`` puts the commit on a single daemon writer thread so
a training loop never blocks on serialization or disk: the device->host
copy happens on the *caller's* thread (mandatory under buffer donation —
the next dispatched chunk invalidates the arrays being saved), everything
after that is background.  Saves commit in submission order; writer
failures are captured and re-raised on the next ``save_async``/``wait``.

Single-writer discipline: one process (one writer thread) owns a given
checkpoint directory at a time.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import uuid
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib

FORMAT = ckpt_lib.FORMAT
DATA_FILE = "leaves.msgpack"
MANIFEST_FILE = "manifest.json"


class CheckpointManager:
    """Synchronous durable checkpoints: manifest, retention, validation.

    ``keep`` bounds retention: after each commit, all but the newest
    ``keep`` steps are deleted (``keep <= 0`` keeps everything).
    """

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = int(keep)
        os.makedirs(directory, exist_ok=True)

    def step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{int(step):08d}")

    def steps(self) -> List[int]:
        """Committed steps (manifest present), ascending."""
        out = []
        for name in os.listdir(self.directory):
            m = ckpt_lib._DIR_RE.match(name)
            if m and os.path.isfile(os.path.join(
                    self.directory, name, MANIFEST_FILE)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        """Newest *valid* committed step (see ``step_valid``), or None.

        Skips torn/corrupted steps — a crash mid-commit (or post-commit
        media corruption caught by the manifest checksum) falls back to
        the newest step that still verifies, which is what resume wants.
        """
        for s in reversed(self.steps()):
            if self.step_valid(s):
                return s
        return None

    def step_valid(self, step: int) -> bool:
        """Whole-file validity check of one committed step (CRC-backed)."""
        return ckpt_lib.step_dir_valid(self.step_path(step))

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Host-copy ``tree``'s leaves and commit; returns the step path."""
        host = [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]
        return self.commit_hosted(step, host, extra=extra)

    def commit_hosted(self, step: int, host_leaves: List[np.ndarray],
                      extra: Optional[Dict[str, Any]] = None) -> str:
        """Commit already-host-resident leaves (the async writer's path).

        No cleanup on failure by design: a failed commit is
        indistinguishable from a crash, and both leave only staging
        debris that the post-commit ``sweep_orphans`` of the *next*
        successful save reclaims.
        """
        payload = msgpack.packb([ckpt_lib._encode_leaf(a)
                                 for a in host_leaves])
        manifest = {
            "format": FORMAT,
            "step": int(step),
            "leaf_count": len(host_leaves),
            "leaves": [{"shape": list(a.shape),
                        "dtype": ckpt_lib.dtype_str(a.dtype)}
                       for a in host_leaves],
            # whole-file checksum of leaves.msgpack: restore() and
            # step_dir_valid() reject torn/corrupted payloads by name
            # instead of deserializing garbage
            "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
            "extra": {} if extra is None else extra,
        }
        tmp = self.step_path(step) + ".tmp-" + uuid.uuid4().hex[:8]
        os.makedirs(tmp)
        for name, data in ((DATA_FILE, payload),
                           (MANIFEST_FILE,
                            json.dumps(manifest, sort_keys=True).encode())):
            with open(os.path.join(tmp, name), "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
        ckpt_lib.fsync_dir(tmp)
        final = self.step_path(step)
        if os.path.isdir(final):        # re-save of an existing step
            shutil.rmtree(final)
        os.replace(tmp, final)          # <- the commit point
        ckpt_lib.fsync_dir(self.directory)
        self._gc()
        self.sweep_orphans()
        return final

    def manifest(self, step: int) -> Dict[str, Any]:
        path = os.path.join(self.step_path(step), MANIFEST_FILE)
        with open(path, "r", encoding="utf-8") as f:
            m = json.load(f)
        if m.get("format") != FORMAT:
            raise ValueError(f"{path}: unknown checkpoint format "
                             f"{m.get('format')!r} (want {FORMAT!r})")
        return m

    def restore(self, step: int, template: Any
                ) -> Tuple[Any, Dict[str, Any]]:
        """Validated load of ``step``; returns ``(tree, extra)``.

        Raises ``ValueError`` with per-leaf path detail on any
        shape/dtype mismatch against ``template`` (manifest-first, so a
        mismatch is diagnosed without decoding the data payload).
        """
        m = self.manifest(step)
        source = self.step_path(step)
        specs = [(tuple(s["shape"]), s["dtype"]) for s in m["leaves"]]
        ckpt_lib.validate_leaves(specs, template, source=source)
        with open(os.path.join(source, DATA_FILE), "rb") as f:
            data = f.read()
        want = m.get("crc32")
        if want is not None:
            got = zlib.crc32(data) & 0xFFFFFFFF
            if got != int(want):
                raise ValueError(
                    f"{source}/{DATA_FILE}: checksum mismatch — manifest "
                    f"crc32={int(want):#010x}, file={got:#010x} (torn or "
                    f"corrupted checkpoint; refusing to deserialize)")
        raw = msgpack.unpackb(data)
        if len(raw) != m["leaf_count"]:
            raise ValueError(
                f"{source}: data payload has {len(raw)} leaves but the "
                f"manifest commits {m['leaf_count']} — torn checkpoint")
        leaves = [ckpt_lib._decode_leaf(d) for d in raw]
        return ckpt_lib._redevice(leaves, template), m.get("extra", {})

    def sweep_orphans(self) -> List[str]:
        return ckpt_lib.sweep_orphans(self.directory)

    def _gc(self) -> None:
        if self.keep <= 0:
            return
        stale = self.steps()[:-self.keep]
        for s in stale:
            shutil.rmtree(self.step_path(s), ignore_errors=True)
        if stale:
            ckpt_lib.fsync_dir(self.directory)


class AsyncCheckpointer:
    """Background checkpoint writer — never blocks the jit'd learner step.

    ``save_async`` synchronously copies the tree's leaves to host (on the
    caller's thread, before the next donated dispatch can invalidate
    them), then queues the encode+fsync+rename commit to a daemon writer
    thread and returns.  ``wait()`` drains the queue; the commit point of
    save k is the rename, observed via ``last_committed_step()``.
    """

    def __init__(self, directory: str, *, keep: int = 3,
                 manager: Optional[CheckpointManager] = None):
        self.manager = manager if manager is not None else \
            CheckpointManager(directory, keep=keep)
        # reclaim debris a crashed predecessor left in this directory
        self.manager.sweep_orphans()
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._last_committed = self.manager.latest_step()
        self._closed = False
        self._thread = threading.Thread(target=self._worker,
                                        name="ckpt-writer", daemon=True)
        self._thread.start()

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot ``tree`` to host and queue its commit; returns fast.

        ``extra`` must be JSON-serializable; it is deep-copied here so
        the caller may keep mutating the original (e.g. appending to a
        live metrics list) while the writer serializes.
        """
        self._reraise()
        if self._closed:
            raise RuntimeError("AsyncCheckpointer is closed")
        # np.array, not np.asarray: asarray is ZERO-copy for numpy and
        # CPU-jax leaves, and an aliased buffer the caller then donates
        # (or mutates) would tear under the writer thread's encode
        host = [np.array(x) for x in jax.tree_util.tree_leaves(tree)]
        extra = None if extra is None else json.loads(json.dumps(extra))
        self._q.put((int(step), host, extra))

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, host, extra = item
                try:
                    self.manager.commit_hosted(step, host, extra=extra)
                    with self._lock:
                        self._last_committed = step
                except BaseException as e:  # stored, re-raised to caller
                    with self._lock:
                        self._error = e
            finally:
                self._q.task_done()

    def wait(self) -> Optional[int]:
        """Block until every queued save committed; re-raise any writer
        failure; return ``last_committed_step()``."""
        self._q.join()
        self._reraise()
        return self.last_committed_step()

    def last_committed_step(self) -> Optional[int]:
        with self._lock:
            return self._last_committed

    def latest_step(self) -> Optional[int]:
        return self.manager.latest_step()

    def restore(self, step: int, template: Any
                ) -> Tuple[Any, Dict[str, Any]]:
        """Drain pending saves (they may supersede disk state), then
        ``CheckpointManager.restore``."""
        self.wait()
        return self.manager.restore(step, template)

    def close(self) -> None:
        """Drain the queue and stop the writer thread (idempotent)."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
        self._thread.join()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _reraise(self) -> None:
        with self._lock:
            e, self._error = self._error, None
        if e is not None:
            raise RuntimeError("async checkpoint write failed") from e
