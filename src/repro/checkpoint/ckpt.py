"""Msgpack-based pytree checkpointing (no flax/orbax offline).

Handles: plain arrays, scalars, nested dict/list/tuple/NamedTuple-like
pytrees, the quantized containers (PackedTensor, BlockQuantized,
ObserverState) — everything is flattened with jax.tree_util and the treedef
reconstructed by the caller providing a matching "template" pytree, which
sidesteps pickling treedefs.

Durability: writes stage into a ``ckpt-tmp-*`` file in the target
directory, fsync the file, ``os.replace`` onto the final name, then fsync
the directory — the rename is the commit point, so a crash at any earlier
instant leaves prior checkpoints untouched and at worst some tmp debris
behind (reclaimed by ``sweep_orphans``).  Loads validate every leaf's
shape and dtype against the caller's template and raise ``ValueError``
with per-leaf detail — a real exception, not an ``assert``, so the check
survives ``python -O``.

Quantized checkpoints: saving a ``ptq_pack``'d params tree stores int8 codes
directly — the on-disk artifact gets the paper's ~4x size reduction too
(round-trip coverage in ``tests/test_checkpoint.py``).

This module is the single-file layer; ``repro.checkpoint.manager`` builds
the manifest-based directory-per-step format and the async writer on top.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zlib
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

# manifest format tag shared with the manager layer (which imports it)
FORMAT = "repro-ckpt-v1"

# staging-name patterns owned by this subsystem; sweep_orphans removes
# matching debris, tolerant parsers skip it
TMP_PREFIX = "ckpt-tmp-"                       # file saves (this module)
_FILE_RE = re.compile(r"^ckpt_(\d+)\.msgpack$")
_DIR_RE = re.compile(r"^ckpt_(\d+)$")
_TMP_DIR_RE = re.compile(r"^ckpt_\d+\.tmp-")   # manager staging dirs


def dtype_str(dt) -> str:
    """Round-trippable dtype spelling (``'<f4'``; the *name* for extension
    dtypes like bfloat16 whose ``.str`` collapses to raw void bytes)."""
    dt = np.dtype(dt)
    return dt.name if "V" in dt.str else dt.str


def _encode_leaf(x):
    arr = np.asarray(x)
    return {b"dtype": dtype_str(arr.dtype).encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _decode_leaf(d):
    # copy into a bytearray first: np.frombuffer over the msgpack bytes
    # object is a READ-ONLY view, which blows up the moment a resumed
    # leaf is donated to a jit or updated in place
    return np.frombuffer(bytearray(d[b"data"]),
                         dtype=np.dtype(d[b"dtype"].decode())
                         ).reshape(d[b"shape"])


def _encoded_spec(d) -> Tuple[Tuple[int, ...], str]:
    return tuple(d[b"shape"]), d[b"dtype"].decode()


def leaf_spec(x) -> Tuple[Tuple[int, ...], str]:
    """``(shape, dtype_str)`` of a template leaf without device transfer."""
    dt = getattr(x, "dtype", None)
    if dt is None:                      # python scalar leaf
        arr = np.asarray(x)
        return tuple(arr.shape), dtype_str(arr.dtype)
    return tuple(x.shape), dtype_str(dt)


def validate_leaves(specs: Sequence[Tuple[Tuple[int, ...], str]],
                    template: PyTree, *, source: str) -> None:
    """Check per-leaf ``(shape, dtype)`` specs against ``template``.

    Raises ``ValueError`` naming every mismatched leaf by its tree path —
    a count-only check would let a same-count wrong-shape template
    silently reshape garbage.
    """
    paths_leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    if len(specs) != len(paths_leaves):
        raise ValueError(
            f"{source}: leaf count mismatch — checkpoint has "
            f"{len(specs)} leaves, template has {len(paths_leaves)}")
    bad = []
    for (path, t), (shape, dt) in zip(paths_leaves, specs):
        want_shape, want_dt = leaf_spec(t)
        if tuple(shape) != want_shape or dt != want_dt:
            bad.append(f"  {jax.tree_util.keystr(path) or '<root>'}: "
                       f"checkpoint {tuple(shape)}/{dt} vs template "
                       f"{want_shape}/{want_dt}")
    if bad:
        raise ValueError(
            f"{source}: {len(bad)} leaf mismatch(es) against template "
            f"(wrong net_kwargs / algo config?):\n" + "\n".join(bad))


def _redevice(leaves: List[np.ndarray], template: PyTree) -> PyTree:
    """Unflatten host leaves into ``template``'s structure; jax-array
    template leaves come back on device, everything else stays (writeable)
    numpy."""
    treedef = jax.tree_util.tree_structure(template)
    t_leaves = jax.tree_util.tree_leaves(template)
    out = [jnp.asarray(leaf) if isinstance(t, jax.Array) else
           np.asarray(leaf) for leaf, t in zip(leaves, t_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def fsync_dir(path: str) -> None:
    """fsync a directory so renames inside it survive power loss."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(path: str, tree: PyTree, step: Optional[int] = None
                    ) -> str:
    """Durably save pytree leaves; returns the final path.

    With ``step`` the file is ``<path>/ckpt_{step:08d}.msgpack`` and a
    successful commit also sweeps tmp debris left by earlier crashed
    saves in that directory.  The ``os.replace`` is the commit point
    (fsync'd file, then fsync'd directory).
    """
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    leaves = jax.tree_util.tree_leaves(tree)
    payload = msgpack.packb([_encode_leaf(x) for x in leaves])
    fd, tmp = tempfile.mkstemp(dir=d, prefix=TMP_PREFIX)
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(d)
    if step is not None:
        sweep_orphans(d)
    return path


def load_checkpoint(path: str, template: PyTree, step: Optional[int] = None
                    ) -> PyTree:
    """Load into the structure of ``template``.

    Every leaf's shape and dtype is validated against ``template`` before
    any data is materialized; mismatches raise ``ValueError`` with
    per-leaf path detail (see ``validate_leaves``).  Loaded numpy leaves
    are writeable copies, safe to mutate or donate.
    """
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    with open(path, "rb") as f:
        raw = msgpack.unpackb(f.read())
    validate_leaves([_encoded_spec(d) for d in raw], template, source=path)
    return _redevice([_decode_leaf(d) for d in raw], template)


def step_dir_valid(path: str) -> bool:
    """Is a manager-format step directory loadable?

    Checks (without decoding the payload): the manifest parses as JSON
    and carries the right format tag; ``leaves.msgpack`` exists; and —
    when the manifest records a ``crc32`` — the whole-file checksum of
    the data payload matches.  A torn or corrupted step reports invalid
    (False) instead of raising, so resume paths can skip it and fall
    back to the newest valid one.
    """
    try:
        with open(os.path.join(path, "manifest.json"), "r",
                  encoding="utf-8") as f:
            m = json.load(f)
    except (OSError, ValueError):
        return False
    if m.get("format") != FORMAT:
        return False
    data = os.path.join(path, "leaves.msgpack")
    if not os.path.isfile(data):
        return False
    crc = m.get("crc32")
    if crc is None:        # pre-CRC checkpoint: trust the commit rename
        return True
    try:
        with open(data, "rb") as f:
            payload = f.read()
    except OSError:
        return False
    return (zlib.crc32(payload) & 0xFFFFFFFF) == int(crc)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed *valid* step in ``ckpt_dir``, or None.

    Recognizes both the single-file format (``ckpt_N.msgpack``) and the
    manager's directory format (``ckpt_N/`` with a committed manifest).
    Tolerant: stray ``ckpt_*`` entries that don't parse as a step are
    skipped, never fatal; directory-format steps that fail
    ``step_dir_valid`` (torn payload, checksum mismatch) are skipped
    too, so the newest *valid* step wins.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        m = _FILE_RE.match(name)
        if m and os.path.isfile(full):
            steps.append(int(m.group(1)))
            continue
        m = _DIR_RE.match(name)
        if m and step_dir_valid(full):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def sweep_orphans(ckpt_dir: str) -> List[str]:
    """Remove tmp debris from crashed or failed saves; returns the names
    removed.

    Only this subsystem's own staging patterns are touched
    (``ckpt-tmp-*`` files from ``save_checkpoint``, ``ckpt_N.tmp-*``
    staging dirs from ``CheckpointManager``).  Safe under the
    single-writer discipline the subsystem assumes: a sweep runs on the
    writer's own thread only after its staging entry has been renamed
    away, so it can only ever see dead debris.
    """
    removed: List[str] = []
    if not os.path.isdir(ckpt_dir):
        return removed
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.startswith(TMP_PREFIX) and os.path.isfile(full):
            os.unlink(full)
            removed.append(name)
        elif _TMP_DIR_RE.match(name) and os.path.isdir(full):
            shutil.rmtree(full)
            removed.append(name)
    return removed
