"""Msgpack-based pytree checkpointing (no flax/orbax offline).

Handles: plain arrays, scalars, nested dict/list/tuple/NamedTuple-like
pytrees, the quantized containers (PackedTensor, BlockQuantized,
ObserverState) — everything is flattened with jax.tree_util and the treedef
reconstructed by the caller providing a matching "template" pytree, which
sidesteps pickling treedefs. Writes are atomic (tmp + rename).

Quantized checkpoints: saving a ``ptq_pack``'d params tree stores int8 codes
directly — the on-disk artifact gets the paper's ~4x size reduction too.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _encode_leaf(x):
    arr = np.asarray(x)
    return {b"dtype": arr.dtype.str.encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _decode_leaf(d):
    return np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode())
                         ).reshape(d[b"shape"])


def save_checkpoint(path: str, tree: PyTree, step: Optional[int] = None
                    ) -> str:
    """Save pytree leaves; returns the final path."""
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves = jax.tree_util.tree_leaves(tree)
    payload = msgpack.packb([_encode_leaf(x) for x in leaves])
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)
    return path


def load_checkpoint(path: str, template: PyTree, step: Optional[int] = None
                    ) -> PyTree:
    """Load into the structure of ``template`` (shapes/dtypes must match)."""
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    with open(path, "rb") as f:
        raw = msgpack.unpackb(f.read())
    leaves = [_decode_leaf(d) for d in raw]
    treedef = jax.tree_util.tree_structure(template)
    assert treedef.num_leaves == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, template {treedef.num_leaves}"
    t_leaves = jax.tree_util.tree_leaves(template)
    out = [jnp.asarray(leaf).astype(t.dtype) if hasattr(t, "dtype")
           else np.asarray(leaf)
           for leaf, t in zip(leaves, t_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f[len("ckpt_"):-len(".msgpack")])
             for f in os.listdir(ckpt_dir)
             if f.startswith("ckpt_") and f.endswith(".msgpack")]
    return max(steps) if steps else None
