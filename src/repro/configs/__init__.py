"""Architecture configs (one module per assigned architecture)."""
from repro.configs import base
from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, get, get_reduced, names

__all__ = ["base", "ArchConfig", "InputShape", "INPUT_SHAPES", "get",
           "get_reduced", "names"]
