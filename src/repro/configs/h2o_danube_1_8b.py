"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L, d_model 2560, 32 heads (GQA kv=8), d_ff 6912,
vocab 32000, Mistral-style SWA (window 4096 at this scale).
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN_LOCAL

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense", source="arXiv:2401.16818",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=6912,
    vocab=32000, pattern=(ATTN_LOCAL,), window=4096,
    sharding="tp", supports_long_500k=True,  # SWA caps the decode cache
)

REDUCED = ArchConfig(
    name="h2o-danube-1.8b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, pattern=(ATTN_LOCAL,), window=32, sharding="tp",
)

base.register(CONFIG, REDUCED)
