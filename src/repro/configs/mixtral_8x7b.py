"""mixtral-8x7b — sparse MoE (8 experts, top-2) with sliding-window attention.

[arXiv:2401.04088] 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 32000, SWA window 4096.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, MOE_LOCAL

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", source="arXiv:2401.04088",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, pattern=(MOE_LOCAL,), window=4096,
    n_experts=8, moe_top_k=2, sharding="fsdp", supports_long_500k=True,
    grad_accum=2,  # memory-term fit (EXPERIMENTS.md §Perf)
)

REDUCED = ArchConfig(
    name="mixtral-8x7b-reduced", family="moe", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, pattern=(MOE_LOCAL,), window=32, n_experts=4, moe_top_k=2,
    sharding="fsdp",
)

base.register(CONFIG, REDUCED)
