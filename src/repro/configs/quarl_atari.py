"""The paper's own policy architectures (QuaRL Appendix B/C).

These are RL policy networks, not LM architectures — they are consumed by
repro.rl (networks.py) and the mixed-precision case study:

  Atari DQN backbone: 3-layer conv (128 filters) + FC 128 (Appendix B).
  Policy A: 3 conv x 128 + FC 128     (Table 10)
  Policy B: 3 conv x 512 + FC 512
  Policy C: 3 conv x 1024 + FC 2048
  Deployment policies (Table 5): 3-layer MLPs 64 / 256 / (4096,512,1024).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ConvPolicyConfig:
    name: str
    conv_filters: Tuple[int, ...]
    fc_width: int


@dataclasses.dataclass(frozen=True)
class MLPPolicyConfig:
    name: str
    widths: Tuple[int, ...]


ATARI_DQN = ConvPolicyConfig("atari_dqn", (128, 128, 128), 128)
POLICY_A = ConvPolicyConfig("policy_a", (128, 128, 128), 128)
POLICY_B = ConvPolicyConfig("policy_b", (512, 512, 512), 512)
POLICY_C = ConvPolicyConfig("policy_c", (1024, 1024, 1024), 2048)

DEPLOY_POLICY_I = MLPPolicyConfig("policy_i", (64, 64, 64))
DEPLOY_POLICY_II = MLPPolicyConfig("policy_ii", (256, 256, 256))
DEPLOY_POLICY_III = MLPPolicyConfig("policy_iii", (4096, 512, 1024))
