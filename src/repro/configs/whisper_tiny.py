"""whisper-tiny — encoder-decoder audio transformer (conv frontend STUB).

[arXiv:2212.04356] 4L enc + 4L dec, d_model 384, 6 heads, d_ff 1536,
vocab 51865. The mel+conv frontend is stubbed: input_specs() provides
precomputed frame embeddings (B, 1500, 384); the transformer encoder runs
over them and the decoder cross-attends (per the assignment carve-out).
LayerNorm + GeLU per the original. vocab 51865 is not divisible by the
model axis -> vocab stays replicated (see partition_specs).
"""
from repro.configs import base
from repro.configs.base import ArchConfig, CROSS

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", source="arXiv:2212.04356",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, pattern=(CROSS,), norm="layer", activation="gelu",
    encoder_layers=4, encoder_seq=1500, cross_attn=True, rope_theta=10000.0,
    sharding="tp", supports_long_500k=False,  # full-attn decoder
)

REDUCED = ArchConfig(
    name="whisper-tiny-reduced", family="audio", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, pattern=(CROSS,), norm="layer", activation="gelu",
    encoder_layers=2, encoder_seq=16, cross_attn=True, sharding="tp",
)

base.register(CONFIG, REDUCED)
