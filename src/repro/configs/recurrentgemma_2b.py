"""recurrentgemma-2b — Griffin: RG-LRU recurrence + local attention, 1:2.

[arXiv:2402.19427] 26L, d_model 2560, 10 heads (MQA kv=1, head_dim 256),
d_ff 7680, vocab 256000, window 2048 on attention layers, tied embeddings.
Pattern (rglru, rglru, attn_local) x 8 + (rglru, rglru) remainder = 26.
Sub-quadratic (recurrent state + ring caches) -> long_500k native.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN_LOCAL, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", source="arXiv:2402.19427",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    window=2048, tie_embeddings=True, sharding="tp",
    supports_long_500k=True,
)

REDUCED = ArchConfig(
    name="recurrentgemma-2b-reduced", family="hybrid", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
    vocab=512, head_dim=32, pattern=(RGLRU, ATTN_LOCAL), window=32,
    tie_embeddings=True, sharding="tp",
)

base.register(CONFIG, REDUCED)
