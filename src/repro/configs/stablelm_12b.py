"""stablelm-12b — dense GQA decoder.

[hf:stabilityai/stablelm-2-1_6b family, 12b dims as assigned]
40L, d_model 5120, 32 heads (GQA kv=8), d_ff 13824, vocab 100352.
Pure full attention -> long_500k only as the SWA *variant* (DESIGN.md).
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense", source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, pattern=(ATTN,), sharding="fsdp",
    grad_accum=2,  # memory-term fit (EXPERIMENTS.md §Perf)
    supports_long_500k=False,  # full attention; SWA variant provided
)

REDUCED = ArchConfig(
    name="stablelm-12b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, pattern=(ATTN,), sharding="fsdp",
)

base.register(CONFIG, REDUCED)
