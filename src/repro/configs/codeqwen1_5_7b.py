"""codeqwen1.5-7b — dense decoder, full multi-head attention (kv == heads).

[hf:Qwen/CodeQwen1.5-7B] 32L, d_model 4096, 32 heads (kv=32), d_ff 13440,
vocab 92416. Pure full attention -> long_500k only as the SWA variant.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", source="hf:Qwen/CodeQwen1.5-7B",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab=92416, pattern=(ATTN,), rope_theta=1_000_000.0,
    sharding="fsdp", supports_long_500k=False,
    grad_accum=2,  # memory-term fit (EXPERIMENTS.md §Perf)
)

REDUCED = ArchConfig(
    name="codeqwen1.5-7b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, pattern=(ATTN,), sharding="fsdp",
)

base.register(CONFIG, REDUCED)
